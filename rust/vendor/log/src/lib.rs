//! Offline shim for the `log` crate facade: `error!`/`warn!`/`info!`/
//! `debug!`/`trace!` write directly to stderr, filtered by `RUST_LOG`
//! (a plain level name; default `warn`).  No logger registration needed.

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Max level enabled via RUST_LOG (error|warn|info|debug|trace).
pub fn max_level() -> Level {
    match std::env::var("RUST_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("info") => Level::Info,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        Some("warn") | None | Some(_) => Level::Warn,
    }
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("[{}] {}", level.as_str(), args);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::log($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::log($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::log($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::log($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::log($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn macros_do_not_panic() {
        error!("e {}", 1);
        warn!("w {}", 2);
        info!("i {}", 3);
        debug!("d {}", 4);
        trace!("t {}", 5);
    }
}
