//! Offline shim for the `anyhow` crate (DESIGN.md section 2: no network at
//! build time).  Implements the subset this repository uses: `Error` with a
//! context chain, `Result`, the `anyhow!` / `bail!` / `ensure!` macros and
//! the `Context` extension trait.  Semantics match upstream where it
//! matters: `{}` displays the outermost message, `{:#}` displays the whole
//! chain separated by ": ", and `?` converts any `std::error::Error`.

use std::fmt;

/// An error with a chain of context messages.  `chain[0]` is the outermost
/// (most recently attached) message; the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Used by the single-expression arm of `anyhow!`.
    pub fn msg_from<M: fmt::Display>(message: M) -> Error {
        Error::msg(message)
    }

    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg_from($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", inner().unwrap_err()), "root cause");
    }

    #[test]
    fn macros() {
        let x = 3;
        assert_eq!(format!("{}", anyhow!("got {x}")), "got 3");
        assert_eq!(format!("{}", anyhow!("got {}", 4)), "got 4");
        let s = String::from("plain");
        assert_eq!(format!("{}", anyhow!(s)), "plain");
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "not ok: {}", 9);
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(format!("{}", f(false).unwrap_err()), "not ok: 9");
        fn g() -> Result<()> {
            bail!("bailed {}", 7)
        }
        assert_eq!(format!("{}", g().unwrap_err()), "bailed 7");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(format!("{}", none.context("missing").unwrap_err()), "missing");
    }
}
