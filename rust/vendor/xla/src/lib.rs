//! Offline stub of the `xla` crate (xla_extension bindings) covering the
//! API subset `massv::runtime` uses.
//!
//! Host-side `Literal` construction/extraction is fully functional (it is
//! plain Rust data), so everything that never touches PJRT -- the decoder
//! against scripted backends, the tensor round-trip tests, the serving
//! stack in scripted-artifact mode -- works in this build.  Compiling or
//! executing HLO returns a clear `XlaError`; swap this path dependency for
//! the real `xla` crate on a machine with the PJRT CPU plugin to serve
//! from compiled artifacts (the code in `massv::runtime` is unchanged).

use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "xla stub: {what} requires the real PJRT runtime (this build vendors \
         the offline stub; see rust/vendor/xla)"
    ))
}

// ---------------------------------------------------------------- literals

#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U32(v) => v.len(),
        }
    }
}

/// Element types `Literal` can hold (subset of xla::NativeType).
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn unwrap(d: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }

    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }

    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::U32(v)
    }

    fn unwrap(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host literal: an array with a shape, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array { data: LiteralData, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array { data: T::wrap(vec![v]), dims: vec![] }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal::Array { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal::Tuple(parts)
    }

    pub fn reshape(self, new_dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, dims } => {
                let old: i64 = dims.iter().product();
                let new: i64 = new_dims.iter().product();
                if old != new {
                    return Err(XlaError(format!(
                        "reshape {dims:?} -> {new_dims:?}: element count mismatch"
                    )));
                }
                Ok(Literal::Array { data, dims: new_dims.to_vec() })
            }
            Literal::Tuple(_) => Err(XlaError("cannot reshape a tuple literal".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unwrap(data)
                .ok_or_else(|| XlaError("literal element type mismatch".into())),
            Literal::Tuple(_) => Err(XlaError("cannot extract a tuple literal".into())),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(XlaError("tuple literal has no array shape".into())),
        }
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::Array { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.iter().map(Literal::element_count).sum(),
        }
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(std::mem::take(parts)),
            Literal::Array { .. } => {
                Err(XlaError("decompose_tuple on a non-tuple literal".into()))
            }
        }
    }
}

// ------------------------------------------------------------ PJRT facade

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Succeeds so that artifact-free code paths (manifest loading, the
    /// scripted serving backend) can construct a `Runtime`; only compiling
    /// or executing HLO reports the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (no PJRT)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling an XlaComputation"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        Err(unavailable("parsing HLO text"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("syncing a device buffer"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a loaded executable"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_and_vec() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        assert!(s.to_vec::<i32>().is_err());
        let v = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(v.array_shape().unwrap().dims(), &[3]);
    }

    #[test]
    fn reshape_checks_counts() {
        let v = Literal::vec1(&[0f32; 6]);
        let r = v.clone().reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert!(v.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0f32).decompose_tuple().is_err());
    }

    #[test]
    fn pjrt_stub_reports_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.platform_name().contains("stub"));
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
