//! TCP serving front-end: newline-delimited JSON over a plain socket.
//!
//! `tokio` is not in the offline vendored set (DESIGN.md section 2), so the
//! server is thread-per-connection over `std::net` -- entirely adequate for
//! the request rates this testbed sustains, and it keeps the request path
//! free of any Python.
//!
//! Protocol (one JSON object per line, both directions):
//!   request:  {"op":"generate", "prompt": str, "image": [f32;768],
//!              "task"?: str, "target"?: str, "mode"?: "massv"|
//!              "massv_wo_sdvit"|"baseline"|"tree"|"target_only",
//!              "variant"?: str (drafter variant for mode "tree";
//!              default "massv"), "temperature"?: f32, "top_p"?: f32,
//!              "max_new"?: int, "seed"?: int,
//!              "priority"?: "interactive"|"batch",
//!              "text_only_draft"?: bool, "adaptive"?: bool}
//!   request:  {"op":"metrics"}    |    {"op":"ping"}
//!   response: {"id":n, "text":str, "tokens":[...], "mal":f,
//!              "mean_path_depth":f, "tree_nodes_drafted":n, ...}
//!             or {"error": str}

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::Engine;
use crate::util::json::Json;

pub use protocol::{parse_request, render_metrics, render_response};

pub struct Server {
    engine: Arc<Engine>,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(engine: Arc<Engine>) -> Server {
        Server { stop: Arc::new(AtomicBool::new(false)), engine }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Serve until the stop flag is raised.  Returns the bound address via
    /// the callback (port 0 supported for tests).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut handles = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::info!("connection from {peer}");
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, &engine, &stop) {
                            log::debug!("connection {peer} closed: {e:#}");
                        }
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

fn handle_conn(stream: TcpStream, engine: &Engine, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true)?;
    // bounded reads so the handler notices the stop flag even while a
    // client holds the connection open without sending anything
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed
            Ok(_) => {
                if line.trim().is_empty() {
                    continue;
                }
                let reply = handle_line(&line, engine);
                writer.write_all(reply.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // timeout tick: re-check the stop flag
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn handle_line(line: &str, engine: &Engine) -> Json {
    match parse_request(line, engine) {
        Ok(protocol::Op::Ping) => Json::obj(vec![("ok", Json::Bool(true))]),
        Ok(protocol::Op::Metrics) => render_metrics(engine),
        Ok(protocol::Op::Generate(req)) => {
            let resp = engine.run(req);
            render_response(&resp)
        }
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    }
}

/// Minimal blocking client for examples, benches, and integration tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(crate::util::json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
    }
}
