//! TCP serving front-end: newline-delimited JSON over a plain socket.
//!
//! `tokio` is not in the offline vendored set (DESIGN.md section 2), so the
//! server is thread-per-connection over `std::net` -- entirely adequate for
//! the request rates this testbed sustains, and it keeps the request path
//! free of any Python.
//!
//! The server is generic over `coordinator::EngineFront`: the default is a
//! single `Engine`; `cluster::ClusterEngine` drops in for multi-replica
//! scale-out with prefix-affinity routing (`docs/cluster.md`).  The wire
//! protocol is identical either way -- topology is a deployment knob, not
//! a protocol change.
//!
//! Protocol (one JSON object per line, both directions):
//!   request:  {"op":"generate", "prompt": str,
//!              "image"?: [f32; manifest image_shape product],
//!              "image_id"?: hex str (a previously reported image's
//!              content address; pixels win when both are present),
//!              "task"?: str, "target"?: str, "mode"?: "massv"|
//!              "massv_wo_sdvit"|"baseline"|"tree"|"target_only",
//!              "variant"?: str (drafter variant for mode "tree";
//!              default "massv"), "temperature"?: f32, "top_p"?: f32,
//!              "max_new"?: int, "seed"?: int,
//!              "priority"?: "interactive"|"batch",
//!              "text_only_draft"?: bool, "adaptive"?: bool,
//!              "stream"?: bool, "deadline_ms"?: int,
//!              "tenant"?: str (weighted-fair scheduling + quota key;
//!              default "default")}
//!   request:  {"op":"metrics"}  |  {"op":"ping"}  |  {"op":"cancel","id":n}
//!   response: {"id":n, "text":str, "tokens":[...], "mal":f, "steps":n,
//!              "image_id": hex str, "cache_hit": bool, "prefill_ms": f,
//!              "finish_reason":"eos"|"length"|"cancelled"|"deadline"|
//!              "rejected"|"error", ...}   or {"error": str}
//!
//! With "stream": true the generate response becomes a frame sequence --
//! one {"id":n, "chunk":[tokens...]} line per decode step, then the final
//! summary object (no "chunk" field); chunk concatenation == "tokens".
//! Streaming holds its connection until done; issue cancels for a
//! streaming request from a second connection.  Malformed fields are
//! rejected with an {"error": "field ..."} frame naming the bad field
//! (protocol.rs validates instead of coercing), a client that disconnects
//! mid-stream gets its session cancelled promptly, and the per-session
//! update channel is bounded (coordinator::stream) so a slow reader costs
//! bounded memory.  The HTTP/SSE front end over the same engine lives in
//! `server::http` (`docs/gateway.md`).

pub mod http;
pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Engine, EngineFront, Update};
use crate::util::json::Json;

pub use protocol::{
    parse_generate, parse_request, render_chunk, render_metrics, render_response,
};

pub struct Server<F: EngineFront = Engine> {
    engine: Arc<F>,
    stop: Arc<AtomicBool>,
    /// Live (unreaped) connection threads; see `conn_count_handle`.
    conns: Arc<AtomicUsize>,
}

impl<F: EngineFront> Server<F> {
    pub fn new(engine: Arc<F>) -> Server<F> {
        Server {
            stop: Arc::new(AtomicBool::new(false)),
            conns: Arc::new(AtomicUsize::new(0)),
            engine,
        }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Observes the accept loop's count of tracked connection threads
    /// (live handlers plus finished-but-unreaped ones).  Tests use it to
    /// pin that finished handlers are actually reaped.
    pub fn conn_count_handle(&self) -> Arc<AtomicUsize> {
        self.conns.clone()
    }

    /// Serve until the stop flag is raised.  Returns the bound address via
    /// the callback (port 0 supported for tests).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            // reap finished connection threads each tick; without this the
            // handle vec grows for the server's whole lifetime (one entry
            // per connection ever accepted)
            let mut i = 0;
            while i < handles.len() {
                if handles[i].is_finished() {
                    let _ = handles.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            self.conns.store(handles.len(), Ordering::Relaxed);
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::info!("connection from {peer}");
                    let engine = self.engine.clone();
                    let stop = self.stop.clone();
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) = handle_conn(stream, engine.as_ref(), &stop) {
                            log::debug!("connection {peer} closed: {e:#}");
                        }
                    }));
                    self.conns.store(handles.len(), Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        self.conns.store(0, Ordering::Relaxed);
        Ok(())
    }
}

fn handle_conn<F: EngineFront>(stream: TcpStream, engine: &F, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true)?;
    // bounded reads so the handler notices the stop flag even while a
    // client holds the connection open without sending anything
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // bounded writes so a client that stops reading mid-stream (full
    // socket buffer) turns into a write error -- which the streaming path
    // converts into a cancel -- instead of wedging the handler thread
    stream.set_write_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // NOTE: no clear here.  A timed-out read_line has already consumed
        // any partial line from the socket into `line`; clearing at the
        // top of the loop would silently discard those bytes and corrupt
        // the request a slow client is still writing.  Clear only after a
        // complete line has been handled.
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed
            Ok(_) => {
                if !line.trim().is_empty() {
                    handle_request(&line, engine, &mut writer)?;
                }
                line.clear();
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // timeout tick: re-check the stop flag
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Handle one request line, writing one frame (or, for streaming
/// generates, a chunk-frame sequence followed by the summary frame).
/// Generic over the writer so tests can inject failing sinks and the HTTP
/// gateway's tests can reuse the reference frame sequence.
pub fn handle_request<F: EngineFront, W: Write>(
    line: &str,
    engine: &F,
    writer: &mut W,
) -> Result<()> {
    let reply = match parse_request(line, engine) {
        Ok(protocol::Op::Ping) => Json::obj(vec![("ok", Json::Bool(true))]),
        Ok(protocol::Op::Metrics) => render_metrics(engine),
        Ok(protocol::Op::Cancel(id)) => Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("ok", Json::Bool(engine.cancel(id))),
        ]),
        Ok(protocol::Op::Generate { req, stream: false }) => render_response(&engine.run(req)),
        Ok(protocol::Op::Generate { req, stream: true }) => {
            let id = req.id;
            let rx = engine.submit_streaming(req);
            loop {
                match rx.recv() {
                    Ok(Update::Chunk(tokens)) => {
                        if let Err(e) = write_frame(writer, &render_chunk(id, &tokens)) {
                            // the client went away mid-stream: cancel the
                            // session so the engine stops decoding for a
                            // dead connection, and drain the channel so
                            // the terminal accounting (cancelled counter,
                            // inflight gauge) has settled before this
                            // handler unwinds.  Without the cancel the
                            // session kept decoding to max_new/deadline.
                            engine.cancel(id);
                            while rx.recv().is_ok() {}
                            return Err(e);
                        }
                    }
                    Ok(Update::Done(resp)) => break render_response(&resp),
                    Err(_) => {
                        break Json::obj(vec![("error", Json::str("engine shut down"))])
                    }
                }
            }
        }
        Err(e) => Json::obj(vec![("error", Json::str(format!("{e:#}")))]),
    };
    write_frame(writer, &reply)
}

fn write_frame<W: Write>(writer: &mut W, frame: &Json) -> Result<()> {
    writer.write_all(frame.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

/// Minimal blocking client for examples, benches, and integration tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(crate::util::json::parse(&line)?)
    }

    pub fn ping(&mut self) -> Result<bool> {
        let r = self.call(&Json::obj(vec![("op", Json::str("ping"))]))?;
        Ok(r.get("ok").map(|v| v.as_bool().unwrap_or(false)).unwrap_or(false))
    }

    /// Streaming call (`"stream": true` generates): collects the per-step
    /// chunk frames and returns them with the final summary frame.
    pub fn call_streaming(&mut self, req: &Json) -> Result<(Vec<Vec<i32>>, Json)> {
        let (frames, summary) = self.call_streaming_timed(req)?;
        Ok((frames.into_iter().map(|(_, c)| c).collect(), summary))
    }

    /// Like `call_streaming`, but stamps every chunk frame with the
    /// elapsed milliseconds since the request was written.  The first
    /// stamp is the client-observed TTFT; the scenario replay harness
    /// (`workload::scenario::replay`) derives TPOT from the stamp span.
    pub fn call_streaming_timed(&mut self, req: &Json) -> Result<(Vec<(f64, Vec<i32>)>, Json)> {
        let t0 = std::time::Instant::now();
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut chunks = Vec::new();
        loop {
            let mut line = String::new();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow::anyhow!("connection closed mid-stream"));
            }
            let frame = crate::util::json::parse(&line)?;
            match frame.get("chunk") {
                Some(c) => chunks.push((t0.elapsed().as_secs_f64() * 1e3, c.to_i32_vec()?)),
                None => return Ok((chunks, frame)),
            }
        }
    }
}
