//! Wire protocol: JSON <-> request/response mapping.
//!
//! Streaming (`"stream": true` on a generate) turns the single response
//! line into a frame sequence: one `{"id": n, "chunk": [tokens...]}` line
//! per decode step, terminated by the usual summary response object (the
//! frame *without* a "chunk" field).  Concatenating the chunks yields
//! exactly the summary's "tokens".  `{"op": "cancel", "id": n}` flags a
//! queued or in-flight request; its submitter receives the partial output
//! with `finish_reason = "cancelled"`.
//!
//! Images: a generate request carries `"image"` (raw pixels, validated
//! against the manifest's `image_shape`), `"image_id"` (the content
//! address a previous response reported, skipping the pixel payload), or
//! both (pixels win).  Every response echoes `image_id` plus `cache_hit`
//! and `prefill_ms` -- see `docs/prefix_cache.md`.

use anyhow::{anyhow, Result};

use crate::cache::parse_image_id;
use crate::coordinator::{DecodeMode, EngineFront, Priority, Request, Response};
use crate::spec::GenConfig;
use crate::util::json::{parse, Json};

pub enum Op {
    Ping,
    Metrics,
    Generate { req: Request, stream: bool },
    Cancel(u64),
}

pub fn parse_request<F: EngineFront>(line: &str, engine: &F) -> Result<Op> {
    let v = parse(line)?;
    match v.req("op")?.as_str()? {
        "ping" => Ok(Op::Ping),
        "metrics" => Ok(Op::Metrics),
        "generate" => {
            let stream = v
                .get("stream")
                .map(|b| b.as_bool().unwrap_or(false))
                .unwrap_or(false);
            Ok(Op::Generate { req: parse_generate(&v, engine)?, stream })
        }
        "cancel" => Ok(Op::Cancel(v.req("id")?.as_usize()? as u64)),
        op => Err(anyhow!("unknown op {op:?}")),
    }
}

fn parse_generate<F: EngineFront>(v: &Json, engine: &F) -> Result<Request> {
    let prompt = v.req("prompt")?.as_str()?.to_string();
    let image = match v.get("image") {
        Some(img) => img.to_f32_vec()?,
        None => Vec::new(),
    };
    let image_id = match v.get("image_id") {
        Some(id) => Some(parse_image_id(id.as_str()?)?),
        None => None,
    };
    if image.is_empty() && image_id.is_none() {
        return Err(anyhow!("generate needs \"image\" pixels or an \"image_id\""));
    }
    // expected dims come from the artifact manifest, not a hard-coded shape
    let m = engine.manifest();
    if !image.is_empty() && image.len() != m.image_elems() {
        return Err(anyhow!(
            "image must have {} floats (shape {:?}), got {}",
            m.image_elems(),
            m.image_shape,
            image.len()
        ));
    }
    let text_only_draft = v
        .get("text_only_draft")
        .map(|b| b.as_bool().unwrap_or(false))
        .unwrap_or(false);
    let adaptive = v
        .get("adaptive")
        .map(|b| b.as_bool().unwrap_or(false))
        .unwrap_or(false);
    let mode = match v.get("mode").and_then(|m| m.as_str().ok()).unwrap_or("massv") {
        "target_only" => DecodeMode::TargetOnly,
        // token-tree speculation; drafter variant comes from the separate
        // "variant" field (default "massv").  Validate it here so a typo is
        // a hard error, exactly like a typo'd chain-mode variant -- the
        // router's missing-drafter fallback is for absent artifacts, not
        // malformed requests.
        "tree" => {
            let variant =
                v.get("variant").and_then(|x| x.as_str().ok()).unwrap_or("massv");
            if !matches!(variant, "massv" | "massv_wo_sdvit" | "baseline") {
                return Err(anyhow!("unknown drafter variant {variant:?}"));
            }
            DecodeMode::Tree { variant: variant.to_string(), text_only_draft, adaptive }
        }
        variant @ ("massv" | "massv_wo_sdvit" | "baseline") => DecodeMode::Speculative {
            variant: variant.to_string(),
            text_only_draft,
            adaptive,
        },
        m => return Err(anyhow!("unknown mode {m:?}")),
    };
    let gen = GenConfig {
        temperature: v.get("temperature").map(|t| t.as_f64().unwrap_or(0.0)).unwrap_or(0.0) as f32,
        top_p: v.get("top_p").map(|t| t.as_f64().unwrap_or(1.0)).unwrap_or(1.0) as f32,
        max_new: v
            .get("max_new")
            .map(|t| t.as_usize().unwrap_or(48))
            .unwrap_or(48),
        seed: v.get("seed").map(|t| t.as_i64().unwrap_or(0)).unwrap_or(0) as u64,
        tree: None, // engine default tree shape (SpecParams::tree)
    };
    let priority = match v.get("priority").and_then(|p| p.as_str().ok()) {
        Some("batch") => Priority::Batch,
        _ => Priority::Interactive,
    };
    let deadline_ms = v.get("deadline_ms").and_then(|d| d.as_usize().ok()).map(|d| d as u64);
    // optional per-request drafter vision compression override; 0 falls
    // back to the engine/manifest default (same as absent)
    let draft_vision_ratio = v
        .get("draft_vision_ratio")
        .and_then(|r| r.as_usize().ok())
        .map(|r| r as u32)
        .filter(|r| *r > 0);
    Ok(Request {
        id: engine.next_id(),
        task: v
            .get("task")
            .and_then(|t| t.as_str().ok())
            .unwrap_or("adhoc")
            .to_string(),
        prompt,
        image,
        image_id,
        target: v
            .get("target")
            .and_then(|t| t.as_str().ok())
            .unwrap_or("")
            .to_string(),
        mode,
        gen,
        draft_vision_ratio,
        priority,
        deadline_ms,
    })
}

/// One streaming frame: the tokens emitted by a single decode step.
pub fn render_chunk(id: u64, tokens: &[i32]) -> Json {
    Json::obj(vec![("id", Json::num(id as f64)), ("chunk", Json::arr_i32(tokens))])
}

pub fn render_response(r: &Response) -> Json {
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        ("text", Json::str(r.text.clone())),
        ("tokens", Json::arr_i32(&r.tokens)),
        ("mal", Json::num(r.mal)),
        ("verify_calls", Json::num(r.verify_calls as f64)),
        ("accepted_draft", Json::num(r.accepted_draft as f64)),
        ("mean_path_depth", Json::num(r.mean_path_depth)),
        ("tree_nodes_drafted", Json::num(r.tree_nodes_drafted as f64)),
        ("finished_by_eos", Json::Bool(r.finished_by_eos)),
        ("steps", Json::num(r.steps as f64)),
        ("finish_reason", Json::str(r.finish_reason.clone())),
        ("queue_ms", Json::num(r.queue_ms)),
        ("latency_ms", Json::num(r.latency_ms)),
        ("image_id", Json::str(r.image_id.clone())),
        ("cache_hit", Json::Bool(r.cache_hit)),
        ("prefill_ms", Json::num(r.prefill_ms)),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Json::obj(fields)
}

pub fn render_metrics<F: EngineFront>(engine: &F) -> Json {
    let mut fields: Vec<(String, Json)> = engine
        .scrape()
        .into_iter()
        .map(|(k, v)| (k, Json::num(v)))
        .collect();
    fields.sort_by(|a, b| a.0.cmp(&b.0));
    let execs = engine.exec_stats();
    let exec_json = Json::Arr(
        execs
            .into_iter()
            .map(|(name, calls, mean_us)| {
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("calls", Json::num(calls as f64)),
                    ("mean_micros", Json::num(mean_us)),
                ])
            })
            .collect(),
    );
    let mut obj: Vec<(String, Json)> = fields;
    obj.push(("executables".to_string(), exec_json));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    // parse_generate needs an Engine only for next_id(); these tests cover
    // the pure pieces.  Full protocol round-trips live in tests/server.rs.

    #[test]
    fn render_response_round_trips() {
        let r = Response {
            id: 9,
            text: "the red circle .".into(),
            tokens: vec![5, 6, 7, 8],
            mal: 3.25,
            verify_calls: 4,
            accepted_draft: 9,
            mean_path_depth: 2.5,
            tree_nodes_drafted: 18,
            finished_by_eos: true,
            steps: 5,
            finish_reason: "eos".into(),
            queue_ms: 0.5,
            latency_ms: 12.25,
            image_id: "00000000deadbeef".into(),
            cache_hit: true,
            prefill_ms: 1.5,
            error: None,
        };
        let j = render_response(&r);
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(back.get("id").unwrap().as_i64().unwrap(), 9);
        assert_eq!(back.get("image_id").unwrap().as_str().unwrap(), "00000000deadbeef");
        assert!(back.get("cache_hit").unwrap().as_bool().unwrap());
        assert!((back.get("prefill_ms").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
        assert_eq!(back.get("text").unwrap().as_str().unwrap(), "the red circle .");
        assert_eq!(back.get("tokens").unwrap().to_i32_vec().unwrap(), vec![5, 6, 7, 8]);
        assert!((back.get("mal").unwrap().as_f64().unwrap() - 3.25).abs() < 1e-9);
        assert!((back.get("mean_path_depth").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(back.get("tree_nodes_drafted").unwrap().as_i64().unwrap(), 18);
        assert_eq!(back.get("steps").unwrap().as_i64().unwrap(), 5);
        assert_eq!(back.get("finish_reason").unwrap().as_str().unwrap(), "eos");
        assert!(back.get("error").is_none());
    }

    #[test]
    fn render_chunk_frame_shape() {
        let j = render_chunk(7, &[10, 11, 12]);
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(back.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(back.get("chunk").unwrap().to_i32_vec().unwrap(), vec![10, 11, 12]);
        // the final summary frame is distinguished by the absent "chunk"
        assert!(back.get("tokens").is_none());
    }

    #[test]
    fn tree_mode_wire_name() {
        use crate::coordinator::DecodeMode;
        let m = DecodeMode::Tree {
            variant: "massv".into(),
            text_only_draft: false,
            adaptive: false,
        };
        assert_eq!(m.wire_name(), "tree");
    }

    #[test]
    fn render_failure_has_error() {
        let r = Response::failure(1, "boom".into());
        let j = render_response(&r);
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(j.get("finish_reason").unwrap().as_str().unwrap(), "error");
    }
}
