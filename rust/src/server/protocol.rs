//! Wire protocol: JSON <-> request/response mapping.
//!
//! Streaming (`"stream": true` on a generate) turns the single response
//! line into a frame sequence: one `{"id": n, "chunk": [tokens...]}` line
//! per decode step, terminated by the usual summary response object (the
//! frame *without* a "chunk" field).  Concatenating the chunks yields
//! exactly the summary's "tokens".  `{"op": "cancel", "id": n}` flags a
//! queued or in-flight request; its submitter receives the partial output
//! with `finish_reason = "cancelled"`.
//!
//! Images: a generate request carries `"image"` (raw pixels, validated
//! against the manifest's `image_shape`), `"image_id"` (the content
//! address a previous response reported, skipping the pixel payload), or
//! both (pixels win).  Every response echoes `image_id` plus `cache_hit`
//! and `prefill_ms` -- see `docs/prefix_cache.md`.

use anyhow::{anyhow, Result};

use crate::cache::parse_image_id;
use crate::coordinator::{
    DecodeMode, EngineFront, Priority, Request, Response, DEFAULT_TENANT,
};
use crate::spec::GenConfig;
use crate::util::json::{parse, Json};

pub enum Op {
    Ping,
    Metrics,
    Generate { req: Request, stream: bool },
    Cancel(u64),
}

// ---------------------------------------------------------- validation
//
// Typed optional-field accessors.  A present-but-malformed field is a hard
// error naming the field, never a silent default: the pre-fix behavior
// mapped e.g. a non-numeric "temperature" to 0.0 via `unwrap_or`, so a
// client typo ("temperature": "0.7") silently changed sampling.  Both the
// TCP and HTTP front ends parse through these, so they reject identically.

fn opt_f64(v: &Json, name: &str) -> Result<Option<f64>> {
    match v.get(name) {
        None => Ok(None),
        Some(x) => Ok(Some(
            x.as_f64().map_err(|_| anyhow!("field {name:?} must be a number"))?,
        )),
    }
}

fn opt_uint(v: &Json, name: &str) -> Result<Option<u64>> {
    match opt_f64(v, name)? {
        None => Ok(None),
        Some(f) => {
            if !f.is_finite() || f < 0.0 || f.fract() != 0.0 || f > 9e15 {
                return Err(anyhow!("field {name:?} must be a non-negative integer, got {f}"));
            }
            Ok(Some(f as u64))
        }
    }
}

fn opt_bool(v: &Json, name: &str) -> Result<Option<bool>> {
    match v.get(name) {
        None => Ok(None),
        Some(x) => Ok(Some(
            x.as_bool().map_err(|_| anyhow!("field {name:?} must be a boolean"))?,
        )),
    }
}

fn opt_str<'a>(v: &'a Json, name: &str) -> Result<Option<&'a str>> {
    match v.get(name) {
        None => Ok(None),
        Some(x) => Ok(Some(
            x.as_str().map_err(|_| anyhow!("field {name:?} must be a string"))?,
        )),
    }
}

pub fn parse_request<F: EngineFront>(line: &str, engine: &F) -> Result<Op> {
    let v = parse(line)?;
    match v.req("op")?.as_str()? {
        "ping" => Ok(Op::Ping),
        "metrics" => Ok(Op::Metrics),
        "generate" => {
            let stream = opt_bool(&v, "stream")?.unwrap_or(false);
            Ok(Op::Generate { req: parse_generate(&v, engine)?, stream })
        }
        "cancel" => {
            let id = opt_uint(&v, "id")?.ok_or_else(|| anyhow!("cancel needs an \"id\""))?;
            Ok(Op::Cancel(id))
        }
        op => Err(anyhow!("unknown op {op:?}")),
    }
}

/// Parse + validate a generate body into a `Request` (id allocated from
/// the engine).  Shared by the TCP protocol and the HTTP gateway, so both
/// front ends accept and reject exactly the same inputs.
pub fn parse_generate<F: EngineFront>(v: &Json, engine: &F) -> Result<Request> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .map_err(|_| anyhow!("field \"prompt\" must be a string"))?
        .to_string();
    let image = match v.get("image") {
        Some(img) => img
            .to_f32_vec()
            .map_err(|_| anyhow!("field \"image\" must be an array of numbers"))?,
        None => Vec::new(),
    };
    let image_id = match opt_str(v, "image_id")? {
        Some(id) => Some(parse_image_id(id)?),
        None => None,
    };
    if image.is_empty() && image_id.is_none() {
        return Err(anyhow!("generate needs \"image\" pixels or an \"image_id\""));
    }
    // expected dims come from the artifact manifest, not a hard-coded shape
    let m = engine.manifest();
    if !image.is_empty() && image.len() != m.image_elems() {
        return Err(anyhow!(
            "image must have {} floats (shape {:?}), got {}",
            m.image_elems(),
            m.image_shape,
            image.len()
        ));
    }
    let text_only_draft = opt_bool(v, "text_only_draft")?.unwrap_or(false);
    let adaptive = opt_bool(v, "adaptive")?.unwrap_or(false);
    let mode = match opt_str(v, "mode")?.unwrap_or("massv") {
        "target_only" => DecodeMode::TargetOnly,
        // token-tree speculation; drafter variant comes from the separate
        // "variant" field (default "massv").  Validate it here so a typo is
        // a hard error, exactly like a typo'd chain-mode variant -- the
        // router's missing-drafter fallback is for absent artifacts, not
        // malformed requests.
        "tree" => {
            let variant = opt_str(v, "variant")?.unwrap_or("massv");
            if !matches!(variant, "massv" | "massv_wo_sdvit" | "baseline") {
                return Err(anyhow!("unknown drafter variant {variant:?}"));
            }
            DecodeMode::Tree { variant: variant.to_string(), text_only_draft, adaptive }
        }
        variant @ ("massv" | "massv_wo_sdvit" | "baseline") => DecodeMode::Speculative {
            variant: variant.to_string(),
            text_only_draft,
            adaptive,
        },
        m => return Err(anyhow!("unknown mode {m:?}")),
    };
    let temperature = opt_f64(v, "temperature")?.unwrap_or(0.0);
    if !temperature.is_finite() || temperature < 0.0 {
        return Err(anyhow!("field \"temperature\" must be a number >= 0, got {temperature}"));
    }
    let top_p = opt_f64(v, "top_p")?.unwrap_or(1.0);
    if !top_p.is_finite() || top_p <= 0.0 || top_p > 1.0 {
        return Err(anyhow!("field \"top_p\" must satisfy 0 < top_p <= 1, got {top_p}"));
    }
    let max_new = opt_uint(v, "max_new")?.unwrap_or(48);
    if max_new == 0 {
        return Err(anyhow!("field \"max_new\" must be an integer >= 1"));
    }
    let gen = GenConfig {
        temperature: temperature as f32,
        top_p: top_p as f32,
        max_new: max_new as usize,
        seed: opt_uint(v, "seed")?.unwrap_or(0),
        tree: None, // engine default tree shape (SpecParams::tree)
    };
    let priority = match opt_str(v, "priority")? {
        None | Some("interactive") => Priority::Interactive,
        Some("batch") => Priority::Batch,
        Some(p) => {
            return Err(anyhow!(
                "field \"priority\" must be \"interactive\" or \"batch\", got {p:?}"
            ))
        }
    };
    let deadline_ms = opt_uint(v, "deadline_ms")?;
    // optional per-request drafter vision compression override; 0 falls
    // back to the engine/manifest default (same as absent)
    let draft_vision_ratio =
        opt_uint(v, "draft_vision_ratio")?.map(|r| r as u32).filter(|r| *r > 0);
    let tenant = match opt_str(v, "tenant")? {
        None => DEFAULT_TENANT.to_string(),
        Some("") => return Err(anyhow!("field \"tenant\" must be a non-empty string")),
        Some(t) => t.to_string(),
    };
    Ok(Request {
        id: engine.next_id(),
        task: opt_str(v, "task")?.unwrap_or("adhoc").to_string(),
        prompt,
        image,
        image_id,
        target: opt_str(v, "target")?.unwrap_or("").to_string(),
        mode,
        gen,
        draft_vision_ratio,
        priority,
        deadline_ms,
        tenant,
    })
}

/// One streaming frame: the tokens emitted by a single decode step.
pub fn render_chunk(id: u64, tokens: &[i32]) -> Json {
    Json::obj(vec![("id", Json::num(id as f64)), ("chunk", Json::arr_i32(tokens))])
}

pub fn render_response(r: &Response) -> Json {
    let mut fields = vec![
        ("id", Json::num(r.id as f64)),
        ("text", Json::str(r.text.clone())),
        ("tokens", Json::arr_i32(&r.tokens)),
        ("mal", Json::num(r.mal)),
        ("verify_calls", Json::num(r.verify_calls as f64)),
        ("accepted_draft", Json::num(r.accepted_draft as f64)),
        ("mean_path_depth", Json::num(r.mean_path_depth)),
        ("tree_nodes_drafted", Json::num(r.tree_nodes_drafted as f64)),
        ("finished_by_eos", Json::Bool(r.finished_by_eos)),
        ("steps", Json::num(r.steps as f64)),
        ("finish_reason", Json::str(r.finish_reason.clone())),
        ("queue_ms", Json::num(r.queue_ms)),
        ("latency_ms", Json::num(r.latency_ms)),
        ("image_id", Json::str(r.image_id.clone())),
        ("cache_hit", Json::Bool(r.cache_hit)),
        ("prefill_ms", Json::num(r.prefill_ms)),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::str(e.clone())));
    }
    Json::obj(fields)
}

pub fn render_metrics<F: EngineFront>(engine: &F) -> Json {
    let mut fields: Vec<(String, Json)> = engine
        .scrape()
        .into_iter()
        .map(|(k, v)| (k, Json::num(v)))
        .collect();
    fields.sort_by(|a, b| a.0.cmp(&b.0));
    let execs = engine.exec_stats();
    let exec_json = Json::Arr(
        execs
            .into_iter()
            .map(|(name, calls, mean_us)| {
                Json::obj(vec![
                    ("name", Json::str(name)),
                    ("calls", Json::num(calls as f64)),
                    ("mean_micros", Json::num(mean_us)),
                ])
            })
            .collect(),
    );
    let mut obj: Vec<(String, Json)> = fields;
    obj.push(("executables".to_string(), exec_json));
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    // parse_generate needs an Engine only for next_id(); these tests cover
    // the pure pieces.  Full protocol round-trips live in tests/server.rs.

    #[test]
    fn render_response_round_trips() {
        let r = Response {
            id: 9,
            text: "the red circle .".into(),
            tokens: vec![5, 6, 7, 8],
            mal: 3.25,
            verify_calls: 4,
            accepted_draft: 9,
            mean_path_depth: 2.5,
            tree_nodes_drafted: 18,
            finished_by_eos: true,
            steps: 5,
            finish_reason: "eos".into(),
            queue_ms: 0.5,
            latency_ms: 12.25,
            image_id: "00000000deadbeef".into(),
            cache_hit: true,
            prefill_ms: 1.5,
            error: None,
        };
        let j = render_response(&r);
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(back.get("id").unwrap().as_i64().unwrap(), 9);
        assert_eq!(back.get("image_id").unwrap().as_str().unwrap(), "00000000deadbeef");
        assert!(back.get("cache_hit").unwrap().as_bool().unwrap());
        assert!((back.get("prefill_ms").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-9);
        assert_eq!(back.get("text").unwrap().as_str().unwrap(), "the red circle .");
        assert_eq!(back.get("tokens").unwrap().to_i32_vec().unwrap(), vec![5, 6, 7, 8]);
        assert!((back.get("mal").unwrap().as_f64().unwrap() - 3.25).abs() < 1e-9);
        assert!((back.get("mean_path_depth").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(back.get("tree_nodes_drafted").unwrap().as_i64().unwrap(), 18);
        assert_eq!(back.get("steps").unwrap().as_i64().unwrap(), 5);
        assert_eq!(back.get("finish_reason").unwrap().as_str().unwrap(), "eos");
        assert!(back.get("error").is_none());
    }

    #[test]
    fn render_chunk_frame_shape() {
        let j = render_chunk(7, &[10, 11, 12]);
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(back.get("id").unwrap().as_i64().unwrap(), 7);
        assert_eq!(back.get("chunk").unwrap().to_i32_vec().unwrap(), vec![10, 11, 12]);
        // the final summary frame is distinguished by the absent "chunk"
        assert!(back.get("tokens").is_none());
    }

    #[test]
    fn tree_mode_wire_name() {
        use crate::coordinator::DecodeMode;
        let m = DecodeMode::Tree {
            variant: "massv".into(),
            text_only_draft: false,
            adaptive: false,
        };
        assert_eq!(m.wire_name(), "tree");
    }

    #[test]
    fn typed_field_accessors_reject_wrong_types_and_name_the_field() {
        let v = parse(r#"{"s":"x","f":1.5,"i":3,"b":true,"neg":-1}"#).unwrap();
        // well-typed values pass through
        assert_eq!(opt_str(&v, "s").unwrap(), Some("x"));
        assert_eq!(opt_f64(&v, "f").unwrap(), Some(1.5));
        assert_eq!(opt_uint(&v, "i").unwrap(), Some(3));
        assert_eq!(opt_bool(&v, "b").unwrap(), Some(true));
        // absent fields are None, not errors
        assert_eq!(opt_str(&v, "missing").unwrap(), None);
        assert_eq!(opt_uint(&v, "missing").unwrap(), None);
        // wrong types are errors naming the offending field
        for (err, field) in [
            (opt_f64(&v, "s").unwrap_err(), "s"),
            (opt_uint(&v, "f").unwrap_err(), "f"), // fractional: not an integer
            (opt_uint(&v, "neg").unwrap_err(), "neg"),
            (opt_bool(&v, "i").unwrap_err(), "i"),
            (opt_str(&v, "b").unwrap_err(), "b"),
        ] {
            let msg = format!("{err:#}");
            assert!(msg.contains(&format!("{field:?}")), "{msg} should name {field:?}");
        }
    }

    #[test]
    fn render_failure_has_error() {
        let r = Response::failure(1, "boom".into());
        let j = render_response(&r);
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(j.get("finish_reason").unwrap().as_str().unwrap(), "error");
    }
}
