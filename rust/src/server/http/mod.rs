//! HTTP/1.1 + SSE gateway: the engine's second front door.
//!
//! Same thread-per-connection `std::net` substrate as the TCP server
//! (`tokio` is not in the offline vendored set), same `EngineFront`
//! abstraction underneath -- a single `Engine` or a multi-replica
//! `cluster::ClusterEngine` serves identically.  The gateway adds what a
//! shared deployment needs at the edge: OpenAI-style JSON endpoints, SSE
//! streaming that reuses the TCP protocol's chunk frames (so chunk
//! concatenation is bit-identical to the TCP `tokens` array), and
//! per-tenant admission control (token buckets + concurrency quotas) that
//! sheds with `429`/`503` + `Retry-After` instead of queue-timeout
//! failures.  Full endpoint reference: `docs/gateway.md`.
//!
//! Endpoints (one request per connection, `Connection: close`):
//!   GET  /healthz          -> {"ok":true}
//!   GET  /metrics          -> engine scrape + gateway `http_*` counters
//!   POST /v1/cancel/{id}   -> {"id":n,"ok":bool}
//!   POST /v1/generate      -> generate body (same fields as the TCP
//!                             protocol); "stream":true switches the
//!                             response to `text/event-stream` with one
//!                             `data: {"id":n,"chunk":[...]}` frame per
//!                             decode step, a `data: {summary}` frame, and
//!                             a terminal `data: [DONE]` sentinel.
//!
//! The tenant is the `x-tenant` header when present, else the body's
//! `tenant` field, else "default".  Validation is shared with the TCP
//! protocol (`protocol::parse_generate`), so both fronts reject the same
//! inputs -- the HTTP gateway maps those errors to `400` with the same
//! field-naming message.

pub mod admission;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::{Engine, EngineFront, Update};
use crate::metrics::Counter;
use crate::server::protocol::{
    parse_generate, render_chunk, render_metrics, render_response,
};
use crate::util::json::{parse, Json};

pub use admission::{Admit, AdmissionControl, Permit, Quota};

/// Gateway knobs: the default quota applies to any tenant without an
/// explicit override.  `Quota::default()` (all zeros) admits everything.
#[derive(Clone, Default)]
pub struct GatewayConfig {
    pub default_quota: Quota,
    pub tenant_quotas: Vec<(String, Quota)>,
}

/// Gateway-local counters, merged into the `/metrics` response.  They live
/// here rather than in the engine's registry because shedding happens
/// before the engine ever sees the request.
#[derive(Default)]
pub struct HttpCounters {
    /// requests that reached routing (every parsed HTTP request)
    pub requests: Counter,
    /// requests shed with 429 (tenant over rate quota)
    pub shed_429: Counter,
    /// requests shed with 503 (tenant over concurrency quota or engine
    /// admission rejected)
    pub shed_503: Counter,
}

pub struct HttpServer<F: EngineFront = Engine> {
    engine: Arc<F>,
    admission: Arc<AdmissionControl>,
    counters: Arc<HttpCounters>,
    stop: Arc<AtomicBool>,
    conns: Arc<AtomicUsize>,
}

impl<F: EngineFront> HttpServer<F> {
    pub fn new(engine: Arc<F>, cfg: GatewayConfig) -> HttpServer<F> {
        let admission = AdmissionControl::new(cfg.default_quota);
        for (tenant, quota) in &cfg.tenant_quotas {
            admission.set_quota(tenant, *quota);
        }
        HttpServer {
            engine,
            admission: Arc::new(admission),
            counters: Arc::new(HttpCounters::default()),
            stop: Arc::new(AtomicBool::new(false)),
            conns: Arc::new(AtomicUsize::new(0)),
        }
    }

    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    pub fn conn_count_handle(&self) -> Arc<AtomicUsize> {
        self.conns.clone()
    }

    /// Shed/request counters (observability + bench assertions).
    pub fn counters(&self) -> Arc<HttpCounters> {
        self.counters.clone()
    }

    /// The admission table (runtime quota changes).
    pub fn admission(&self) -> Arc<AdmissionControl> {
        self.admission.clone()
    }

    /// Serve until the stop flag is raised.  Same accept-loop shape as the
    /// TCP `Server`: non-blocking accept with a 5ms idle tick, per-tick
    /// reaping of finished connection threads.
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            let mut i = 0;
            while i < handles.len() {
                if handles[i].is_finished() {
                    let _ = handles.swap_remove(i).join();
                } else {
                    i += 1;
                }
            }
            self.conns.store(handles.len(), Ordering::Relaxed);
            match listener.accept() {
                Ok((stream, peer)) => {
                    log::info!("http connection from {peer}");
                    let engine = self.engine.clone();
                    let admission = self.admission.clone();
                    let counters = self.counters.clone();
                    let stop = self.stop.clone();
                    handles.push(std::thread::spawn(move || {
                        if let Err(e) =
                            handle_conn(stream, engine.as_ref(), &admission, &counters, &stop)
                        {
                            log::debug!("http connection {peer} closed: {e:#}");
                        }
                    }));
                    self.conns.store(handles.len(), Ordering::Relaxed);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        for h in handles {
            let _ = h.join();
        }
        self.conns.store(0, Ordering::Relaxed);
        Ok(())
    }
}

// ------------------------------------------------------------ wire level

struct HttpRequest {
    method: String,
    path: String,
    /// header names lowercased
    headers: Vec<(String, String)>,
    body: String,
}

impl HttpRequest {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }
}

/// `read_line` that treats read-timeout ticks as "check the stop flag and
/// keep going" (the socket has a 100ms read timeout so handlers notice
/// shutdown).  Returns Ok(0) on EOF.
fn read_line_tolerant(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    stop: &AtomicBool,
) -> Result<usize> {
    loop {
        if stop.load(Ordering::Relaxed) {
            return Err(anyhow!("server stopping"));
        }
        match reader.read_line(line) {
            Ok(n) => return Ok(n),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue; // partial line already buffered in `line`
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn read_exact_tolerant(
    reader: &mut BufReader<TcpStream>,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<()> {
    let mut filled = 0;
    while filled < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Err(anyhow!("server stopping"));
        }
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(anyhow!("connection closed mid-body")),
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Parse one HTTP/1.1 request.  Returns None on a clean EOF before the
/// request line (client connected and left).
fn read_http_request(
    reader: &mut BufReader<TcpStream>,
    stop: &AtomicBool,
) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    if read_line_tolerant(reader, &mut line, stop)? == 0 {
        return Ok(None);
    }
    let mut parts = line.trim_end().splitn(3, ' ');
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        return Err(anyhow!("malformed request line {line:?}"));
    }
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        if read_line_tolerant(reader, &mut h, stop)? == 0 {
            return Err(anyhow!("connection closed mid-headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    // 16 MiB cap: an image payload is ~100s of KiB; anything larger is a
    // hostile or broken client, not a request worth buffering
    if content_length > 16 << 20 {
        return Err(anyhow!("content-length {content_length} exceeds the 16 MiB cap"));
    }
    let mut body = vec![0u8; content_length];
    read_exact_tolerant(reader, &mut body, stop)?;
    Ok(Some(HttpRequest {
        method,
        path,
        headers,
        body: String::from_utf8(body).map_err(|_| anyhow!("body is not valid utf-8"))?,
    }))
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

fn write_json_response<W: Write>(
    w: &mut W,
    status: u16,
    extra_headers: &[(&str, String)],
    body: &Json,
) -> Result<()> {
    let payload = body.to_string();
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n",
        status_reason(status),
        payload.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(payload.as_bytes())?;
    w.flush()?;
    Ok(())
}

fn write_sse_header<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()?;
    Ok(())
}

fn write_sse_frame<W: Write>(w: &mut W, data: &str) -> Result<()> {
    w.write_all(b"data: ")?;
    w.write_all(data.as_bytes())?;
    w.write_all(b"\n\n")?;
    w.flush()?;
    Ok(())
}

fn err_body(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

// ------------------------------------------------------------- handlers

fn handle_conn<F: EngineFront>(
    stream: TcpStream,
    engine: &F,
    admission: &AdmissionControl,
    counters: &HttpCounters,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // bounded writes: a client that stops reading an SSE stream becomes a
    // write error, which the streaming path converts into a cancel
    stream.set_write_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // one request per connection (Connection: close): streaming responses
    // own the socket until done, and per-request connections keep the
    // handler state machine trivial
    let req = match read_http_request(&mut reader, stop)? {
        Some(r) => r,
        None => return Ok(()),
    };
    counters.requests.inc();
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            write_json_response(&mut writer, 200, &[], &Json::obj(vec![("ok", Json::Bool(true))]))
        }
        ("GET", "/metrics") => {
            let mut obj = match render_metrics(engine) {
                Json::Obj(fields) => fields,
                other => vec![("metrics".to_string(), other)],
            };
            obj.push(("http_requests".into(), Json::num(counters.requests.get() as f64)));
            obj.push(("http_shed_429".into(), Json::num(counters.shed_429.get() as f64)));
            obj.push(("http_shed_503".into(), Json::num(counters.shed_503.get() as f64)));
            write_json_response(&mut writer, 200, &[], &Json::Obj(obj))
        }
        ("POST", path) if path.starts_with("/v1/cancel/") => {
            match path["/v1/cancel/".len()..].parse::<u64>() {
                Ok(id) => write_json_response(
                    &mut writer,
                    200,
                    &[],
                    &Json::obj(vec![
                        ("id", Json::num(id as f64)),
                        ("ok", Json::Bool(engine.cancel(id))),
                    ]),
                ),
                Err(_) => write_json_response(
                    &mut writer,
                    400,
                    &[],
                    &err_body("cancel path must end in a numeric request id"),
                ),
            }
        }
        ("POST", "/v1/generate") => handle_generate(&req, engine, admission, counters, &mut writer),
        (_, "/healthz" | "/metrics" | "/v1/generate") => write_json_response(
            &mut writer,
            405,
            &[],
            &err_body("method not allowed for this path"),
        ),
        _ => write_json_response(&mut writer, 404, &[], &err_body("no such endpoint")),
    }
}

fn handle_generate<F: EngineFront, W: Write>(
    http: &HttpRequest,
    engine: &F,
    admission: &AdmissionControl,
    counters: &HttpCounters,
    writer: &mut W,
) -> Result<()> {
    let body = match parse(&http.body) {
        Ok(v) => v,
        Err(e) => {
            return write_json_response(&mut *writer, 400, &[], &err_body(&format!("{e}")))
        }
    };
    let stream = match body.get("stream") {
        None => false,
        Some(s) => match s.as_bool() {
            Ok(b) => b,
            Err(_) => {
                return write_json_response(
                    writer,
                    400,
                    &[],
                    &err_body("field \"stream\" must be a boolean"),
                )
            }
        },
    };
    // shared validation with the TCP protocol: both fronts reject the same
    // inputs with the same field-naming messages
    let mut req = match parse_generate(&body, engine) {
        Ok(r) => r,
        Err(e) => return write_json_response(writer, 400, &[], &err_body(&format!("{e:#}"))),
    };
    // the x-tenant header outranks the body field (the header is what a
    // proxy stamps after authentication)
    if let Some(h) = http.header("x-tenant") {
        if h.is_empty() {
            return write_json_response(
                writer,
                400,
                &[],
                &err_body("header \"x-tenant\" must be non-empty"),
            );
        }
        req.tenant = h.to_string();
    }
    // admission: shed before the engine sees the request.  The permit is
    // held until this handler returns, covering the whole generation.
    let _permit = match admission.admit(&req.tenant) {
        Admit::Ok(p) => p,
        Admit::RetryAfter(secs) => {
            counters.shed_429.inc();
            return write_json_response(
                writer,
                429,
                &[("Retry-After", secs.to_string())],
                &Json::obj(vec![
                    ("error", Json::str("tenant over rate quota")),
                    ("retry_after", Json::num(secs as f64)),
                ]),
            );
        }
        Admit::Busy => {
            counters.shed_503.inc();
            return write_json_response(
                writer,
                503,
                &[("Retry-After", "1".to_string())],
                &Json::obj(vec![
                    ("error", Json::str("tenant over concurrency quota")),
                    ("retry_after", Json::num(1.0)),
                ]),
            );
        }
    };
    if !stream {
        let resp = engine.run(req);
        if resp.finish_reason == "rejected" {
            counters.shed_503.inc();
            return write_json_response(
                writer,
                503,
                &[("Retry-After", "1".to_string())],
                &render_response(&resp),
            );
        }
        return write_json_response(writer, 200, &[], &render_response(&resp));
    }
    // streaming: hold the status line until the first update so an
    // engine-side rejection can still become a clean 503
    let id = req.id;
    let rx = engine.submit_streaming(req);
    let first = rx.recv();
    if let Ok(Update::Done(resp)) = &first {
        if resp.finish_reason == "rejected" {
            counters.shed_503.inc();
            return write_json_response(
                writer,
                503,
                &[("Retry-After", "1".to_string())],
                &render_response(resp),
            );
        }
    }
    write_sse_header(writer)?;
    let mut update = match first {
        Ok(u) => Some(u),
        Err(_) => None,
    };
    loop {
        match update.take() {
            Some(Update::Chunk(tokens)) => {
                if let Err(e) = write_sse_frame(writer, &render_chunk(id, &tokens).to_string()) {
                    // client gone mid-stream: same fix as the TCP path --
                    // cancel so the engine stops decoding for a dead
                    // connection, drain so terminal accounting settles
                    engine.cancel(id);
                    while rx.recv().is_ok() {}
                    return Err(e);
                }
            }
            Some(Update::Done(resp)) => {
                write_sse_frame(writer, &render_response(&resp).to_string())?;
                write_sse_frame(writer, "[DONE]")?;
                return Ok(());
            }
            None => {
                // engine shut down before Done: close the stream cleanly
                write_sse_frame(writer, &err_body("engine shut down").to_string())?;
                write_sse_frame(writer, "[DONE]")?;
                return Ok(());
            }
        }
        update = rx.recv().ok();
    }
}

// --------------------------------------------------------------- client

/// Minimal blocking HTTP client for tests and benches: one fresh
/// connection per request, reads to EOF (the server closes).
pub struct HttpClient {
    addr: String,
}

impl HttpClient {
    pub fn new(addr: impl Into<String>) -> HttpClient {
        HttpClient { addr: addr.into() }
    }

    /// Send one request; returns (status, headers lowercased, body).
    pub fn request(
        &self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: Option<&Json>,
    ) -> Result<(u16, Vec<(String, String)>, String)> {
        let mut stream = TcpStream::connect(&self.addr)?;
        stream.set_nodelay(true)?;
        let payload = body.map(|b| b.to_string()).unwrap_or_default();
        let mut req = format!("{method} {path} HTTP/1.1\r\nHost: {}\r\n", self.addr);
        for (k, v) in headers {
            req.push_str(&format!("{k}: {v}\r\n"));
        }
        req.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", payload.len()));
        stream.write_all(req.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw)?;
        let raw = String::from_utf8(raw).map_err(|_| anyhow!("non-utf8 response"))?;
        let (head, body) = raw
            .split_once("\r\n\r\n")
            .ok_or_else(|| anyhow!("malformed response: no header terminator"))?;
        let mut lines = head.lines();
        let status_line = lines.next().ok_or_else(|| anyhow!("empty response"))?;
        let status = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| anyhow!("malformed status line {status_line:?}"))?;
        let headers = lines
            .filter_map(|l| l.split_once(':'))
            .map(|(k, v)| (k.trim().to_ascii_lowercase(), v.trim().to_string()))
            .collect();
        Ok((status, headers, body.to_string()))
    }

    pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
        headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Non-streaming generate; returns (status, parsed JSON body).
    pub fn generate(&self, body: &Json, tenant: Option<&str>) -> Result<(u16, Json)> {
        let hdrs: Vec<(&str, &str)> = tenant.map(|t| ("x-tenant", t)).into_iter().collect();
        let (status, _, text) = self.request("POST", "/v1/generate", &hdrs, Some(body))?;
        Ok((status, parse(&text)?))
    }

    /// Streaming generate: parses the SSE frame sequence.  Returns
    /// (status, chunk frames, summary frame).  On a non-200 status the
    /// chunks are empty and the summary is the error body.
    pub fn generate_streaming(
        &self,
        body: &Json,
        tenant: Option<&str>,
    ) -> Result<(u16, Vec<Vec<i32>>, Json)> {
        let hdrs: Vec<(&str, &str)> = tenant.map(|t| ("x-tenant", t)).into_iter().collect();
        let (status, _, text) = self.request("POST", "/v1/generate", &hdrs, Some(body))?;
        if status != 200 {
            return Ok((status, Vec::new(), parse(&text)?));
        }
        let mut chunks = Vec::new();
        let mut summary = None;
        let mut saw_done = false;
        for frame in text.split("\n\n") {
            let Some(data) = frame.trim().strip_prefix("data: ") else { continue };
            if data == "[DONE]" {
                saw_done = true;
                break;
            }
            let v = parse(data)?;
            match v.get("chunk") {
                Some(c) => chunks.push(c.to_i32_vec()?),
                None => summary = Some(v),
            }
        }
        if !saw_done {
            return Err(anyhow!("SSE stream missing the [DONE] sentinel"));
        }
        let summary = summary.ok_or_else(|| anyhow!("SSE stream missing the summary frame"))?;
        Ok((status, chunks, summary))
    }

    /// Streaming generate that reads the SSE frames incrementally and
    /// stamps each chunk frame with the elapsed milliseconds since the
    /// request was written (first stamp = client-observed TTFT), instead
    /// of buffering the whole response to EOF like `generate_streaming`.
    /// On a non-200 status the frames are empty and the summary is the
    /// error body.
    pub fn generate_streaming_timed(
        &self,
        body: &Json,
        tenant: Option<&str>,
    ) -> Result<(u16, Vec<(f64, Vec<i32>)>, Json)> {
        let mut stream = TcpStream::connect(&self.addr)?;
        stream.set_nodelay(true)?;
        let payload = body.to_string();
        let mut req = format!("POST /v1/generate HTTP/1.1\r\nHost: {}\r\n", self.addr);
        if let Some(t) = tenant {
            req.push_str(&format!("x-tenant: {t}\r\n"));
        }
        req.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", payload.len()));
        let t0 = std::time::Instant::now();
        stream.write_all(req.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let status = line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| anyhow!("malformed status line {line:?}"))?;
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                return Err(anyhow!("connection closed mid-headers"));
            }
            if h.trim_end().is_empty() {
                break;
            }
        }
        if status != 200 {
            let mut rest = String::new();
            reader.read_to_string(&mut rest)?;
            return Ok((status, Vec::new(), parse(&rest)?));
        }
        let mut frames = Vec::new();
        let mut summary = None;
        loop {
            let mut l = String::new();
            if reader.read_line(&mut l)? == 0 {
                return Err(anyhow!("SSE stream closed before [DONE]"));
            }
            let Some(data) = l.trim_end().strip_prefix("data: ") else { continue };
            if data == "[DONE]" {
                break;
            }
            let v = parse(data)?;
            match v.get("chunk") {
                Some(c) => frames.push((t0.elapsed().as_secs_f64() * 1e3, c.to_i32_vec()?)),
                None => summary = Some(v),
            }
        }
        let summary = summary.ok_or_else(|| anyhow!("SSE stream missing the summary frame"))?;
        Ok((status, frames, summary))
    }
}
