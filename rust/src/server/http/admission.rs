//! Per-tenant admission control for the HTTP gateway: token-bucket rate
//! limiting plus concurrency quotas.
//!
//! Each tenant gets an independent token bucket (sustained `rps`, burst
//! headroom `burst`) and an in-flight cap.  Over-rate requests are shed
//! with a computed `Retry-After`; over-concurrency requests are shed as
//! busy.  Admission happens before the engine sees the request, so a
//! flooding tenant is stopped at the front door instead of filling the
//! shared scheduler queue -- the weighted-fair scheduler then arbitrates
//! among the requests that *were* admitted.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Per-tenant limits.  Zero means unlimited for each knob independently,
/// so `Quota::default()` admits everything (the single-tenant dev setup).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Quota {
    /// Sustained request rate (requests/second); 0 = unlimited.
    pub rps: f64,
    /// Token-bucket capacity: how many requests may arrive instantaneously
    /// above the sustained rate.  Clamped to >= 1 when `rps` is active.
    pub burst: f64,
    /// Maximum in-flight requests (admitted, not yet finished); 0 =
    /// unlimited.
    pub max_concurrent: usize,
}

/// Outcome of an admission check.
pub enum Admit {
    /// Admitted.  Hold the permit for the request's lifetime; dropping it
    /// releases the concurrency slot.
    Ok(Permit),
    /// Over the rate quota: shed with 429 and this `Retry-After` (seconds,
    /// >= 1 -- the time until the bucket refills one token).
    RetryAfter(u64),
    /// Over the concurrency quota: shed with 503.
    Busy,
}

/// RAII concurrency slot: decrements the tenant's in-flight count on drop,
/// so every exit path (response written, client gone, handler panic)
/// releases exactly once.
pub struct Permit {
    inflight: Arc<AtomicUsize>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

struct TenantState {
    quota: Quota,
    /// Current bucket level, refilled lazily at `rps` tokens/second.
    tokens: f64,
    last_refill: Instant,
    inflight: Arc<AtomicUsize>,
}

impl TenantState {
    fn new(quota: Quota) -> TenantState {
        TenantState {
            quota,
            // start full: a fresh tenant gets its whole burst
            tokens: quota.burst.max(1.0),
            last_refill: Instant::now(),
            inflight: Arc::new(AtomicUsize::new(0)),
        }
    }
}

/// The gateway's admission table: one `TenantState` per tenant name,
/// created on first sight with the default quota unless an override was
/// configured.
pub struct AdmissionControl {
    default_quota: Quota,
    tenants: Mutex<HashMap<String, TenantState>>,
}

impl AdmissionControl {
    pub fn new(default_quota: Quota) -> AdmissionControl {
        AdmissionControl { default_quota, tenants: Mutex::new(HashMap::new()) }
    }

    /// Install (or replace) a tenant-specific quota.  Resets that tenant's
    /// bucket to full; in-flight counts carry over.
    pub fn set_quota(&self, tenant: &str, quota: Quota) {
        let mut map = self.tenants.lock().unwrap();
        match map.get_mut(tenant) {
            Some(st) => {
                st.quota = quota;
                st.tokens = quota.burst.max(1.0);
                st.last_refill = Instant::now();
            }
            None => {
                map.insert(tenant.to_string(), TenantState::new(quota));
            }
        }
    }

    /// Admit or shed one request for `tenant`.  Concurrency is checked
    /// before the bucket so a busy rejection does not burn rate budget.
    pub fn admit(&self, tenant: &str) -> Admit {
        let mut map = self.tenants.lock().unwrap();
        let default_quota = self.default_quota;
        let st = map
            .entry(tenant.to_string())
            .or_insert_with(|| TenantState::new(default_quota));
        if st.quota.max_concurrent > 0
            && st.inflight.load(Ordering::Relaxed) >= st.quota.max_concurrent
        {
            return Admit::Busy;
        }
        if st.quota.rps > 0.0 {
            let now = Instant::now();
            let dt = now.duration_since(st.last_refill).as_secs_f64();
            st.last_refill = now;
            st.tokens = (st.tokens + dt * st.quota.rps).min(st.quota.burst.max(1.0));
            if st.tokens < 1.0 {
                let wait = ((1.0 - st.tokens) / st.quota.rps).ceil().max(1.0);
                // cap at a day so a near-zero rps cannot overflow headers
                return Admit::RetryAfter(wait.min(86_400.0) as u64);
            }
            st.tokens -= 1.0;
        }
        st.inflight.fetch_add(1, Ordering::Relaxed);
        Admit::Ok(Permit { inflight: st.inflight.clone() })
    }

    /// Current in-flight count for a tenant (observability/tests).
    pub fn inflight(&self, tenant: &str) -> usize {
        self.tenants
            .lock()
            .unwrap()
            .get(tenant)
            .map(|st| st.inflight.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_default_always_admits() {
        let ac = AdmissionControl::new(Quota::default());
        let mut permits = Vec::new();
        for _ in 0..100 {
            match ac.admit("t") {
                Admit::Ok(p) => permits.push(p),
                _ => panic!("unlimited quota shed a request"),
            }
        }
        assert_eq!(ac.inflight("t"), 100);
        permits.clear();
        assert_eq!(ac.inflight("t"), 0);
    }

    #[test]
    fn bucket_sheds_after_burst_with_retry_after() {
        let ac = AdmissionControl::new(Quota::default());
        // 1 req/s sustained, burst of 3: requests 1-3 pass, 4 sheds
        ac.set_quota("t", Quota { rps: 1.0, burst: 3.0, max_concurrent: 0 });
        let mut permits = Vec::new();
        for _ in 0..3 {
            match ac.admit("t") {
                Admit::Ok(p) => permits.push(p),
                _ => panic!("burst request shed"),
            }
        }
        match ac.admit("t") {
            Admit::RetryAfter(s) => assert!((1..=2).contains(&s), "retry-after {s}"),
            _ => panic!("over-burst request admitted"),
        }
        // an unrelated tenant is unaffected (independent buckets)
        assert!(matches!(ac.admit("other"), Admit::Ok(_)));
    }

    #[test]
    fn concurrency_cap_sheds_busy_and_permit_release_readmits() {
        let ac = AdmissionControl::new(Quota::default());
        ac.set_quota("t", Quota { rps: 0.0, burst: 0.0, max_concurrent: 2 });
        let p1 = match ac.admit("t") {
            Admit::Ok(p) => p,
            _ => panic!(),
        };
        let _p2 = match ac.admit("t") {
            Admit::Ok(p) => p,
            _ => panic!(),
        };
        assert!(matches!(ac.admit("t"), Admit::Busy));
        drop(p1);
        assert!(matches!(ac.admit("t"), Admit::Ok(_)));
    }

    #[test]
    fn bucket_refills_over_time() {
        let ac = AdmissionControl::new(Quota::default());
        // 50 req/s so the test refills quickly
        ac.set_quota("t", Quota { rps: 50.0, burst: 1.0, max_concurrent: 0 });
        assert!(matches!(ac.admit("t"), Admit::Ok(_)));
        assert!(matches!(ac.admit("t"), Admit::RetryAfter(_)));
        std::thread::sleep(std::time::Duration::from_millis(40));
        assert!(matches!(ac.admit("t"), Admit::Ok(_)), "bucket should refill at 50/s");
    }
}
