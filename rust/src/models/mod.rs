//! High-level model handles over compiled PJRT executables.
//!
//! A `ModelSet` owns the PJRT client plus a lazy cache of compiled entry
//! points (one executable per HLO artifact; weights are baked in, so
//! loading a "model" costs one parse+compile per entry point on first use).
//!
//! `TargetModel` / `DraftModel` expose the serving-level operations the
//! speculative decoder composes:
//!
//!   target:  prefill_mm -> verify(gamma+1) / decode(1)
//!   drafter: prefill_mm | prefill_text -> draft(gamma, fused) / decode(1)
//!
//! KV caches stay opaque `xla::Literal`s between calls -- the coordinator
//! never parses them, it just threads them through (DESIGN.md section 3).

pub mod scripted;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::tensor::to_vec_i32;
use crate::runtime::{lit_f32, lit_i32, scalar_f32, scalar_i32, scalar_u32, Exec, Runtime, Tensor};
use crate::spec::tree::DraftTree;

pub const IMAGE_ELEMS: usize = 16 * 16 * 3;

pub struct ModelSet {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub dir: String,
    execs: Mutex<HashMap<String, Arc<Exec>>>,
}

impl ModelSet {
    pub fn load(artifacts_dir: &str) -> Result<Arc<ModelSet>> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Arc::new(ModelSet {
            rt: Runtime::cpu()?,
            manifest,
            dir: artifacts_dir.to_string(),
            execs: Mutex::new(HashMap::new()),
        }))
    }

    /// Fetch (compiling on first use) the executable for one entry point.
    pub fn exec(&self, entry: &ModelEntry, point: &str) -> Result<Arc<Exec>> {
        let rel = entry
            .entries
            .get(point)
            .ok_or_else(|| anyhow!("model {} has no entry point {point:?}", entry.name))?;
        let key = rel.clone();
        if let Some(e) = self.execs.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        // compile outside the lock (compilation can take hundreds of ms)
        let path = format!("{}/{}", self.dir, rel);
        let name = format!("{}::{}", entry.name, point);
        let exec = Arc::new(self.rt.load_exec(&path, &name)?);
        let mut cache = self.execs.lock().unwrap();
        Ok(cache.entry(key).or_insert(exec).clone())
    }

    pub fn target(self: &Arc<Self>, name: &str) -> Result<TargetModel> {
        let entry = self.manifest.target(name)?.clone();
        Ok(TargetModel { set: self.clone(), entry })
    }

    pub fn drafter(self: &Arc<Self>, name: &str, variant: &str) -> Result<DraftModel> {
        let entry = self.manifest.drafter(name, variant)?.clone();
        Ok(DraftModel { set: self.clone(), entry })
    }

    pub fn drafter_for(self: &Arc<Self>, target: &str, variant: &str) -> Result<DraftModel> {
        let entry = self.manifest.drafter_for_target(target, variant)?.clone();
        Ok(DraftModel { set: self.clone(), entry })
    }

    /// Per-executable latency table (name, calls, mean micros) for metrics.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        self.execs
            .lock()
            .unwrap()
            .values()
            .map(|e| (e.name.clone(), e.call_count(), e.mean_micros()))
            .collect()
    }
}

/// Per-sequence decoding state: an opaque device-format KV cache plus the
/// absolute position where the next token will be written.  Under the
/// scripted backend `pos` is the stream index and `script` carries the
/// deterministic token lines; PJRT states leave `script` as `None`.
pub struct SeqState {
    pub kv: xla::Literal,
    pub pos: i32,
    pub script: Option<Arc<scripted::ScriptSet>>,
}

fn prompt_literal(prompt: &[i32], p_max: usize) -> Result<xla::Literal> {
    if prompt.len() != p_max {
        return Err(anyhow!("prompt must be padded to {p_max}, got {}", prompt.len()));
    }
    lit_i32(prompt, &[p_max])
}

#[derive(Clone)]
pub struct TargetModel {
    pub set: Arc<ModelSet>,
    pub entry: ModelEntry,
}

impl TargetModel {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn vocab(&self) -> usize {
        self.entry.vocab
    }

    fn is_scripted(&self) -> bool {
        self.set.manifest.backend == "scripted"
    }

    /// Multimodal prefill.  Returns last-position logits and the sequence
    /// state positioned at the first generation slot.
    pub fn prefill_mm(&self, image: &[f32], prompt: &[i32], len: usize) -> Result<(Vec<f32>, SeqState)> {
        if image.len() != IMAGE_ELEMS {
            return Err(anyhow!("image must have {IMAGE_ELEMS} elems, got {}", image.len()));
        }
        let m = &self.set.manifest;
        if self.is_scripted() {
            return scripted::prefill_target(m, self.entry.vocab, image, prompt, len);
        }
        let exec = self.set.exec(&self.entry, "prefill_mm")?;
        let out = exec.call(&[
            lit_f32(image, &[16, 16, 3])?,
            prompt_literal(prompt, m.p_max)?,
            scalar_i32(len as i32),
        ])?;
        let logits = crate::runtime::to_vec_f32(&out[0])?;
        let kv = out.into_iter().nth(1).unwrap();
        Ok((logits, SeqState { kv, pos: (m.n_visual + len) as i32, script: None }))
    }

    /// Verify gamma+1 tokens written at `state.pos`.  Returns per-position
    /// logits [(gamma+1) x V]; the caller advances `state.pos` by the
    /// number of tokens actually accepted (stale tail is position-masked).
    pub fn verify(&self, state: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        let gamma1 = self.set.manifest.gamma + 1;
        if tokens.len() != gamma1 {
            return Err(anyhow!("verify expects {gamma1} tokens, got {}", tokens.len()));
        }
        if self.is_scripted() {
            return scripted::verify_target(self.entry.vocab, state, tokens);
        }
        let exec = self.set.exec(&self.entry, "verify")?;
        let out = exec.call(&[
            lit_i32(tokens, &[gamma1])?,
            scalar_i32(state.pos),
            state.kv.clone(),
        ])?;
        let logits = Tensor::new(
            crate::runtime::to_vec_f32(&out[0])?,
            vec![gamma1, self.entry.vocab],
        )?;
        state.kv = out.into_iter().nth(1).unwrap();
        Ok(logits)
    }

    /// Flattened tree verification (one forward pass for a whole draft
    /// tree).  Scripted states answer per node positionally; the PJRT path
    /// linearizes chain-shaped trees through the fixed verify window (see
    /// `spec::decoder::verify_tree_linearized`).
    pub fn verify_tree(
        &self,
        state: &mut SeqState,
        last: i32,
        tree: &DraftTree,
        gamma: usize,
    ) -> Result<Tensor> {
        if self.is_scripted() {
            return scripted::verify_tree_target(self.entry.vocab, state, tree);
        }
        crate::spec::decoder::verify_tree_linearized(self, state, last, tree, gamma)
    }

    /// Single-token decode (non-speculative baseline path).  Writes the
    /// token at `state.pos` and advances it.
    pub fn decode(&self, state: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        if self.is_scripted() {
            return scripted::decode_target(self.entry.vocab, state);
        }
        let exec = self.set.exec(&self.entry, "decode")?;
        let out = exec.call(&[
            lit_i32(&[token], &[1])?,
            scalar_i32(state.pos),
            state.kv.clone(),
        ])?;
        let logits = crate::runtime::to_vec_f32(&out[0])?;
        state.kv = out.into_iter().nth(1).unwrap();
        state.pos += 1;
        Ok(logits)
    }
}

/// Tokens + raw q-logits produced by one fused draft call.
pub struct DraftOutput {
    pub tokens: Vec<i32>,
    /// [gamma x V] raw logits; q_i = softmax(logits_i / T).
    pub qlogits: Tensor,
}

#[derive(Clone)]
pub struct DraftModel {
    pub set: Arc<ModelSet>,
    pub entry: ModelEntry,
}

impl DraftModel {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn variant(&self) -> &str {
        self.entry.variant.as_deref().unwrap_or("?")
    }

    pub fn is_multimodal(&self) -> bool {
        self.entry.multimodal
    }

    fn is_scripted(&self) -> bool {
        self.set.manifest.backend == "scripted"
    }

    /// Drafter prefill.  Multimodal drafters consume the image unless
    /// `text_only` (Table-3 mode: visual tokens discarded); the baseline
    /// drafter has no multimodal entry point at all.
    pub fn prefill(
        &self,
        image: Option<&[f32]>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
    ) -> Result<SeqState> {
        let m = &self.set.manifest;
        if self.is_scripted() {
            return scripted::prefill_drafter(
                m,
                self.variant(),
                self.entry.multimodal,
                image,
                prompt,
                len,
                text_only,
            );
        }
        let prompt_lit = prompt_literal(prompt, m.p_max)?;
        if self.entry.multimodal && !text_only {
            let image = image.ok_or_else(|| anyhow!("multimodal drafter needs an image"))?;
            let exec = self.set.exec(&self.entry, "prefill_mm")?;
            let out = exec.call(&[
                lit_f32(image, &[16, 16, 3])?,
                prompt_lit,
                scalar_i32(len as i32),
            ])?;
            let kv = out.into_iter().nth(1).unwrap();
            Ok(SeqState { kv, pos: (m.n_visual + len) as i32, script: None })
        } else {
            let exec = self.set.exec(&self.entry, "prefill_text")?;
            let out = exec.call(&[prompt_lit, scalar_i32(len as i32)])?;
            let kv = out.into_iter().nth(1).unwrap();
            Ok(SeqState { kv, pos: len as i32, script: None })
        }
    }

    /// Fused on-device draft loop: writes `last` at `state.pos`, samples
    /// gamma tokens at `temperature` (gumbel-max; T=0 == argmax), returns
    /// them with their raw q-logits.  Advances pos past `last` only -- the
    /// caller advances further by the accepted count.
    pub fn draft(
        &self,
        state: &mut SeqState,
        last: i32,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftOutput> {
        let gamma = self.set.manifest.gamma;
        if self.is_scripted() {
            let _ = (last, temperature, seed);
            let (tokens, qlogits) = scripted::draft_drafter(self.entry.vocab, gamma, state)?;
            return Ok(DraftOutput { tokens, qlogits });
        }
        let exec = self.set.exec(&self.entry, "draft")?;
        let out = exec.call(&[
            scalar_i32(last),
            scalar_i32(state.pos),
            state.kv.clone(),
            scalar_f32(temperature),
            scalar_u32(seed),
        ])?;
        let tokens = to_vec_i32(&out[0])?;
        let qlogits = Tensor::new(
            crate::runtime::to_vec_f32(&out[1])?,
            vec![gamma, self.entry.vocab],
        )?;
        state.kv = out.into_iter().nth(2).unwrap();
        Ok(DraftOutput { tokens, qlogits })
    }

    /// Draft a token tree from `last`: the scripted backend branches over
    /// its candidate lines; the PJRT path degenerates to the fused chain.
    pub fn draft_tree(
        &self,
        state: &mut SeqState,
        last: i32,
        cfg: &crate::spec::tree::TreeConfig,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftTree> {
        if self.is_scripted() {
            let _ = (last, temperature, seed);
            return scripted::draft_tree_drafter(self.entry.vocab, cfg, state);
        }
        crate::spec::decoder::draft_tree_via_chain(self, state, last, cfg, temperature, seed)
    }

    /// Step-wise decode (reference path + TVD distribution analysis).
    pub fn decode(&self, state: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        if self.is_scripted() {
            let _ = token;
            let (_, q) = scripted::draft_drafter(self.entry.vocab, 1, state)?;
            state.pos += 1;
            return Ok(q.data);
        }
        let exec = self.set.exec(&self.entry, "decode")?;
        let out = exec.call(&[
            lit_i32(&[token], &[1])?,
            scalar_i32(state.pos),
            state.kv.clone(),
        ])?;
        let logits = crate::runtime::to_vec_f32(&out[0])?;
        state.kv = out.into_iter().nth(1).unwrap();
        state.pos += 1;
        Ok(logits)
    }
}
