//! High-level model handles over compiled PJRT executables.
//!
//! A `ModelSet` owns the PJRT client plus a lazy cache of compiled entry
//! points (one executable per HLO artifact; weights are baked in, so
//! loading a "model" costs one parse+compile per entry point on first use).
//!
//! `TargetModel` / `DraftModel` expose the serving-level operations the
//! speculative decoder composes:
//!
//!   target:  encode_image -> prefill_encoded -> verify(gamma+1) / decode(1)
//!   drafter: prefill_encoded | prefill_text -> draft(gamma, fused) / decode(1)
//!
//! Prefill is split into two stages so the prefix cache (`crate::cache`)
//! can reuse work across requests:
//!
//!   * `encode_image` produces a `VisionEncoding` -- the content-addressed,
//!     prompt-independent part of multimodal prefill (the vision tower +
//!     projector in a real VLM; the image's stream-seed contribution under
//!     the scripted backend).  One encoding serves every prompt over the
//!     same image, for both target and drafter.
//!   * `prefill_encoded` consumes an encoding plus the prompt and builds
//!     the post-prefill `SeqState`.  `prefill_mm` remains as the fused
//!     convenience (encode + prefill in one call).
//!
//! `SeqState::fork` snapshots a sequence state for the cache: a warm
//! request resumes from a fork of the cached post-prefill state instead of
//! re-running either stage (`prefill_from`).  `SeqState::bytes` gives the
//! size accounting the cache's byte budget is enforced against.
//!
//! KV caches stay opaque between calls -- the coordinator never parses
//! them, it just threads them through (DESIGN.md section 3).  The slot is
//! a `kv::KvBacking`: an owned `xla::Literal` by default, or a block
//! table into the engine's paged pool once `SeqState::paginate` moves it
//! there -- after which `fork` is a per-block refcount bump instead of a
//! deep copy (see `docs/paged_kv.md`).

pub mod scripted;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::kv::{KvBacking, KvPool};
use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::tensor::to_vec_i32;
use crate::runtime::{lit_f32, lit_i32, scalar_f32, scalar_i32, scalar_u32, Exec, Runtime, Tensor};
use crate::spec::tree::DraftTree;

/// Default raw-image element count (16x16x3); the runtime checks request
/// images against `Manifest::image_elems()`, which falls back to this for
/// manifests that predate the `image_shape` field.
pub const IMAGE_ELEMS: usize = 16 * 16 * 3;

pub struct ModelSet {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub dir: String,
    execs: Mutex<HashMap<String, Arc<Exec>>>,
}

impl ModelSet {
    pub fn load(artifacts_dir: &str) -> Result<Arc<ModelSet>> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Arc::new(ModelSet {
            rt: Runtime::cpu()?,
            manifest,
            dir: artifacts_dir.to_string(),
            execs: Mutex::new(HashMap::new()),
        }))
    }

    /// Fetch (compiling on first use) the executable for one entry point.
    pub fn exec(&self, entry: &ModelEntry, point: &str) -> Result<Arc<Exec>> {
        let rel = entry
            .entries
            .get(point)
            .ok_or_else(|| anyhow!("model {} has no entry point {point:?}", entry.name))?;
        let key = rel.clone();
        if let Some(e) = self.execs.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        // compile outside the lock (compilation can take hundreds of ms)
        let path = format!("{}/{}", self.dir, rel);
        let name = format!("{}::{}", entry.name, point);
        let exec = Arc::new(self.rt.load_exec(&path, &name)?);
        let mut cache = self.execs.lock().unwrap();
        Ok(cache.entry(key).or_insert(exec).clone())
    }

    pub fn target(self: &Arc<Self>, name: &str) -> Result<TargetModel> {
        let entry = self.manifest.target(name)?.clone();
        Ok(TargetModel { set: self.clone(), entry })
    }

    pub fn drafter(self: &Arc<Self>, name: &str, variant: &str) -> Result<DraftModel> {
        let entry = self.manifest.drafter(name, variant)?.clone();
        Ok(DraftModel { set: self.clone(), entry })
    }

    pub fn drafter_for(self: &Arc<Self>, target: &str, variant: &str) -> Result<DraftModel> {
        let entry = self.manifest.drafter_for_target(target, variant)?.clone();
        Ok(DraftModel { set: self.clone(), entry })
    }

    /// Per-executable latency table (name, calls, mean micros) for metrics.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        self.execs
            .lock()
            .unwrap()
            .values()
            .map(|e| (e.name.clone(), e.call_count(), e.mean_micros()))
            .collect()
    }
}

/// Destructure an executable's output tuple, erroring -- with the entry
/// point named -- when the artifact returns a different arity than the
/// entry point's contract promises (previously a panic via `nth().unwrap()`).
fn expect_outputs<const N: usize>(
    out: Vec<xla::Literal>,
    entry: &str,
) -> Result<[xla::Literal; N]> {
    let got = out.len();
    <[xla::Literal; N]>::try_from(out)
        .map_err(|_| anyhow!("{entry}: expected {N} outputs from the compiled artifact, got {got}"))
}

/// The reusable, prompt-independent product of multimodal prefill stage 1:
/// what a vision tower + projector emits for one image.  Content-addressed
/// by image hash in `crate::cache`, shared by target and drafter.
#[derive(Debug, Clone)]
pub enum VisionEncoding {
    /// Scripted backend: the image's FNV contribution to the deterministic
    /// stream seed (`models::scripted::image_seed`) -- the scripted
    /// stand-in for "projected vision embeddings".
    Scripted { image_seed: u64 },
    /// Backends without a separate encode entry point (the fused PJRT
    /// prefill executables, mock backends): the raw pixels, carried
    /// through to the fused prefill call.  Nothing but the bytes is
    /// reused, which is still what the `image_id` protocol saves on the
    /// wire.
    Raw(Arc<Vec<f32>>),
}

impl VisionEncoding {
    pub fn raw(image: &[f32]) -> VisionEncoding {
        VisionEncoding::Raw(Arc::new(image.to_vec()))
    }

    /// Raw pixels, when this encoding carries them.
    pub fn pixels(&self) -> Option<&[f32]> {
        match self {
            VisionEncoding::Raw(px) => Some(px),
            VisionEncoding::Scripted { .. } => None,
        }
    }

    /// The scripted stream-seed contribution (computed from pixels for raw
    /// encodings, so the scripted backend accepts either form).
    pub fn scripted_seed(&self) -> u64 {
        match self {
            VisionEncoding::Scripted { image_seed } => *image_seed,
            VisionEncoding::Raw(px) => scripted::image_seed(px),
        }
    }

    /// Size accounting for the cache byte budget.
    pub fn bytes(&self) -> usize {
        match self {
            VisionEncoding::Scripted { .. } => 8,
            VisionEncoding::Raw(px) => px.len() * 4,
        }
    }

    /// Drafter-side compressed view of a raw encoding: blockwise mean
    /// pooling at `ratio`, each block's mean replicated back over the
    /// block so the buffer keeps the fixed shape the PJRT prefill
    /// executables expect (compression reduces information, not dims).
    /// Ratio 1 shares the original pixels (no copy).  `None` for
    /// scripted encodings (their compression lives in
    /// `scripted::pooled_vision_digest`).
    pub fn pooled_pixels(&self, ratio: u32) -> Option<Arc<Vec<f32>>> {
        match self {
            VisionEncoding::Raw(px) => {
                let r = ratio.max(1) as usize;
                if r == 1 {
                    return Some(px.clone());
                }
                let mut out = Vec::with_capacity(px.len());
                for chunk in px.chunks(r) {
                    let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
                    for _ in 0..chunk.len() {
                        out.push(mean);
                    }
                }
                Some(Arc::new(out))
            }
            VisionEncoding::Scripted { .. } => None,
        }
    }
}

/// Heap bytes behind one opaque KV literal (cache size accounting).
pub(crate) fn literal_bytes(l: &xla::Literal) -> usize {
    match l {
        xla::Literal::Array { data, dims } => {
            let elems = match data {
                xla::LiteralData::F32(v) => v.len(),
                xla::LiteralData::I32(v) => v.len(),
                xla::LiteralData::U32(v) => v.len(),
            };
            elems * 4 + dims.len() * 8
        }
        xla::Literal::Tuple(parts) => parts.iter().map(literal_bytes).sum(),
    }
}

/// Per-sequence decoding state: an opaque device-format KV cache plus the
/// absolute position where the next token will be written.  Under the
/// scripted backend `pos` is the stream index and `script` carries the
/// deterministic token lines; PJRT states leave `script` as `None`.
pub struct SeqState {
    pub kv: KvBacking,
    pub pos: i32,
    pub script: Option<Arc<scripted::ScriptSet>>,
}

impl SeqState {
    /// Fresh post-prefill state over an owned KV literal (the form every
    /// backend produces; `paginate` moves it into a pool afterwards).
    pub fn new(kv: xla::Literal, pos: i32, script: Option<Arc<scripted::ScriptSet>>) -> SeqState {
        SeqState { kv: KvBacking::Owned(kv), pos, script }
    }

    /// Snapshot this state so two sequences can continue independently
    /// (the prefix cache stores post-prefill forks; every warm request
    /// forks again; tree branches fork per divergence).  Owned KV literals
    /// deep-copy; paged tables bump per-block refcounts -- O(table), no
    /// payload copy -- and diverge lazily via copy-on-write.
    pub fn fork(&self) -> SeqState {
        SeqState { kv: self.kv.clone(), pos: self.pos, script: self.script.clone() }
    }

    /// Move the KV into a paged pool (no-op when already paged).  From
    /// here on `fork` is a refcount bump and divergent writes copy only
    /// the blocks they touch.
    pub fn paginate(&mut self, pool: &Arc<KvPool>) {
        self.kv.paginate(pool);
    }

    /// Approximate heap size of this state, for the cache byte budget.
    /// The script is `Arc`-shared between forks but counted in full: the
    /// cache holds the longest-lived reference, so its budget should bear
    /// the content.  Paged KV charges only the block-table handle here --
    /// block content is accounted once on the pool gauge (`kv_pool_bytes`),
    /// shared across every fork.
    pub fn bytes(&self) -> usize {
        let script = self.script.as_ref().map_or(0, |s| {
            (s.primary.len() + s.alts.iter().map(Vec::len).sum::<usize>()) * 4
        });
        self.kv.bytes() + script + std::mem::size_of::<SeqState>()
    }
}

/// Forkable post-prefill snapshot of everything a warm start needs: the
/// target's last-position prefill logits plus both models' sequence
/// states, taken *before* the first token is sampled (so per-request
/// sampling config stays out of the cache key).
pub struct PrefixSnapshot {
    pub last_logits: Vec<f32>,
    pub tstate: SeqState,
    /// `None` for target-only prefixes (no drafter state was built).
    pub dstate: Option<SeqState>,
}

impl PrefixSnapshot {
    /// Size accounting for the cache byte budget.
    pub fn bytes(&self) -> usize {
        self.last_logits.len() * 4
            + self.tstate.bytes()
            + self.dstate.as_ref().map_or(0, SeqState::bytes)
    }
}

fn prompt_literal(prompt: &[i32], p_max: usize) -> Result<xla::Literal> {
    if prompt.len() != p_max {
        return Err(anyhow!("prompt must be padded to {p_max}, got {}", prompt.len()));
    }
    lit_i32(prompt, &[p_max])
}

fn check_image(m: &Manifest, image: &[f32]) -> Result<()> {
    if image.len() != m.image_elems() {
        return Err(anyhow!(
            "image must have {} elems (shape {:?}), got {}",
            m.image_elems(),
            m.image_shape,
            image.len()
        ));
    }
    Ok(())
}

#[derive(Clone)]
pub struct TargetModel {
    pub set: Arc<ModelSet>,
    pub entry: ModelEntry,
}

impl TargetModel {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn vocab(&self) -> usize {
        self.entry.vocab
    }

    fn is_scripted(&self) -> bool {
        self.set.manifest.backend == "scripted"
    }

    /// Prefill stage 1: the prompt-independent image encode.  Cacheable by
    /// image content hash and shared with the drafter.
    pub fn encode_image(&self, image: &[f32]) -> Result<VisionEncoding> {
        check_image(&self.set.manifest, image)?;
        if self.is_scripted() {
            return Ok(VisionEncoding::Scripted { image_seed: scripted::image_seed(image) });
        }
        // the fused PJRT prefill executables have no separate vision-tower
        // entry point: carry the pixels through to the fused call
        Ok(VisionEncoding::raw(image))
    }

    /// Prefill stage 2: build the post-prefill state from an encoding.
    /// Returns last-position logits and the sequence state positioned at
    /// the first generation slot.
    pub fn prefill_encoded(
        &self,
        enc: &VisionEncoding,
        prompt: &[i32],
        len: usize,
    ) -> Result<(Vec<f32>, SeqState)> {
        let m = &self.set.manifest;
        if self.is_scripted() {
            return scripted::prefill_target_seeded(
                m,
                self.entry.vocab,
                enc.scripted_seed(),
                prompt,
                len,
            );
        }
        let image = enc.pixels().ok_or_else(|| {
            anyhow!("target {}: PJRT prefill needs a raw vision encoding", self.entry.name)
        })?;
        let exec = self.set.exec(&self.entry, "prefill_mm")?;
        let out = exec.call(&[
            lit_f32(image, &m.image_shape)?,
            prompt_literal(prompt, m.p_max)?,
            scalar_i32(len as i32),
        ])?;
        let [logits, kv] = expect_outputs::<2>(out, "target::prefill_mm")?;
        let logits = crate::runtime::to_vec_f32(&logits)?;
        Ok((logits, SeqState::new(kv, (m.n_visual + len) as i32, None)))
    }

    /// Fused multimodal prefill (stage 1 + stage 2 in one call; the
    /// cold-path convenience the eval harness and benches use).
    pub fn prefill_mm(&self, image: &[f32], prompt: &[i32], len: usize) -> Result<(Vec<f32>, SeqState)> {
        let enc = self.encode_image(image)?;
        self.prefill_encoded(&enc, prompt, len)
    }

    /// Warm-start a sequence from a cached post-prefill prefix: the fork
    /// *is* the whole operation (KV snapshots are immutable between calls),
    /// so a warm prefill costs one state copy instead of a forward pass.
    pub fn prefill_from(&self, prefix: &SeqState) -> SeqState {
        prefix.fork()
    }

    /// Verify gamma+1 tokens written at `state.pos`.  Returns per-position
    /// logits [(gamma+1) x V]; the caller advances `state.pos` by the
    /// number of tokens actually accepted (stale tail is position-masked).
    pub fn verify(&self, state: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        let gamma1 = self.set.manifest.gamma + 1;
        if tokens.len() != gamma1 {
            return Err(anyhow!("verify expects {gamma1} tokens, got {}", tokens.len()));
        }
        if self.is_scripted() {
            return scripted::verify_target(self.entry.vocab, state, tokens);
        }
        let exec = self.set.exec(&self.entry, "verify")?;
        let out = exec.call(&[
            lit_i32(tokens, &[gamma1])?,
            scalar_i32(state.pos),
            state.kv.literal(),
        ])?;
        let [logits, kv] = expect_outputs::<2>(out, "target::verify")?;
        let logits = Tensor::new(
            crate::runtime::to_vec_f32(&logits)?,
            vec![gamma1, self.entry.vocab],
        )?;
        state.kv.set(kv);
        Ok(logits)
    }

    /// Flattened tree verification (one forward pass for a whole draft
    /// tree).  Scripted states answer per node positionally; the PJRT path
    /// linearizes chain-shaped trees through the fixed verify window (see
    /// `spec::decoder::verify_tree_linearized`).
    pub fn verify_tree(
        &self,
        state: &mut SeqState,
        last: i32,
        tree: &DraftTree,
        gamma: usize,
    ) -> Result<Tensor> {
        if self.is_scripted() {
            return scripted::verify_tree_target(self.entry.vocab, state, tree);
        }
        crate::spec::decoder::verify_tree_linearized(self, state, last, tree, gamma)
    }

    /// Single-token decode (non-speculative baseline path).  Writes the
    /// token at `state.pos` and advances it.
    pub fn decode(&self, state: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        if self.is_scripted() {
            return scripted::decode_target(self.entry.vocab, state);
        }
        let exec = self.set.exec(&self.entry, "decode")?;
        let out = exec.call(&[
            lit_i32(&[token], &[1])?,
            scalar_i32(state.pos),
            state.kv.literal(),
        ])?;
        let [logits, kv] = expect_outputs::<2>(out, "target::decode")?;
        let logits = crate::runtime::to_vec_f32(&logits)?;
        state.kv.set(kv);
        state.pos += 1;
        Ok(logits)
    }

    /// Cross-request batched decode.  The scripted backend computes each
    /// lane from its own per-sequence script state, in lane order, so lane
    /// order cannot leak between requests; PJRT packs along a batch axis
    /// when the artifact exports a `decode_batch` entry point and falls
    /// back to per-lane calls otherwise.  Per-lane `Result`s isolate one
    /// faulty lane from the rest of the batch.
    pub fn decode_batch(&self, lanes: &mut [(&mut SeqState, i32)]) -> Vec<Result<Vec<f32>>> {
        if self.is_scripted() || !self.entry.entries.contains_key("decode_batch") || lanes.len() < 2
        {
            return lanes.iter_mut().map(|(st, tok)| self.decode(st, *tok)).collect();
        }
        match self.decode_batch_packed(lanes) {
            Ok(rows) => rows.into_iter().map(Ok).collect(),
            Err(e) => {
                // the packed path validates every output before mutating any
                // lane, so a fused-call failure can retry per-lane: only the
                // genuinely faulty lane errors, the rest of the gang proceeds
                log::warn!("target::decode_batch packed call failed ({e:#}); retrying per-lane");
                lanes.iter_mut().map(|(st, tok)| self.decode(st, *tok)).collect()
            }
        }
    }

    /// PJRT packed decode: tokens [B], positions [B], KVs as a tuple.
    fn decode_batch_packed(&self, lanes: &mut [(&mut SeqState, i32)]) -> Result<Vec<Vec<f32>>> {
        let b = lanes.len();
        let exec = self.set.exec(&self.entry, "decode_batch")?;
        let tokens: Vec<i32> = lanes.iter().map(|(_, t)| *t).collect();
        let positions: Vec<i32> = lanes.iter().map(|(st, _)| st.pos).collect();
        let kvs = xla::Literal::Tuple(lanes.iter().map(|(st, _)| st.kv.literal()).collect());
        let out = exec.call(&[lit_i32(&tokens, &[b])?, lit_i32(&positions, &[b])?, kvs])?;
        let [logits, kvs] = expect_outputs::<2>(out, "target::decode_batch")?;
        let rows = unpack_rows(&logits, b, self.entry.vocab, "target::decode_batch")?;
        scatter_kvs(lanes.iter_mut().map(|(st, _)| &mut **st), kvs, "target::decode_batch")?;
        for (st, _) in lanes.iter_mut() {
            st.pos += 1;
        }
        Ok(rows)
    }

    /// Cross-request batched verification (see `decode_batch` for the
    /// lane-isolation and fallback contract).  Positions are not advanced
    /// (same contract as `verify`).
    pub fn verify_batch(&self, lanes: &mut [(&mut SeqState, &[i32])]) -> Vec<Result<Tensor>> {
        let uniform = lanes
            .windows(2)
            .all(|w| w[0].1.len() == w[1].1.len());
        if self.is_scripted()
            || !self.entry.entries.contains_key("verify_batch")
            || lanes.len() < 2
            || !uniform
        {
            return lanes.iter_mut().map(|(st, toks)| self.verify(st, *toks)).collect();
        }
        match self.verify_batch_packed(lanes) {
            Ok(rows) => rows.into_iter().map(Ok).collect(),
            Err(e) => {
                // no lane state was mutated (outputs validate before the KV
                // scatter), so per-lane retry isolates the faulty lane
                log::warn!("target::verify_batch packed call failed ({e:#}); retrying per-lane");
                lanes.iter_mut().map(|(st, toks)| self.verify(st, *toks)).collect()
            }
        }
    }

    /// PJRT packed verify: tokens [B x (gamma+1)], positions [B], KV tuple;
    /// returns per-lane [(gamma+1) x V] logits.
    fn verify_batch_packed(&self, lanes: &mut [(&mut SeqState, &[i32])]) -> Result<Vec<Tensor>> {
        let b = lanes.len();
        let w = lanes[0].1.len();
        let exec = self.set.exec(&self.entry, "verify_batch")?;
        let tokens: Vec<i32> = lanes.iter().flat_map(|(_, t)| t.iter().copied()).collect();
        let positions: Vec<i32> = lanes.iter().map(|(st, _)| st.pos).collect();
        let kvs = xla::Literal::Tuple(lanes.iter().map(|(st, _)| st.kv.literal()).collect());
        let out = exec.call(&[lit_i32(&tokens, &[b, w])?, lit_i32(&positions, &[b])?, kvs])?;
        let [logits, kvs] = expect_outputs::<2>(out, "target::verify_batch")?;
        let v = self.entry.vocab;
        let flat = crate::runtime::to_vec_f32(&logits)?;
        if flat.len() != b * w * v {
            return Err(anyhow!(
                "target::verify_batch: expected {b}x{w}x{v} logits, got {} values",
                flat.len()
            ));
        }
        // build every fallible output BEFORE the KV scatter: lane state
        // must stay untouched on any Err so the caller's per-lane retry
        // cannot double-apply the pass
        let rows: Vec<Tensor> = flat
            .chunks(w * v)
            .map(|c| Tensor::new(c.to_vec(), vec![w, v]))
            .collect::<Result<_>>()?;
        scatter_kvs(lanes.iter_mut().map(|(st, _)| &mut **st), kvs, "target::verify_batch")?;
        Ok(rows)
    }

    /// Cross-request batched tree verification.  Always per-lane: tree
    /// linearization is lane-specific, and no batched tree-attention entry
    /// point exists in the artifact schema yet.
    pub fn verify_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &DraftTree)],
        gamma: usize,
    ) -> Vec<Result<Tensor>> {
        lanes
            .iter_mut()
            .map(|(st, last, tree)| self.verify_tree(st, *last, *tree, gamma))
            .collect()
    }
}

/// Scatter a returned KV tuple back onto the lanes of a packed batch
/// call.  Packed paths must call this only after validating every other
/// output: once the scatter runs, lane state is committed, so the
/// caller's per-lane fallback on error stays safe (no double-apply).
fn scatter_kvs<'a>(
    states: impl ExactSizeIterator<Item = &'a mut SeqState>,
    kvs: xla::Literal,
    entry: &str,
) -> Result<()> {
    let n = states.len();
    let xla::Literal::Tuple(parts) = kvs else {
        return Err(anyhow!("{entry}: expected a KV tuple output"));
    };
    if parts.len() != n {
        return Err(anyhow!("{entry}: expected {n} KV parts, got {}", parts.len()));
    }
    for (st, kv) in states.zip(parts) {
        st.kv.set(kv);
    }
    Ok(())
}

/// Split a packed [B x V] logits literal into per-lane rows.
fn unpack_rows(
    logits: &xla::Literal,
    b: usize,
    vocab: usize,
    entry: &str,
) -> Result<Vec<Vec<f32>>> {
    let flat = crate::runtime::to_vec_f32(logits)?;
    if flat.len() != b * vocab {
        return Err(anyhow!(
            "{entry}: expected {b}x{vocab} logits, got {} values",
            flat.len()
        ));
    }
    Ok(flat.chunks(vocab).map(|c| c.to_vec()).collect())
}

/// Tokens + raw q-logits produced by one fused draft call.
pub struct DraftOutput {
    pub tokens: Vec<i32>,
    /// [gamma x V] raw logits; q_i = softmax(logits_i / T).
    pub qlogits: Tensor,
}

#[derive(Clone)]
pub struct DraftModel {
    pub set: Arc<ModelSet>,
    pub entry: ModelEntry,
}

impl DraftModel {
    pub fn name(&self) -> &str {
        &self.entry.name
    }

    pub fn variant(&self) -> &str {
        self.entry.variant.as_deref().unwrap_or("?")
    }

    pub fn is_multimodal(&self) -> bool {
        self.entry.multimodal
    }

    fn is_scripted(&self) -> bool {
        self.set.manifest.backend == "scripted"
    }

    /// Drafter prefill from a shared vision encoding (stage 2; stage 1 is
    /// the target's `encode_image`, reused here).  Multimodal drafters
    /// consume the encoding unless `text_only` (Table-3 mode: visual
    /// tokens discarded); the baseline drafter has no multimodal entry
    /// point at all.  `vision_ratio` is the drafter-side vision token
    /// compression knob (1 = full resolution, bit-identical to the
    /// pre-compression path): the scripted backend walks a pooled vision
    /// sequence of `n_visual / ratio` tokens, the PJRT path feeds
    /// blockwise mean-pooled pixels through the fixed-shape prefill.  The
    /// target never sees the ratio, so outputs stay lossless.
    pub fn prefill_encoded(
        &self,
        enc: Option<&VisionEncoding>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
        vision_ratio: u32,
    ) -> Result<SeqState> {
        let m = &self.set.manifest;
        if self.is_scripted() {
            return scripted::prefill_drafter_seeded(
                m,
                self.variant(),
                self.entry.multimodal,
                enc.map(VisionEncoding::scripted_seed),
                prompt,
                len,
                text_only,
                vision_ratio,
            );
        }
        let prompt_lit = prompt_literal(prompt, m.p_max)?;
        if self.entry.multimodal && !text_only {
            let enc = enc.ok_or_else(|| anyhow!("multimodal drafter needs an image"))?;
            let image = enc.pooled_pixels(vision_ratio).ok_or_else(|| {
                anyhow!("drafter {}: PJRT prefill needs a raw vision encoding", self.entry.name)
            })?;
            let exec = self.set.exec(&self.entry, "prefill_mm")?;
            let out = exec.call(&[
                lit_f32(&image, &m.image_shape)?,
                prompt_lit,
                scalar_i32(len as i32),
            ])?;
            // drafter prefills return (logits, kv); the logits are unused
            // (the first draft call starts from the target's token)
            let [_logits, kv] = expect_outputs::<2>(out, "drafter::prefill_mm")?;
            Ok(SeqState::new(kv, (m.n_visual + len) as i32, None))
        } else {
            let exec = self.set.exec(&self.entry, "prefill_text")?;
            let out = exec.call(&[prompt_lit, scalar_i32(len as i32)])?;
            let [_logits, kv] = expect_outputs::<2>(out, "drafter::prefill_text")?;
            Ok(SeqState::new(kv, len as i32, None))
        }
    }

    /// Fused drafter prefill over raw pixels (cold-path convenience;
    /// always full vision resolution).
    pub fn prefill(
        &self,
        image: Option<&[f32]>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
    ) -> Result<SeqState> {
        let enc = match image {
            Some(px) => {
                check_image(&self.set.manifest, px)?;
                Some(if self.is_scripted() {
                    VisionEncoding::Scripted { image_seed: scripted::image_seed(px) }
                } else {
                    VisionEncoding::raw(px)
                })
            }
            None => None,
        };
        self.prefill_encoded(enc.as_ref(), prompt, len, text_only, 1)
    }

    /// Warm-start from a cached post-prefill prefix (see
    /// `TargetModel::prefill_from`).
    pub fn prefill_from(&self, prefix: &SeqState) -> SeqState {
        prefix.fork()
    }

    /// Fused on-device draft loop: writes `last` at `state.pos`, samples
    /// gamma tokens at `temperature` (gumbel-max; T=0 == argmax), returns
    /// them with their raw q-logits.  Advances pos past `last` only -- the
    /// caller advances further by the accepted count.
    pub fn draft(
        &self,
        state: &mut SeqState,
        last: i32,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftOutput> {
        let gamma = self.set.manifest.gamma;
        if self.is_scripted() {
            let _ = (last, temperature, seed);
            let (tokens, qlogits) = scripted::draft_drafter(self.entry.vocab, gamma, state)?;
            return Ok(DraftOutput { tokens, qlogits });
        }
        let exec = self.set.exec(&self.entry, "draft")?;
        let out = exec.call(&[
            scalar_i32(last),
            scalar_i32(state.pos),
            state.kv.literal(),
            scalar_f32(temperature),
            scalar_u32(seed),
        ])?;
        let [tokens, qlogits, kv] = expect_outputs::<3>(out, "drafter::draft")?;
        let tokens = to_vec_i32(&tokens)?;
        let qlogits = Tensor::new(
            crate::runtime::to_vec_f32(&qlogits)?,
            vec![gamma, self.entry.vocab],
        )?;
        state.kv.set(kv);
        Ok(DraftOutput { tokens, qlogits })
    }

    /// Draft a token tree from `last`: the scripted backend branches over
    /// its candidate lines; the PJRT path degenerates to the fused chain.
    pub fn draft_tree(
        &self,
        state: &mut SeqState,
        last: i32,
        cfg: &crate::spec::tree::TreeConfig,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftTree> {
        if self.is_scripted() {
            let _ = (last, temperature, seed);
            return scripted::draft_tree_drafter(self.entry.vocab, cfg, state);
        }
        crate::spec::decoder::draft_tree_via_chain(self, state, last, cfg, temperature, seed)
    }

    /// Cross-request batched drafting: each lane drafts from its own
    /// state under its own (last, temperature, seed).  Scripted lanes are
    /// computed independently in lane order (no cross-lane leakage); PJRT
    /// packs along a batch axis when the artifact exports a `draft_batch`
    /// entry point, else falls back to per-lane calls.
    #[allow(clippy::type_complexity)]
    pub fn draft_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, f32, u32)],
    ) -> Vec<Result<DraftOutput>> {
        if self.is_scripted() || !self.entry.entries.contains_key("draft_batch") || lanes.len() < 2
        {
            return lanes
                .iter_mut()
                .map(|(st, last, t, seed)| self.draft(st, *last, *t, *seed))
                .collect();
        }
        match self.draft_batch_packed(lanes) {
            Ok(outs) => outs.into_iter().map(Ok).collect(),
            Err(e) => {
                // no lane state was mutated (outputs validate before the KV
                // scatter), so per-lane retry isolates the faulty lane
                log::warn!("drafter::draft_batch packed call failed ({e:#}); retrying per-lane");
                lanes
                    .iter_mut()
                    .map(|(st, last, t, seed)| self.draft(st, *last, *t, *seed))
                    .collect()
            }
        }
    }

    /// PJRT packed draft: last [B], positions [B], KV tuple, temperatures
    /// [B], seeds [B] -> tokens [B x gamma], qlogits [B x gamma x V], KVs.
    #[allow(clippy::type_complexity)]
    fn draft_batch_packed(
        &self,
        lanes: &mut [(&mut SeqState, i32, f32, u32)],
    ) -> Result<Vec<DraftOutput>> {
        let b = lanes.len();
        let gamma = self.set.manifest.gamma;
        let exec = self.set.exec(&self.entry, "draft_batch")?;
        let lasts: Vec<i32> = lanes.iter().map(|(_, l, _, _)| *l).collect();
        let positions: Vec<i32> = lanes.iter().map(|(st, ..)| st.pos).collect();
        let kvs = xla::Literal::Tuple(lanes.iter().map(|(st, ..)| st.kv.literal()).collect());
        let temps: Vec<f32> = lanes.iter().map(|(_, _, t, _)| *t).collect();
        let seeds: Vec<u32> = lanes.iter().map(|(_, _, _, s)| *s).collect();
        let out = exec.call(&[
            lit_i32(&lasts, &[b])?,
            lit_i32(&positions, &[b])?,
            kvs,
            lit_f32(&temps, &[b])?,
            xla::Literal::vec1(&seeds),
        ])?;
        let [tokens, qlogits, kvs] = expect_outputs::<3>(out, "drafter::draft_batch")?;
        let v = self.entry.vocab;
        if gamma == 0 || v == 0 {
            return Err(anyhow!("drafter::draft_batch: degenerate gamma={gamma} vocab={v}"));
        }
        let toks = to_vec_i32(&tokens)?;
        let flat = crate::runtime::to_vec_f32(&qlogits)?;
        if toks.len() != b * gamma || flat.len() != b * gamma * v {
            return Err(anyhow!(
                "drafter::draft_batch: expected {b}x{gamma} tokens and {b}x{gamma}x{v} \
                 qlogits, got {} and {}",
                toks.len(),
                flat.len()
            ));
        }
        // build every fallible output BEFORE the KV scatter (see
        // `verify_batch_packed`): on any Err, no lane state has changed
        let outs: Vec<DraftOutput> = toks
            .chunks(gamma)
            .zip(flat.chunks(gamma * v))
            .map(|(tc, qc)| {
                Ok(DraftOutput {
                    tokens: tc.to_vec(),
                    qlogits: Tensor::new(qc.to_vec(), vec![gamma, v])?,
                })
            })
            .collect::<Result<_>>()?;
        scatter_kvs(lanes.iter_mut().map(|(st, ..)| &mut **st), kvs, "drafter::draft_batch")?;
        Ok(outs)
    }

    /// Cross-request batched tree drafting.  Always per-lane (per-lane
    /// tree shapes; the fused PJRT drafters have no tree entry point, so
    /// their per-lane path already degenerates to the chain draft).
    #[allow(clippy::type_complexity)]
    pub fn draft_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &crate::spec::tree::TreeConfig, f32, u32)],
    ) -> Vec<Result<DraftTree>> {
        lanes
            .iter_mut()
            .map(|(st, last, cfg, t, seed)| self.draft_tree(st, *last, *cfg, *t, *seed))
            .collect()
    }

    /// Step-wise decode (reference path + TVD distribution analysis).
    pub fn decode(&self, state: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        if self.is_scripted() {
            let _ = token;
            let (_, q) = scripted::draft_drafter(self.entry.vocab, 1, state)?;
            state.pos += 1;
            return Ok(q.data);
        }
        let exec = self.set.exec(&self.entry, "decode")?;
        let out = exec.call(&[
            lit_i32(&[token], &[1])?,
            scalar_i32(state.pos),
            state.kv.literal(),
        ])?;
        let [logits, kv] = expect_outputs::<2>(out, "drafter::decode")?;
        let logits = crate::runtime::to_vec_f32(&logits)?;
        state.kv.set(kv);
        state.pos += 1;
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expect_outputs_names_entry_and_arity() {
        let out = vec![xla::Literal::scalar(0.0f32)];
        let err = expect_outputs::<2>(out, "target::verify").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("target::verify"), "{msg}");
        assert!(msg.contains("expected 2"), "{msg}");
        assert!(msg.contains("got 1"), "{msg}");
        let [a] = expect_outputs::<1>(vec![xla::Literal::scalar(3i32)], "x").unwrap();
        assert_eq!(a, xla::Literal::scalar(3i32));
    }

    #[test]
    fn seq_state_fork_is_independent() {
        let script = Arc::new(scripted::ScriptSet::single(vec![5, 6, 7]));
        let st = SeqState::new(
            xla::Literal::vec1(&[1.0f32, 2.0]),
            9,
            Some(script.clone()),
        );
        let mut fork = st.fork();
        fork.pos += 3;
        assert_eq!(st.pos, 9, "fork must not alias positions");
        assert_eq!(fork.kv.literal(), st.kv.literal());
        assert!(Arc::ptr_eq(fork.script.as_ref().unwrap(), &script), "scripts are shared");
        assert!(st.bytes() > 0 && st.bytes() == fork.bytes());
    }

    #[test]
    fn paginated_fork_materializes_identically() {
        // the same fork contract must hold once the state is paged: fork,
        // diverge the original, and the fork still materializes the old KV
        let pool = crate::kv::KvPool::new(crate::kv::KvPoolConfig {
            block_words: 4,
            budget_bytes: 1 << 20,
        });
        let mut st = SeqState::new(xla::Literal::vec1(&vec![1.5f32; 20]), 3, None);
        st.paginate(&pool);
        assert!(st.kv.is_paged());
        let fork = st.fork();
        st.kv.set(xla::Literal::vec1(&vec![2.5f32; 20]));
        assert_eq!(fork.kv.literal(), xla::Literal::vec1(&vec![1.5f32; 20]));
        assert_eq!(st.kv.literal(), xla::Literal::vec1(&vec![2.5f32; 20]));
        // paged states charge the handle, not the payload
        assert!(st.bytes() < 20 * 4 + std::mem::size_of::<SeqState>());
    }

    #[test]
    fn snapshot_bytes_cover_all_parts() {
        let st = |n: usize| SeqState::new(xla::Literal::vec1(&vec![0.0f32; n]), 0, None);
        let without = PrefixSnapshot { last_logits: vec![0.0; 8], tstate: st(4), dstate: None };
        let with = PrefixSnapshot {
            last_logits: vec![0.0; 8],
            tstate: st(4),
            dstate: Some(st(16)),
        };
        assert!(with.bytes() > without.bytes());
        assert!(without.bytes() >= 8 * 4 + 4 * 4);
    }

    #[test]
    fn scripted_batch_entry_points_match_per_lane_calls() {
        // batched decode/verify over the scripted backend must equal the
        // per-lane calls and be independent of lane order: each lane owns
        // its script + position, so nothing can leak across lanes
        let dir = scripted::write_test_artifacts("models_batch", 48, false);
        let set = ModelSet::load(&dir).unwrap();
        let target = set.target("qwensim-L").unwrap();
        let prefill = |phase: usize| {
            let img = scripted::demo_image(phase);
            let enc = target.encode_image(&img).unwrap();
            target.prefill_encoded(&enc, &[1, 5, 9], 3).unwrap().1
        };
        let (mut a, mut b) = (prefill(0), prefill(1));
        let (mut a2, mut b2) = (prefill(0), prefill(1));
        let mut fwd_lanes = vec![(&mut a, 7), (&mut b, 9)];
        let fwd: Vec<Vec<f32>> = target
            .decode_batch(&mut fwd_lanes)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let mut rev_lanes = vec![(&mut b2, 9), (&mut a2, 7)];
        let rev: Vec<Vec<f32>> = target
            .decode_batch(&mut rev_lanes)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(fwd[0], rev[1], "lane order must not leak between scripted streams");
        assert_eq!(fwd[1], rev[0]);
        assert_eq!(a.pos, 1);
        // per-lane reference call
        let mut r = prefill(1);
        assert_eq!(fwd[1], target.decode(&mut r, 9).unwrap());

        // verify_batch leaves positions untouched and matches verify()
        let gamma1 = set.manifest.gamma + 1;
        let (mut a, mut b) = (prefill(2), prefill(3));
        let pos_before = a.pos;
        let (wa, wb) = (vec![5i32; gamma1], vec![6i32; gamma1]);
        let mut lanes: Vec<(&mut SeqState, &[i32])> = vec![(&mut a, &wa), (&mut b, &wb)];
        let out: Vec<_> = target
            .verify_batch(&mut lanes)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(a.pos, pos_before, "verify must not advance positions");
        let mut r = prefill(3);
        assert_eq!(out[1].data, target.verify(&mut r, &wb).unwrap().data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pooled_pixels_blockwise_mean_keeps_shape() {
        let img: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let raw = VisionEncoding::Raw(Arc::new(img.clone()));
        let p1 = raw.pooled_pixels(1).unwrap();
        assert_eq!(*p1, img, "ratio 1 is the identity (shared, not copied)");
        let p4 = raw.pooled_pixels(4).unwrap();
        assert_eq!(p4.len(), img.len(), "compression must preserve the fixed shape");
        assert_eq!(&p4[..4], &[1.5; 4], "block mean replicated over the block");
        assert_eq!(&p4[4..], &[5.5; 4]);
        let s = VisionEncoding::Scripted { image_seed: 1 };
        assert!(s.pooled_pixels(4).is_none(), "scripted encodings pool via the digest");
    }

    #[test]
    fn vision_encoding_seed_matches_either_form() {
        let img: Vec<f32> = (0..IMAGE_ELEMS).map(|i| i as f32 * 0.01).collect();
        let raw = VisionEncoding::raw(&img);
        let scripted_enc = VisionEncoding::Scripted { image_seed: scripted::image_seed(&img) };
        assert_eq!(raw.scripted_seed(), scripted_enc.scripted_seed());
        assert!(raw.pixels().is_some());
        assert!(scripted_enc.pixels().is_none());
        assert_eq!(scripted_enc.bytes(), 8);
        assert_eq!(raw.bytes(), IMAGE_ELEMS * 4);
    }
}
