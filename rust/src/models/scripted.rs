//! Deterministic scripted model backend (`manifest.backend == "scripted"`).
//!
//! Stands in for the compiled PJRT executables wherever the real runtime is
//! unavailable (CI, the vendored-stub build, integration tests): every
//! request maps to a deterministic target token stream derived by hashing
//! its (image, prompt) pair, and drafter variants propose agreement-
//! degraded copies of that stream -- "massv" diverges rarely, "baseline"
//! constantly, text-only drafting degrades further -- so acceptance
//! dynamics, MAL ordering across variants, and chain-vs-tree behavior are
//! all exercised end-to-end (engine, scheduler, TCP protocol) with zero
//! model weights.
//!
//! Logits are sharp one-hots (`SHARP`), so temperature sampling follows the
//! script deterministically and T>0 losslessness is testable seed by seed.
//! `SeqState.pos` holds the *stream* index (same convention as
//! `spec::testing`); the opaque KV literal is never read.
//!
//! Every op here reads ONLY the `SeqState` it is handed -- there is no
//! module-level state -- so the batched entry points
//! (`TargetModel::decode_batch` et al) can interleave lanes in any order
//! and each lane still follows its own script exactly: the scripted half
//! of the cross-request batching determinism argument
//! (`spec::testing::run_batched_vs_sequential`).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::manifest::Manifest;
use crate::models::SeqState;
use crate::runtime::Tensor;
use crate::spec::tree::{DraftTree, TreeBuilder, TreeConfig};
use crate::util::rng::Rng;

/// One-hot logit magnitude: softmax at T=1 is numerically a point mass.
pub const SHARP: f32 = 50.0;

/// The token lines a scripted sequence follows: the mainline plus
/// alternative branch lines for tree drafting.
#[derive(Debug, Clone)]
pub struct ScriptSet {
    pub primary: Vec<i32>,
    pub alts: Vec<Vec<i32>>,
}

impl ScriptSet {
    pub fn single(primary: Vec<i32>) -> ScriptSet {
        ScriptSet { primary, alts: Vec::new() }
    }
}

/// Cyclic indexing (same convention as the test mocks, so budget overruns
/// never panic).
pub fn at(script: &[i32], i: i32) -> i32 {
    script[(i.max(0) as usize) % script.len()]
}

pub fn sharp_row(tok: i32, vocab: usize) -> Vec<f32> {
    let mut row = vec![0.0f32; vocab];
    row[(tok as usize).min(vocab - 1)] = SHARP;
    row
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over a subsample of the image: the prompt-independent half of
/// the stream seed.  This is the scripted backend's "vision encode" --
/// the cacheable product `VisionEncoding::Scripted` carries, so a warm
/// prefill over a cached encoding skips the image walk entirely.
pub fn image_seed(image: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for v in image.iter().step_by(29) {
        h = (h ^ v.to_bits() as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Mix the true prompt prefix into an image seed: the deterministic
/// per-request stream seed, stage 2 of the split prefill.
pub fn stream_seed_from(image_seed: u64, prompt: &[i32], len: usize) -> u64 {
    let mut h = image_seed;
    for &t in prompt.iter().take(len) {
        h = (h ^ t as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fused seed over (image, prompt) -- `image_seed` + `stream_seed_from`.
pub fn stream_seed(image: &[f32], prompt: &[i32], len: usize) -> u64 {
    stream_seed_from(image_seed(image), prompt, len)
}

/// Mixing rounds per pooled vision token in `pooled_vision_digest`: sized
/// so a full-resolution walk (`n_visual` tokens) costs a measurable
/// fraction of a millisecond -- the scripted stand-in for the drafter's
/// per-vision-token prefill FLOPs that compression removes.
pub const POOLED_TOKEN_MIX_ROUNDS: usize = 8192;

/// The drafter's compressed vision prefill, scripted: a deterministic
/// splitmix-style walk over `ceil(n_visual / ratio)` pooled tokens.  Cost
/// scales with the pooled sequence length (each pooled token pays
/// `POOLED_TOKEN_MIX_ROUNDS` mixes), which is exactly the quantity
/// drafter-side vision token compression buys back; the returned digest is
/// a pure function of (image_seed, n_visual, ratio), so the compressed
/// drafter encoding is content-addressable and property-testable.
pub fn pooled_vision_digest(image_seed: u64, n_visual: usize, ratio: u32) -> u64 {
    let ratio = ratio.max(1) as usize;
    let tokens = n_visual.div_ceil(ratio).max(1);
    let mut acc = image_seed ^ (ratio as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    for t in 0..tokens {
        let mut x = acc ^ (t as u64).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        for _ in 0..POOLED_TOKEN_MIX_ROUNDS {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            x ^= z ^ (z >> 31);
        }
        acc = acc.rotate_left(7) ^ x;
    }
    acc
}

/// The target's token stream for one request: `gen_max - 2` content tokens
/// from the non-special vocabulary range, then EOS.
pub fn target_stream(m: &Manifest, image: &[f32], prompt: &[i32], len: usize) -> Vec<i32> {
    target_stream_seeded(m, stream_seed(image, prompt, len))
}

/// `target_stream` from a precomputed stream seed.
pub fn target_stream_seeded(m: &Manifest, seed: u64) -> Vec<i32> {
    let mut rng = Rng::seeded(seed);
    let lo = content_floor(m);
    let n = m.gen_max.saturating_sub(2).max(4);
    let mut s: Vec<i32> = (0..n)
        .map(|_| (lo + rng.range(m.vocab_size - lo)) as i32)
        .collect();
    s.push(m.eos_id);
    s
}

/// First non-special token id (special ids occupy the low range).
fn content_floor(m: &Manifest) -> usize {
    let top = m.pad_id.max(m.bos_id).max(m.eos_id).max(m.sep_id).max(0) as usize + 1;
    // leave one extra slot so corruptions have room even in tiny vocabs
    top.min(m.vocab_size.saturating_sub(2))
}

/// Replace every `period`-th token (at `phase`) with a deterministic
/// *different* content token.  `salt = 0` reproduces the unsalted
/// corruption exactly; a nonzero salt (the pooled vision digest under
/// compression) shifts which wrong token is proposed without changing
/// which positions are corrupted.
fn corrupt(
    stream: &[i32],
    period: usize,
    phase: usize,
    lo: usize,
    vocab: usize,
    salt: u64,
) -> Vec<i32> {
    let span = (vocab - lo).max(2) as i32;
    let salt_off = (salt % span as u64) as i32;
    stream
        .iter()
        .enumerate()
        .map(|(i, &t)| {
            if i % period == phase % period {
                let base = (t - lo as i32).rem_euclid(span);
                let delta = 1 + ((i % 5) as i32 + salt_off) % (span - 1);
                lo as i32 + (base + delta).rem_euclid(span)
            } else {
                t
            }
        })
        .collect()
}

/// Agreement period per drafter variant: corrupt every `period`-th stream
/// position.  Larger = better aligned (the MASSV ordering: full pipeline >
/// w/o SDViT > text-only baseline), halved when the visual context is
/// discarded (`aligned == false`, the Table-3 regime).  Vision token
/// compression (`ratio > 1`) shaves the aligned period mildly --
/// `log2(ratio)/2` positions, so massv goes 7 -> 6 -> 5 at 1x/4x/16x --
/// the ViSpec/SpecVLM "negligible acceptance loss" shape.
fn agreement_period(variant: &str, aligned: bool, ratio: u32) -> usize {
    let p = match variant {
        "massv" => 7,
        "massv_wo_sdvit" => 4,
        "baseline" => 3,
        _ => 2,
    };
    if aligned {
        p.saturating_sub(ratio.max(1).ilog2() as usize / 2).max(2)
    } else {
        (p / 2).max(2)
    }
}

/// Drafter lines for one request: the primary line corrupts the target
/// stream on one phase, the alternative branch line on a disjoint phase --
/// so tree drafting always carries a branch that tracks the target through
/// a primary divergence (what raises tree MAL above chain MAL).
/// `ratio`/`salt` carry the vision-compression state: ratio widens the
/// corruption cadence per `agreement_period`, salt (the pooled digest, 0
/// at full resolution) seasons the wrong-token choice.
pub fn drafter_scripts(
    m: &Manifest,
    stream: &[i32],
    variant: &str,
    aligned: bool,
    ratio: u32,
    salt: u64,
) -> ScriptSet {
    let lo = content_floor(m);
    let period = agreement_period(variant, aligned, ratio);
    ScriptSet {
        primary: corrupt(stream, period, 1, lo, m.vocab_size, salt),
        alts: vec![corrupt(stream, period, 1 + period / 2, lo, m.vocab_size, salt)],
    }
}

fn state(script: ScriptSet) -> SeqState {
    SeqState::new(xla::Literal::scalar(0.0f32), 0, Some(Arc::new(script)))
}

fn script_of(st: &SeqState) -> Result<&Arc<ScriptSet>> {
    st.script
        .as_ref()
        .ok_or_else(|| anyhow!("scripted backend: sequence state carries no script"))
}

// ------------------------------------------------------------- target ops

pub fn prefill_target(
    m: &Manifest,
    vocab: usize,
    image: &[f32],
    prompt: &[i32],
    len: usize,
) -> Result<(Vec<f32>, SeqState)> {
    prefill_target_seeded(m, vocab, image_seed(image), prompt, len)
}

/// `prefill_target` from a cached image seed (the split-prefill stage 2).
pub fn prefill_target_seeded(
    m: &Manifest,
    vocab: usize,
    image_seed: u64,
    prompt: &[i32],
    len: usize,
) -> Result<(Vec<f32>, SeqState)> {
    let stream = target_stream_seeded(m, stream_seed_from(image_seed, prompt, len));
    let logits = sharp_row(stream[0], vocab);
    Ok((logits, state(ScriptSet::single(stream))))
}

/// Row i predicts the stream token after `tokens[i]` (position `pos + i`).
pub fn verify_target(vocab: usize, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
    let script = script_of(st)?.clone();
    let rows: Vec<f32> = (0..tokens.len())
        .flat_map(|i| sharp_row(at(&script.primary, st.pos + i as i32 + 1), vocab))
        .collect();
    Tensor::new(rows, vec![tokens.len(), vocab])
}

pub fn decode_target(vocab: usize, st: &mut SeqState) -> Result<Vec<f32>> {
    let script = script_of(st)?.clone();
    let out = sharp_row(at(&script.primary, st.pos + 1), vocab);
    st.pos += 1;
    Ok(out)
}

/// Tree rows are positional: the node at depth d gets the row predicting
/// stream index `pos + d + 2`; row 0 predicts `pos + 1`.
pub fn verify_tree_target(vocab: usize, st: &mut SeqState, tree: &DraftTree) -> Result<Tensor> {
    let script = script_of(st)?.clone();
    let mut rows: Vec<f32> = Vec::with_capacity((tree.len() + 1) * vocab);
    rows.extend(sharp_row(at(&script.primary, st.pos + 1), vocab));
    for d in &tree.depths {
        rows.extend(sharp_row(at(&script.primary, st.pos + *d as i32 + 2), vocab));
    }
    Tensor::new(rows, vec![tree.len() + 1, vocab])
}

// ------------------------------------------------------------ drafter ops

#[allow(clippy::too_many_arguments)]
pub fn prefill_drafter(
    m: &Manifest,
    variant: &str,
    multimodal: bool,
    image: Option<&[f32]>,
    prompt: &[i32],
    len: usize,
    text_only: bool,
    vision_ratio: u32,
) -> Result<SeqState> {
    prefill_drafter_seeded(
        m,
        variant,
        multimodal,
        image.map(image_seed),
        prompt,
        len,
        text_only,
        vision_ratio,
    )
}

/// `prefill_drafter` from a cached image seed.  The drafter always needs
/// the seed to reconstruct the target's stream (agreement is positional);
/// whether it "sees" the image only modulates the corruption period.
/// `vision_ratio` is the drafter-side compression knob: the vision walk
/// (`pooled_vision_digest`) runs over `n_visual / ratio` pooled tokens, so
/// ratio >= 4 is measurably cheaper; at ratio 1 the digest is computed but
/// discarded (black-boxed against elimination) and the drafter scripts are
/// bit-identical to the uncompressed path.
#[allow(clippy::too_many_arguments)]
pub fn prefill_drafter_seeded(
    m: &Manifest,
    variant: &str,
    multimodal: bool,
    image_seed_in: Option<u64>,
    prompt: &[i32],
    len: usize,
    text_only: bool,
    vision_ratio: u32,
) -> Result<SeqState> {
    // the drafter only "sees" the image when it is multimodal and not in
    // Table-3 text-only mode; alignment degrades otherwise
    let ratio = vision_ratio.max(1);
    let aligned = multimodal && !text_only && image_seed_in.is_some();
    let iseed = image_seed_in.unwrap_or_else(|| image_seed(&[]));
    // only an aligned drafter runs a vision prefill at all (text-only and
    // non-multimodal drafters never walk the image tokens)
    let digest = if aligned { pooled_vision_digest(iseed, m.n_visual, ratio) } else { 0 };
    let salt = if ratio > 1 {
        digest
    } else {
        std::hint::black_box(digest);
        0
    };
    let stream = target_stream_seeded(m, stream_seed_from(iseed, prompt, len));
    Ok(state(drafter_scripts(m, &stream, variant, aligned, ratio, salt)))
}

pub fn draft_drafter(
    vocab: usize,
    gamma: usize,
    st: &mut SeqState,
) -> Result<(Vec<i32>, Tensor)> {
    let script = script_of(st)?.clone();
    let tokens: Vec<i32> =
        (0..gamma).map(|i| at(&script.primary, st.pos + 1 + i as i32)).collect();
    let qlogits = Tensor::new(
        tokens.iter().flat_map(|&t| sharp_row(t, vocab)).collect(),
        vec![gamma, vocab],
    )?;
    Ok((tokens, qlogits))
}

/// Prefix-trie over the primary and alternative lines' windows at the
/// current stream position (genuine multi-branch drafting).
pub fn draft_tree_drafter(
    vocab: usize,
    cfg: &TreeConfig,
    st: &mut SeqState,
) -> Result<DraftTree> {
    let script = script_of(st)?.clone();
    let mut b = TreeBuilder::new(vocab);
    let lines = std::iter::once(&script.primary).chain(script.alts.iter());
    for line in lines {
        let path: Vec<(i32, Vec<f32>)> = (0..cfg.depth())
            .map(|d| {
                let t = at(line, st.pos + 1 + d as i32);
                (t, sharp_row(t, vocab))
            })
            .collect();
        b.add_path(&path, cfg);
    }
    b.build()
}

// --------------------------------------------------------------- fixtures

/// Write a self-contained scripted-backend artifact directory (manifest +
/// vocab, no HLO files) under the system temp dir -- the fixture the
/// integration tests and benches use to drive the full serving stack
/// without PJRT.  `gen_max` controls stream length (large values make
/// decodes long enough to observe scheduling); `with_baseline_drafter`
/// adds the text-only "baseline" drafter variant next to "massv".
/// Returns the directory path; callers clean it up with `remove_dir_all`.
/// Panics on io errors (it is test support, not serving-path code).
pub fn write_test_artifacts(tag: &str, gen_max: usize, with_baseline_drafter: bool) -> String {
    let dir = std::env::temp_dir().join(format!("massv_scripted_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let vocab = 120usize;
    let mut tokens: Vec<String> =
        ["<pad>", "<bos>", "<eos>", "<sep>", "<img>"].iter().map(|s| s.to_string()).collect();
    for i in tokens.len()..vocab {
        tokens.push(format!("w{i}"));
    }
    let tokens_json: Vec<String> = tokens.iter().map(|t| format!("\"{t}\"")).collect();
    std::fs::write(
        dir.join("vocab.json"),
        format!(
            r#"{{"tokens":[{}],"pad_id":0,"bos_id":1,"eos_id":2,"sep_id":3,"img_id":4}}"#,
            tokens_json.join(",")
        ),
    )
    .unwrap();
    let entry = |name: &str, kind: &str, extra: &str| {
        format!(
            r#"{{"name":"{name}","kind":"{kind}","family":"qwensim","paper_analog":"scripted",
                "d_model":48,"n_layers":2,"n_heads":4,"d_head":12,"vocab":{vocab},
                "window":null,"kv_shape":[2,2,4,128,12],"entries":{{}}{extra}}}"#
        )
    };
    let massv = entry(
        "qwensim-S",
        "draft",
        r#","variant":"massv","aligned_target":"qwensim-L","multimodal":true"#,
    );
    let baseline = entry(
        "qwensim-S",
        "draft",
        r#","variant":"baseline","aligned_target":"qwensim-L","multimodal":false"#,
    );
    let drafters = if with_baseline_drafter { format!("{massv},{baseline}") } else { massv };
    let manifest = format!(
        r#"{{"schema":1,"backend":"scripted","gamma":5,"t_max":128,"p_max":32,
            "n_visual":16,"gen_max":{gen_max},"vocab_size":{vocab},"pad_id":0,"bos_id":1,
            "eos_id":2,"sep_id":3,"use_kernel":false,
            "targets":[{target}],
            "drafters":[{drafters}]}}"#,
        gen_max = gen_max,
        vocab = vocab,
        target = entry("qwensim-L", "target", ""),
        drafters = drafters,
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    dir.to_str().unwrap().to_string()
}

/// Deterministic 16x16x3 demo image keyed by `phase` (fixture companion to
/// `write_test_artifacts`; different phases yield different scripted
/// streams).
pub fn demo_image(phase: usize) -> Vec<f32> {
    (0..crate::models::IMAGE_ELEMS).map(|i| ((i + phase) % 7) as f32 * 0.11).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    fn toy_manifest() -> Manifest {
        Manifest::from_json(
            r#"{
          "schema": 1, "backend": "scripted", "gamma": 5, "t_max": 128,
          "p_max": 32, "n_visual": 16, "gen_max": 48, "vocab_size": 120,
          "pad_id": 0, "bos_id": 1, "eos_id": 2, "sep_id": 3,
          "use_kernel": false, "targets": [], "drafters": []
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn streams_are_deterministic_and_request_dependent() {
        let m = toy_manifest();
        let img_a = vec![0.25f32; 768];
        let img_b = vec![0.5f32; 768];
        let prompt = vec![1, 5, 6, 3, 0, 0];
        let s1 = target_stream(&m, &img_a, &prompt, 4);
        let s2 = target_stream(&m, &img_a, &prompt, 4);
        assert_eq!(s1, s2, "same request -> same stream");
        assert_ne!(s1, target_stream(&m, &img_b, &prompt, 4), "image changes the stream");
        assert_eq!(*s1.last().unwrap(), m.eos_id);
        assert!(s1[..s1.len() - 1].iter().all(|&t| t >= 4 && (t as usize) < m.vocab_size));
    }

    #[test]
    fn stream_seed_decomposes_through_image_seed() {
        // the split prefill must reproduce the fused path exactly: seeding
        // from a cached image_seed is the warm-encode correctness argument
        let m = toy_manifest();
        let img: Vec<f32> = (0..768).map(|i| (i % 11) as f32 * 0.07).collect();
        let prompt = vec![1, 5, 9, 3, 0, 0];
        assert_eq!(
            stream_seed(&img, &prompt, 4),
            stream_seed_from(image_seed(&img), &prompt, 4)
        );
        assert_eq!(
            target_stream(&m, &img, &prompt, 4),
            target_stream_seeded(&m, stream_seed_from(image_seed(&img), &prompt, 4))
        );
        let (lg_cold, st_cold) = prefill_target(&m, 120, &img, &prompt, 4).unwrap();
        let (lg_warm, st_warm) =
            prefill_target_seeded(&m, 120, image_seed(&img), &prompt, 4).unwrap();
        assert_eq!(lg_cold, lg_warm);
        assert_eq!(
            st_cold.script.as_ref().unwrap().primary,
            st_warm.script.as_ref().unwrap().primary
        );
    }

    #[test]
    fn corruption_differs_and_period_orders_agreement() {
        let m = toy_manifest();
        let img = vec![0.1f32; 768];
        let stream = target_stream(&m, &img, &[1, 7, 3], 3);
        let agree = |variant: &str| -> usize {
            let s = drafter_scripts(&m, &stream, variant, true, 1, 0);
            s.primary.iter().zip(&stream).filter(|(a, b)| a == b).count()
        };
        let massv = agree("massv");
        let wo = agree("massv_wo_sdvit");
        let base = agree("baseline");
        assert!(massv > wo && wo > base, "{massv} > {wo} > {base} expected");
        // corrupted positions really differ
        let s = drafter_scripts(&m, &stream, "massv", true, 1, 0);
        let diffs = s.primary.iter().zip(&stream).filter(|(a, b)| a != b).count();
        assert!(diffs > 0);
        // primary and alt corrupt disjoint phases
        for i in 0..stream.len() {
            assert!(
                s.primary[i] == stream[i] || s.alts[0][i] == stream[i],
                "position {i} corrupted in both lines"
            );
        }
    }

    #[test]
    fn text_only_degrades_alignment() {
        let m = toy_manifest();
        let img = vec![0.3f32; 768];
        let stream = target_stream(&m, &img, &[1, 9, 3], 3);
        let agree = |aligned: bool| -> usize {
            drafter_scripts(&m, &stream, "massv", aligned, 1, 0)
                .primary
                .iter()
                .zip(&stream)
                .filter(|(a, b)| a == b)
                .count()
        };
        assert!(agree(true) > agree(false));
    }

    #[test]
    fn pooled_digest_is_deterministic_and_ratio_sensitive() {
        let d1 = pooled_vision_digest(0xdead_beef, 16, 1);
        assert_eq!(d1, pooled_vision_digest(0xdead_beef, 16, 1), "pure function");
        let d4 = pooled_vision_digest(0xdead_beef, 16, 4);
        let d16 = pooled_vision_digest(0xdead_beef, 16, 16);
        assert_ne!(d1, d4, "ratio enters the digest");
        assert_ne!(d4, d16);
        assert_ne!(d1, pooled_vision_digest(0xcafe, 16, 1), "seed enters the digest");
        // ratio 0 is clamped to full resolution
        assert_eq!(pooled_vision_digest(7, 16, 0), pooled_vision_digest(7, 16, 1));
    }

    #[test]
    fn compressed_drafter_prefill_is_exact_at_ratio_one_and_degrades_mildly() {
        let m = toy_manifest();
        let img: Vec<f32> = (0..768).map(|i| (i % 13) as f32 * 0.05).collect();
        let prompt = vec![1, 5, 6, 3];
        let seed = Some(image_seed(&img));
        let full = prefill_drafter_seeded(&m, "massv", true, seed, &prompt, 4, false, 1).unwrap();
        let full2 = prefill_drafter_seeded(&m, "massv", true, seed, &prompt, 4, false, 1).unwrap();
        let s_full = full.script.as_ref().unwrap();
        // ratio 1 must be bit-identical to itself across calls (and is the
        // same script the pre-compression code produced: salt 0, period 7)
        assert_eq!(s_full.primary, full2.script.as_ref().unwrap().primary);
        let stream = target_stream_seeded(&m, stream_seed_from(image_seed(&img), &prompt, 4));
        let expect = drafter_scripts(&m, &stream, "massv", true, 1, 0);
        assert_eq!(s_full.primary, expect.primary, "ratio 1 == uncompressed scripts");
        // compression reduces agreement mildly, never below the floor
        let agree = |ratio: u32| -> usize {
            let st =
                prefill_drafter_seeded(&m, "massv", true, seed, &prompt, 4, false, ratio).unwrap();
            st.script.as_ref().unwrap().primary.iter().zip(&stream).filter(|(a, b)| a == b).count()
        };
        let (a1, a4, a16) = (agree(1), agree(4), agree(16));
        assert!(a1 >= a4 && a4 >= a16, "agreement must degrade monotonically: {a1} {a4} {a16}");
        assert!(a16 * 2 > a1, "16x compression must still agree on most positions");
    }

    #[test]
    fn interleaved_lanes_follow_their_own_scripts() {
        // any interleaving of per-lane ops must equal the isolated runs:
        // the invariant batched execution (decode_batch/verify_batch)
        // relies on to keep ganged requests bit-identical to sequential
        let m = toy_manifest();
        let img_a = vec![0.2f32; 768];
        let img_b = vec![0.9f32; 768];
        let prompt = vec![1, 5, 6];
        let run_isolated = |img: &[f32]| {
            let (_, mut st) = prefill_target(&m, m.vocab_size, img, &prompt, 3).unwrap();
            (0..6)
                .map(|_| {
                    crate::spec::sampler::argmax(&decode_target(m.vocab_size, &mut st).unwrap())
                })
                .collect::<Vec<_>>()
        };
        let (iso_a, iso_b) = (run_isolated(&img_a), run_isolated(&img_b));
        assert_ne!(iso_a, iso_b, "distinct images must yield distinct streams");

        let (_, mut a) = prefill_target(&m, m.vocab_size, &img_a, &prompt, 3).unwrap();
        let (_, mut b) = prefill_target(&m, m.vocab_size, &img_b, &prompt, 3).unwrap();
        let mut inter_a = Vec::new();
        let mut inter_b = Vec::new();
        for i in 0..12 {
            // alternate lanes (the fused-tick interleaving)
            let (st, out) = if i % 2 == 0 { (&mut a, &mut inter_a) } else { (&mut b, &mut inter_b) };
            out.push(crate::spec::sampler::argmax(
                &decode_target(m.vocab_size, st).unwrap(),
            ));
        }
        assert_eq!(inter_a, iso_a, "interleaving must not perturb lane A");
        assert_eq!(inter_b, iso_b, "interleaving must not perturb lane B");
    }

    #[test]
    fn sharp_rows_pin_the_argmax() {
        let r = sharp_row(7, 16);
        assert_eq!(crate::spec::sampler::argmax(&r), 7);
        let mut p = Vec::new();
        crate::spec::sampler::softmax_t(&r, 1.0, &mut p);
        assert!(p[7] > 0.999999);
    }
}
