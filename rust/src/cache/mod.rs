//! Multimodal prefix cache: content-addressed reuse of the expensive,
//! request-independent parts of multimodal prefill across the serving
//! stack (the vLLM-prefix-caching idea generalized to the dual
//! target+drafter KV state MASSV sessions carry).
//!
//! Three content-addressed tables share one byte budget:
//!
//!   * **pixels** -- image hash -> raw pixels.  Lets clients send an image
//!     once and reference it by `image_id` afterwards (multi-turn chat,
//!     eval sweeps over one image).
//!   * **encodings** -- image hash -> `VisionEncoding` (the projected
//!     vision embedding; prompt-independent prefill stage 1).  Filled
//!     under *single-flight*: concurrent requests for the same image wait
//!     on one encode instead of racing.
//!   * **prefixes** -- `PrefixKey` (target, drafter config, image, prompt)
//!     -> `PrefixSnapshot` (post-prefill forkable KV for both models plus
//!     the prefill logits).  Also single-flight; a warm request forks the
//!     snapshot instead of running either model's prefill.
//!
//! Snapshots are taken *before* the free first token is sampled, so
//! per-request sampling config (seed, temperature, top_p) stays out of the
//! key and warm prefill is bit-identical to cold prefill -- the property
//! tests in `spec::session` and `tests/serving_integration.rs` pin this.
//!
//! **Ref-counting.** Payloads are `Arc`s: eviction drops the cache's
//! reference, but any session still holding a fork source (or a resolved
//! pixel buffer) keeps the data alive until it finishes -- eviction can
//! never invalidate in-flight work.
//!
//! **Eviction.** LRU over `Ready` entries across all three tables,
//! triggered whenever an insert pushes the total over the byte budget.
//! In-progress (`Filling`) slots are pinned.  Size accounting comes from
//! `PrefixSnapshot::bytes` / `VisionEncoding::bytes` / pixel length.
//!
//! **Waiting.** Single-flight waiters block on a condvar, so under the
//! engine's worker pool a waiting admission occupies its worker for at
//! most one cold prefill/encode of the same key -- bounded, but it does
//! delay unrelated decode steps when every worker waits at once.  A
//! future refinement is to requeue same-key admissions and resubmit them
//! when the fill completes instead of parking the thread.
//!
//! Hit/miss/eviction counters and the bytes/entries gauges are reported
//! through the engine's `Metrics` registry (see `docs/prefix_cache.md`).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::metrics::Metrics;
use crate::models::{PrefixSnapshot, VisionEncoding};

/// Content address of an image: FNV-1a over every pixel's bit pattern
/// plus the length.  (The scripted stream seed subsamples pixels for
/// speed; the cache key hashes all of them.)  A 64-bit non-cryptographic
/// hash is a testbed simplification: it makes accidental aliasing
/// vanishingly unlikely at this scale but is neither collision- nor
/// forgery-resistant -- `image_id`s are content addresses, not
/// capabilities, and any client of a shared server can reference any
/// cached image.  A production deployment would use a 128/256-bit
/// cryptographic hash and scope ids per tenant.
pub fn image_hash(image: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in image {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h = (h ^ image.len() as u64).wrapping_mul(0x0000_0100_0000_01b3);
    h
}

/// Wire form of an image id: 16 lowercase hex digits.
pub fn format_image_id(id: u64) -> String {
    format!("{id:016x}")
}

pub fn parse_image_id(s: &str) -> Result<u64> {
    u64::from_str_radix(s.trim(), 16)
        .map_err(|_| anyhow!("malformed image_id {s:?} (expected up to 16 hex digits)"))
}

/// Everything that determines a post-prefill state.  Sampling config is
/// deliberately absent: snapshots are pre-sampling, so one prefix serves
/// every (seed, temperature, top_p) combination.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PrefixKey {
    pub target: String,
    /// `(drafter name, variant, text_only, draft_vision_ratio)` for
    /// speculative sessions; `None` for target-only requests (their prefix
    /// carries no drafter KV, so it must not be shared with speculative
    /// ones).  The vision ratio is part of the key because the drafter KV
    /// inside a snapshot was built over the pooled vision sequence -- a
    /// warm start at a different ratio would silently resume from the
    /// wrong drafter state (outputs would stay lossless, but acceptance
    /// telemetry and MAL would be misattributed across ratios).
    pub drafter: Option<(String, String, bool, u32)>,
    /// content address of the image (`image_hash`)
    pub image: u64,
    /// the true (unpadded) prompt ids
    pub prompt: Vec<i32>,
}

/// Fixed per-entry overhead charged on top of payload bytes (map slot,
/// key, Arc bookkeeping) so byte budgets stay honest for tiny payloads.
const ENTRY_OVERHEAD: usize = 64;

enum Slot<V> {
    /// A single-flight fill is in progress; same-key callers sleep on the
    /// condvar.  Filling slots are pinned (never evicted) and carry no
    /// bytes yet.
    Filling,
    Ready(Entry<V>),
}

struct Entry<V> {
    value: V,
    bytes: usize,
    last_used: u64,
}

#[derive(Clone)]
enum Victim {
    Image(u64),
    Encoding(u64),
    Prefix(PrefixKey),
}

struct Inner {
    images: HashMap<u64, Entry<Arc<Vec<f32>>>>,
    encodings: HashMap<u64, Slot<Arc<VisionEncoding>>>,
    prefixes: HashMap<PrefixKey, Slot<Arc<PrefixSnapshot>>>,
    bytes: usize,
    tick: u64,
}

impl Inner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn entries(&self) -> usize {
        self.images.len() + self.encodings.len() + self.prefixes.len()
    }

    /// Drop LRU `Ready` entries (any table) until the byte total fits the
    /// budget.  Returns the number evicted.  The victim search is a full
    /// O(entries) scan per eviction under the cache mutex -- fine at this
    /// testbed's entry counts; a `BTreeMap` keyed by `last_used` would
    /// make it O(log n) if profiles ever show pressure here.
    fn evict_to(&mut self, budget: usize) -> u64 {
        fn better(best: &Option<(u64, Victim)>, used: u64) -> bool {
            match best {
                Some((t, _)) => used < *t,
                None => true,
            }
        }
        let mut evicted = 0u64;
        while self.bytes > budget {
            let mut best: Option<(u64, Victim)> = None;
            for (k, e) in &self.images {
                if better(&best, e.last_used) {
                    best = Some((e.last_used, Victim::Image(*k)));
                }
            }
            for (k, s) in &self.encodings {
                if let Slot::Ready(e) = s {
                    if better(&best, e.last_used) {
                        best = Some((e.last_used, Victim::Encoding(*k)));
                    }
                }
            }
            for (k, s) in &self.prefixes {
                if let Slot::Ready(e) = s {
                    if better(&best, e.last_used) {
                        best = Some((e.last_used, Victim::Prefix(k.clone())));
                    }
                }
            }
            let Some((_, victim)) = best else { break };
            // The victim was selected as a live `Ready` entry under this
            // same lock acquisition, so removal MUST find it in that state
            // -- anything else is bookkeeping corruption.  (The old code
            // tolerated a missing/`Filling` victim with `unwrap_or(0)`,
            // counting a phantom eviction while freeing nothing; had the
            // invariant ever broken, `bytes` would have drifted from the
            // live-entry total and the loop could spin without progress.)
            let freed = match victim {
                Victim::Image(k) => {
                    self.images
                        .remove(&k)
                        .expect("eviction victim vanished under the lock")
                        .bytes
                }
                Victim::Encoding(k) => match self.encodings.remove(&k) {
                    Some(Slot::Ready(e)) => e.bytes,
                    _ => unreachable!("eviction victim not Ready under the lock"),
                },
                Victim::Prefix(k) => match self.prefixes.remove(&k) {
                    Some(Slot::Ready(e)) => e.bytes,
                    _ => unreachable!("eviction victim not Ready under the lock"),
                },
            };
            self.bytes -= freed;
            evicted += 1;
        }
        evicted
    }
}

/// Result of a prefix lookup.
pub enum PrefixLookup {
    /// A cached snapshot; fork it and skip prefill entirely.
    Hit(Arc<PrefixSnapshot>),
    /// This caller is the single-flight filler for the key: run the cold
    /// prefill, then `fill()` the guard (dropping it unfilled wakes the
    /// waiters so one of them takes over).
    Fill(PrefixFill),
}

/// Single-flight fill obligation for one prefix key.
pub struct PrefixFill {
    cache: Arc<PrefixCache>,
    key: PrefixKey,
    armed: bool,
}

impl PrefixFill {
    /// Publish the snapshot, waking any same-key waiters.
    pub fn fill(mut self, snap: Arc<PrefixSnapshot>) {
        self.armed = false;
        self.cache.complete_prefix(&self.key, snap);
    }
}

impl Drop for PrefixFill {
    fn drop(&mut self) {
        if self.armed {
            self.cache.abort_prefix(&self.key);
        }
    }
}

/// Unwind/error guard for an in-flight encoding fill: reopens the slot
/// (and wakes waiters) if it is still `Filling` when dropped.
struct EncodeAbort<'a> {
    cache: &'a PrefixCache,
    image: u64,
}

impl Drop for EncodeAbort<'_> {
    fn drop(&mut self) {
        let mut inner = self.cache.inner.lock().unwrap();
        if let Some(Slot::Filling) = inner.encodings.get(&self.image) {
            inner.encodings.remove(&self.image);
        }
        drop(inner);
        self.cache.cv.notify_all();
    }
}

pub struct PrefixCache {
    inner: Mutex<Inner>,
    cv: Condvar,
    budget: usize,
    metrics: Arc<Metrics>,
}

impl PrefixCache {
    pub fn new(budget_bytes: usize, metrics: Arc<Metrics>) -> Arc<PrefixCache> {
        Arc::new(PrefixCache {
            inner: Mutex::new(Inner {
                images: HashMap::new(),
                encodings: HashMap::new(),
                prefixes: HashMap::new(),
                bytes: 0,
                tick: 0,
            }),
            cv: Condvar::new(),
            budget: budget_bytes,
            metrics,
        })
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// (bytes, entries) currently held -- mirrors the exported gauges.
    pub fn stats(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.bytes, inner.entries())
    }

    fn sync_gauges(&self, inner: &Inner) {
        self.metrics.prefix_cache_bytes.set(inner.bytes as i64);
        self.metrics.prefix_cache_entries.set(inner.entries() as i64);
    }

    /// Register pixels under their content hash (idempotent; refreshes
    /// LRU).  Returns the id and a shared handle the caller keeps even if
    /// the entry is evicted immediately.
    pub fn put_image(&self, pixels: &[f32]) -> (u64, Arc<Vec<f32>>) {
        let id = image_hash(pixels);
        (id, self.put_image_hashed(id, pixels))
    }

    /// `put_image` with a precomputed content hash -- the engine hashes
    /// once at submission and reuses the id on the admission hot path.
    pub fn put_image_hashed(&self, id: u64, pixels: &[f32]) -> Arc<Vec<f32>> {
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.next_tick();
        if let Some(e) = inner.images.get_mut(&id) {
            e.last_used = tick;
            return e.value.clone();
        }
        let value = Arc::new(pixels.to_vec());
        let bytes = pixels.len() * 4 + ENTRY_OVERHEAD;
        inner.images.insert(id, Entry { value: value.clone(), bytes, last_used: tick });
        inner.bytes += bytes;
        let ev = inner.evict_to(self.budget);
        self.metrics.prefix_cache_evictions.add(ev);
        self.sync_gauges(&inner);
        value
    }

    /// Resolve an `image_id` back to pixels (refreshes LRU).
    pub fn get_image(&self, id: u64) -> Option<Arc<Vec<f32>>> {
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.next_tick();
        let e = inner.images.get_mut(&id)?;
        e.last_used = tick;
        Some(e.value.clone())
    }

    /// Single-flight image encode: returns the cached encoding, or runs
    /// `make` exactly once per image while concurrent same-image callers
    /// wait.  The bool is true on a cache hit (including waited-for
    /// fills).  `make` runs outside the cache lock.
    pub fn encoding(
        &self,
        image: u64,
        make: impl FnOnce() -> Result<VisionEncoding>,
    ) -> Result<(Arc<VisionEncoding>, bool)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            // one touch path for every table: `next_tick` is the only
            // thing that advances the LRU clock.  (This loop used to
            // hand-roll `inner.tick + 1` and commit it only on the hit
            // arm -- duplicated clock logic that any refactor could
            // desynchronize from the other tables' touches.)
            let tick = inner.next_tick();
            match inner.encodings.get_mut(&image) {
                Some(Slot::Ready(e)) => {
                    e.last_used = tick;
                    let v = e.value.clone();
                    self.metrics.vision_encode_hits.inc();
                    return Ok((v, true));
                }
                Some(Slot::Filling) => {
                    inner = self.cv.wait(inner).unwrap();
                }
                None => {
                    inner.encodings.insert(image, Slot::Filling);
                    break;
                }
            }
        }
        drop(inner);
        // reopen the slot on Err *or unwind*: a panicking `make` must not
        // wedge the key forever (the guard's Drop is a no-op once the slot
        // is Ready, so the success path just pays a redundant notify)
        let _guard = EncodeAbort { cache: self, image };
        let enc = make()?;
        let value = Arc::new(enc);
        let bytes = value.bytes() + ENTRY_OVERHEAD;
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.next_tick();
        inner.encodings.insert(
            image,
            Slot::Ready(Entry { value: value.clone(), bytes, last_used: tick }),
        );
        inner.bytes += bytes;
        let ev = inner.evict_to(self.budget);
        self.metrics.prefix_cache_evictions.add(ev);
        self.metrics.vision_encode_fills.inc();
        self.sync_gauges(&inner);
        drop(inner);
        self.cv.notify_all();
        Ok((value, false))
    }

    /// Prefix lookup with single-flight fill: `Hit` returns the snapshot
    /// to fork; `Fill` makes this caller responsible for producing it
    /// while same-key callers wait.  (Associated fn, not a method: the
    /// returned `PrefixFill` keeps its own `Arc` on the cache so its Drop
    /// can reopen the slot.)
    pub fn prefix(cache: &Arc<PrefixCache>, key: &PrefixKey) -> PrefixLookup {
        let mut inner = cache.inner.lock().unwrap();
        loop {
            // same unified touch path as `encoding` -- see the note there
            let tick = inner.next_tick();
            match inner.prefixes.get_mut(key) {
                Some(Slot::Ready(e)) => {
                    e.last_used = tick;
                    let v = e.value.clone();
                    cache.metrics.prefix_cache_hits.inc();
                    return PrefixLookup::Hit(v);
                }
                Some(Slot::Filling) => {
                    inner = cache.cv.wait(inner).unwrap();
                }
                None => {
                    inner.prefixes.insert(key.clone(), Slot::Filling);
                    cache.metrics.prefix_cache_misses.inc();
                    return PrefixLookup::Fill(PrefixFill {
                        cache: cache.clone(),
                        key: key.clone(),
                        armed: true,
                    });
                }
            }
        }
    }

    fn complete_prefix(&self, key: &PrefixKey, snap: Arc<PrefixSnapshot>) {
        let bytes = snap.bytes() + ENTRY_OVERHEAD;
        let mut inner = self.inner.lock().unwrap();
        let tick = inner.next_tick();
        inner
            .prefixes
            .insert(key.clone(), Slot::Ready(Entry { value: snap, bytes, last_used: tick }));
        inner.bytes += bytes;
        let ev = inner.evict_to(self.budget);
        self.metrics.prefix_cache_evictions.add(ev);
        self.sync_gauges(&inner);
        drop(inner);
        self.cv.notify_all();
    }

    fn abort_prefix(&self, key: &PrefixKey) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(Slot::Filling) = inner.prefixes.get(key) {
            inner.prefixes.remove(key);
        }
        drop(inner);
        self.cv.notify_all();
    }
}

#[cfg(test)]
impl PrefixCache {
    /// Ground-truth byte total recomputed from the live entries, so tests
    /// can pin the incremental `bytes` accounting against it.
    fn recount_bytes(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        let mut total: usize = inner.images.values().map(|e| e.bytes).sum();
        for s in inner.encodings.values() {
            if let Slot::Ready(e) = s {
                total += e.bytes;
            }
        }
        for s in inner.prefixes.values() {
            if let Slot::Ready(e) = s {
                total += e.bytes;
            }
        }
        total
    }

    /// Presence probes that neither touch the LRU clock nor open slots.
    fn has_image(&self, id: u64) -> bool {
        self.inner.lock().unwrap().images.contains_key(&id)
    }

    fn has_encoding(&self, image: u64) -> bool {
        matches!(self.inner.lock().unwrap().encodings.get(&image), Some(Slot::Ready(_)))
    }

    fn has_prefix(&self, key: &PrefixKey) -> bool {
        matches!(self.inner.lock().unwrap().prefixes.get(key), Some(Slot::Ready(_)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::SeqState;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::new())
    }

    fn snapshot(kv_elems: usize) -> Arc<PrefixSnapshot> {
        Arc::new(PrefixSnapshot {
            last_logits: vec![0.0; 8],
            tstate: SeqState::new(xla::Literal::vec1(&vec![0.0f32; kv_elems]), 0, None),
            dstate: None,
        })
    }

    fn key(image: u64, prompt: i32) -> PrefixKey {
        key_at_ratio(image, prompt, 1)
    }

    fn key_at_ratio(image: u64, prompt: i32, ratio: u32) -> PrefixKey {
        PrefixKey {
            target: "t".into(),
            drafter: Some(("d".into(), "massv".into(), false, ratio)),
            image,
            prompt: vec![prompt],
        }
    }

    #[test]
    fn image_ids_round_trip_and_detect_content() {
        let a = vec![0.1f32; 16];
        let b = vec![0.2f32; 16];
        assert_eq!(image_hash(&a), image_hash(&a));
        assert_ne!(image_hash(&a), image_hash(&b));
        // every pixel matters, unlike the subsampled stream seed
        let mut c = a.clone();
        c[1] += 1.0;
        assert_ne!(image_hash(&a), image_hash(&c));
        let id = image_hash(&a);
        assert_eq!(parse_image_id(&format_image_id(id)).unwrap(), id);
        assert!(parse_image_id("not-hex").is_err());
    }

    #[test]
    fn prefix_hit_after_fill_and_miss_before() {
        let cache = PrefixCache::new(1 << 20, metrics());
        let k = key(1, 5);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k) else {
            panic!("first lookup must be a miss");
        };
        fill.fill(snapshot(4));
        match PrefixCache::prefix(&cache, &k) {
            PrefixLookup::Hit(s) => assert_eq!(s.last_logits.len(), 8),
            PrefixLookup::Fill(_) => panic!("second lookup must hit"),
        }
        // different prompt -> different key
        assert!(matches!(PrefixCache::prefix(&cache, &key(1, 6)), PrefixLookup::Fill(_)));
        let m = cache.metrics.clone();
        assert_eq!(m.prefix_cache_hits.get(), 1);
        assert_eq!(m.prefix_cache_misses.get(), 2);
    }

    /// A snapshot's drafter KV was built at one vision compression ratio;
    /// a warm request at another ratio must miss (and fill its own entry)
    /// rather than fork drafter state from the wrong pooled sequence.
    #[test]
    fn prefix_keys_separate_drafter_vision_ratios() {
        let cache = PrefixCache::new(1 << 20, metrics());
        let k1 = key_at_ratio(3, 7, 1);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k1) else { panic!() };
        fill.fill(snapshot(4));
        assert!(matches!(PrefixCache::prefix(&cache, &k1), PrefixLookup::Hit(_)));
        // same target/drafter/image/prompt, compressed drafter view -> miss
        let k4 = key_at_ratio(3, 7, 4);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k4) else {
            panic!("ratio must be part of the prefix key");
        };
        fill.fill(snapshot(4));
        // both ratios now coexist as independent warm entries
        assert!(matches!(PrefixCache::prefix(&cache, &k1), PrefixLookup::Hit(_)));
        assert!(matches!(PrefixCache::prefix(&cache, &k4), PrefixLookup::Hit(_)));
    }

    #[test]
    fn dropped_fill_guard_reopens_the_slot() {
        let cache = PrefixCache::new(1 << 20, metrics());
        let k = key(2, 1);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k) else { panic!() };
        drop(fill); // cold prefill failed -> slot must reopen
        assert!(matches!(PrefixCache::prefix(&cache, &k), PrefixLookup::Fill(_)));
    }

    #[test]
    fn byte_budget_evicts_lru_first() {
        let m = metrics();
        let cache = PrefixCache::new(3000, m.clone());
        // each snapshot ~ 1000 bytes of KV + logits + overhead
        for i in 0..4u64 {
            let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &key(i, 0)) else {
                panic!()
            };
            fill.fill(snapshot(250));
        }
        let (bytes, entries) = cache.stats();
        assert!(bytes <= 3000, "budget violated: {bytes}");
        assert!(entries < 4, "something must have been evicted");
        assert!(m.prefix_cache_evictions.get() > 0);
        // the oldest key is gone; the newest survives
        assert!(matches!(PrefixCache::prefix(&cache, &key(0, 0)), PrefixLookup::Fill(_)));
        assert!(matches!(PrefixCache::prefix(&cache, &key(3, 0)), PrefixLookup::Hit(_)));
        assert_eq!(m.prefix_cache_bytes.get() as usize, cache.stats().0);
    }

    #[test]
    fn eviction_does_not_invalidate_outstanding_refs() {
        let cache = PrefixCache::new(64, metrics()); // everything evicts
        let (id, pixels) = cache.put_image(&[0.5f32; 256]);
        // the entry is already gone (budget 64 B), but our Arc survives
        assert!(cache.get_image(id).is_none());
        assert_eq!(pixels.len(), 256);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &key(9, 9)) else { panic!() };
        fill.fill(snapshot(64));
        let (bytes, _) = cache.stats();
        assert!(bytes <= 64);
    }

    #[test]
    fn encoding_single_flight_runs_make_once() {
        let m = metrics();
        let cache = PrefixCache::new(1 << 20, m.clone());
        let cache2 = cache.clone();
        let img = 77u64;
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let b2 = barrier.clone();
        let t = std::thread::spawn(move || {
            b2.wait();
            cache2
                .encoding(img, || Ok(VisionEncoding::Scripted { image_seed: 1 }))
                .unwrap()
        });
        barrier.wait();
        let (enc_a, _) =
            cache.encoding(img, || Ok(VisionEncoding::Scripted { image_seed: 1 })).unwrap();
        let (enc_b, _) = t.join().unwrap();
        assert_eq!(enc_a.scripted_seed(), 1);
        assert_eq!(enc_b.scripted_seed(), 1);
        assert_eq!(m.vision_encode_fills.get(), 1, "exactly one encode may run");
        assert_eq!(m.vision_encode_hits.get(), 1);
        // a failing fill propagates and reopens the slot
        assert!(cache.encoding(88, || Err(anyhow!("boom"))).is_err());
        let (_, hit) = cache
            .encoding(88, || Ok(VisionEncoding::Scripted { image_seed: 2 }))
            .unwrap();
        assert!(!hit);
    }

    #[test]
    fn image_store_round_trips_and_touches_lru() {
        let cache = PrefixCache::new(1 << 20, metrics());
        let px: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let (id, _) = cache.put_image(&px);
        assert_eq!(cache.get_image(id).unwrap().as_slice(), px.as_slice());
        assert!(cache.get_image(id ^ 1).is_none());
        // idempotent: same content, same id, no duplicate entry
        let (id2, _) = cache.put_image(&px);
        assert_eq!(id, id2);
        assert_eq!(cache.stats().1, 1);
    }

    /// Regression for the `evict_to` accounting bug: after a forced
    /// eviction storm across all three tables, the incremental `bytes`
    /// total (and the exported gauge) must equal the recomputed sum of
    /// live entry bytes -- the old `freed.unwrap_or(0)` arm could count
    /// phantom evictions without subtracting anything, letting `bytes`
    /// drift above the live total forever.
    #[test]
    fn eviction_storm_keeps_bytes_equal_to_live_entries() {
        let m = metrics();
        let cache = PrefixCache::new(4096, m.clone());
        for i in 0..40u64 {
            match i % 3 {
                0 => {
                    cache.put_image(&vec![i as f32 + 0.5; 64 + (i as usize % 7) * 32]);
                }
                1 => {
                    cache
                        .encoding(i, || Ok(VisionEncoding::Scripted { image_seed: i }))
                        .unwrap();
                }
                _ => {
                    let PrefixLookup::Fill(fill) =
                        PrefixCache::prefix(&cache, &key(i, i as i32))
                    else {
                        panic!("fresh key must miss")
                    };
                    fill.fill(snapshot(100 + (i as usize % 5) * 50));
                }
            }
            assert_eq!(
                cache.stats().0,
                cache.recount_bytes(),
                "bytes drifted from live entries at step {i}"
            );
        }
        assert!(m.prefix_cache_evictions.get() > 0, "storm must actually evict");
        let (bytes, _) = cache.stats();
        assert!(bytes <= 4096, "budget violated: {bytes}");
        assert_eq!(bytes, cache.recount_bytes());
        assert_eq!(m.prefix_cache_bytes.get() as usize, bytes);
    }

    /// The LRU clock is shared by all three tables: with hits interleaved
    /// across images/encodings/prefixes, an eviction must pick the entry
    /// whose *last touch* -- in any table -- is globally oldest.
    #[test]
    fn interleaved_touches_across_tables_evict_the_true_lru() {
        // measure the real per-entry charges first (payload + overhead)
        let probe = PrefixCache::new(1 << 20, metrics());
        let px = vec![0.25f32; 64];
        probe.put_image(&px);
        let sz_img = probe.stats().0;
        probe.encoding(7, || Ok(VisionEncoding::Scripted { image_seed: 7 })).unwrap();
        let sz_enc = probe.stats().0 - sz_img;
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&probe, &key(1, 1)) else {
            panic!()
        };
        fill.fill(snapshot(64));
        let sz_pre = probe.stats().0 - sz_img - sz_enc;

        // budget fits image + encoding + one snapshot, but adding a second
        // snapshot forces exactly one eviction
        let m = metrics();
        let cache = PrefixCache::new(sz_img + sz_enc + 2 * sz_pre - 1, m.clone());
        let (img_id, _) = cache.put_image(&px);
        cache.encoding(7, || Ok(VisionEncoding::Scripted { image_seed: 7 })).unwrap();
        let k_c = key(1, 1);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k_c) else { panic!() };
        fill.fill(snapshot(64));
        // touch the image and the prefix, leaving the ENCODING as the
        // globally least-recently-used entry
        cache.get_image(img_id).unwrap();
        assert!(matches!(PrefixCache::prefix(&cache, &k_c), PrefixLookup::Hit(_)));
        // one more snapshot -> one eviction -> the encoding must be it
        let k_d = key(2, 2);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k_d) else { panic!() };
        fill.fill(snapshot(64));
        assert_eq!(m.prefix_cache_evictions.get(), 1);
        assert!(!cache.has_encoding(7), "the cross-table LRU entry must go first");
        assert!(cache.has_image(img_id));
        assert!(cache.has_prefix(&k_c));
        assert!(cache.has_prefix(&k_d));
        assert_eq!(cache.stats().0, cache.recount_bytes());
    }

    /// Eviction racing a single-flight fill: the `Filling` slot is pinned
    /// through an eviction storm (waiters are never orphaned on the
    /// condvar), storm accounting never double-subtracts, and the fill
    /// completing after heavy eviction traffic re-inserts cleanly.
    #[test]
    fn filling_slot_survives_eviction_storm_and_waiters_resolve() {
        let m = metrics();
        let cache = PrefixCache::new(2048, m.clone());
        let k = key(500, 1);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k) else { panic!() };
        let c2 = cache.clone();
        let k2 = k.clone();
        let waiter = std::thread::spawn(move || match PrefixCache::prefix(&c2, &k2) {
            PrefixLookup::Hit(s) => s.last_logits.len(),
            PrefixLookup::Fill(_) => panic!("waiter must resolve to a hit"),
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        // storm: every insert evicts earlier Ready entries while the
        // Filling slot stays pinned
        for i in 0..30u64 {
            let PrefixLookup::Fill(f) = PrefixCache::prefix(&cache, &key(i, 0)) else {
                panic!("fresh key must miss")
            };
            f.fill(snapshot(200));
            assert_eq!(cache.stats().0, cache.recount_bytes(), "double-subtract at {i}");
        }
        assert!(m.prefix_cache_evictions.get() > 0);
        // the delayed fill publishes cleanly and wakes the waiter
        fill.fill(snapshot(64));
        assert_eq!(waiter.join().unwrap(), 8);
        assert!(cache.has_prefix(&k), "freshly filled entry must be resident");
        assert_eq!(cache.stats().0, cache.recount_bytes());
        assert!(cache.stats().0 <= 2048);
    }

    /// Paged-pool extension of the eviction story: a cached snapshot whose
    /// sequence states live in the KV block pool holds refcounts, and
    /// evicting the cache entry (the last reference) releases its blocks
    /// back to the pool.
    #[test]
    fn evicting_a_paged_snapshot_releases_its_pool_blocks() {
        use crate::kv::{KvPool, KvPoolConfig};
        let pool = KvPool::with_metrics(
            KvPoolConfig { block_words: 8, budget_bytes: 1 << 20 },
            None,
        );
        let mut st = SeqState::new(xla::Literal::vec1(&vec![1.5f32; 64]), 0, None);
        st.paginate(&pool);
        assert!(pool.blocks_used() > 0);
        let snap = Arc::new(PrefixSnapshot {
            last_logits: vec![0.0; 8],
            tstate: st,
            dstate: None,
        });
        let cache = PrefixCache::new(64, metrics()); // evicts on insert
        let k = key(9, 9);
        let PrefixLookup::Fill(fill) = PrefixCache::prefix(&cache, &k) else { panic!() };
        fill.fill(snap);
        assert!(!cache.has_prefix(&k), "tiny budget must evict immediately");
        assert_eq!(
            pool.blocks_used(),
            0,
            "dropping the cache's last snapshot ref must release its blocks"
        );
        assert_eq!(pool.bytes_used(), 0);
    }
}
