//! Paper-style table/figure rendering (plain text, fixed width) -- the
//! bench targets print these so `cargo bench` regenerates the paper's
//! artifacts as readable console/report output.

use crate::eval::CellResult;

/// Render one Table-1 style block: rows = methods, columns = tasks +
/// overall, cells = "tau (speedup)".
pub struct TableBlock {
    pub title: String,
    pub columns: Vec<String>,
    /// (method label, cells aligned with columns)
    pub rows: Vec<(String, Vec<String>)>,
}

impl TableBlock {
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        let mut label_w = "METHOD".len();
        for (label, cells) in &self.rows {
            label_w = label_w.max(label.len());
            for (i, c) in cells.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!("{:<label_w$}", "METHOD"));
        for (i, c) in self.columns.iter().enumerate() {
            out.push_str(&format!("  {:>w$}", c, w = widths[i]));
        }
        out.push('\n');
        out.push_str(&"-".repeat(label_w + widths.iter().map(|w| w + 2).sum::<usize>()));
        out.push('\n');
        for (label, cells) in &self.rows {
            out.push_str(&format!("{:<label_w$}", label));
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("  {:>w$}", c, w = widths[i]));
            }
            out.push('\n');
        }
        out
    }
}

/// "2.46 (1.00x)" cell formatting, paper style.
pub fn cell(mal: f64, speedup: f64) -> String {
    if speedup > 0.0 {
        format!("{mal:.2} ({speedup:.2}x)")
    } else {
        format!("{mal:.2}")
    }
}

/// Overall row from per-task cells (pooled by iteration counts is done
/// upstream; this averages the per-task MALs like the paper's OVERALL).
pub fn overall_mal(cells: &[CellResult]) -> f64 {
    if cells.is_empty() {
        return 0.0;
    }
    cells.iter().map(|c| c.mal).sum::<f64>() / cells.len() as f64
}

pub fn overall_wall_speedup(cells: &[CellResult]) -> f64 {
    let with = cells.iter().filter(|c| c.wall_speedup > 0.0).count();
    if with == 0 {
        return 0.0;
    }
    cells.iter().map(|c| c.wall_speedup).sum::<f64>() / with as f64
}

/// ASCII bar chart (Figures 1 and 3).
pub fn bar_chart(title: &str, bars: &[(String, f64)], unit: &str, width: usize) -> String {
    let max = bars.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-9);
    let label_w = bars.iter().map(|(l, _)| l.len()).max().unwrap_or(4);
    let mut out = format!("== {title} ==\n");
    for (label, v) in bars {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:<label_w$}  {:>7.3}{unit} |{}\n",
            label,
            v,
            "#".repeat(n)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_block_renders_aligned() {
        let t = TableBlock {
            title: "Table 1 (qwensim-L, T=0)".into(),
            columns: vec!["instruct".into(), "coco".into(), "OVERALL".into()],
            rows: vec![
                ("BASELINE".into(), vec!["2.37 (1.00x)".into(), "2.21 (1.00x)".into(), "2.46 (1.00x)".into()]),
                ("MASSV".into(), vec!["3.21 (1.24x)".into(), "3.26 (1.46x)".into(), "3.20 (1.28x)".into()]),
            ],
        };
        let s = t.render();
        assert!(s.contains("Table 1"));
        assert!(s.contains("MASSV"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns aligned: every row has same length
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    fn cell_formats() {
        assert_eq!(cell(2.455, 1.276), "2.46 (1.28x)");
        assert_eq!(cell(2.455, 0.0), "2.46");
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            "Fig 1",
            &[("coco".into(), 1.46), ("gqa".into(), 0.73)],
            "x",
            20,
        );
        let coco_bar = s.lines().find(|l| l.starts_with("coco")).unwrap();
        let gqa_bar = s.lines().find(|l| l.starts_with("gqa")).unwrap();
        let count = |l: &str| l.chars().filter(|&c| c == '#').count();
        assert_eq!(count(coco_bar), 20);
        assert_eq!(count(gqa_bar), 10);
    }
}
