//! Paper-evaluation harness: everything needed to regenerate Table 1/2/3
//! and Figures 1/3/4 (Figure 5 is rendered straight from the training
//! curves artifact).  Each bench target in benches/ is a thin wrapper over
//! these functions -- see DESIGN.md section 6 for the experiment index.

pub mod tables;

use anyhow::Result;

use crate::models::ModelSet;
use crate::spec::{sampler, GenConfig, GenStats, SpecDecoder};
use crate::stats::{tvd, FixedHistogram};
use crate::workload::EvalItem;
use std::sync::Arc;

/// Aggregate over one (target, drafter, task, temperature) cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub task: String,
    /// mean accepted length tau (tokens per target forward pass)
    pub mal: f64,
    /// measured wallclock speedup vs the non-speculative baseline
    /// (only when the baseline was run; 0.0 otherwise)
    pub wall_speedup: f64,
    /// modeled speedup tau / (1 + gamma * c) with c = measured
    /// draft-step/target-step cost ratio (hardware-independent form)
    pub model_speedup: f64,
    pub spec_decode_ms: f64,
    pub base_decode_ms: f64,
    pub n_requests: usize,
    pub tokens: usize,
}

/// Run speculative decoding over a task's eval set.
pub fn run_spec(
    models: &Arc<ModelSet>,
    target_name: &str,
    variant: &str,
    items: &[EvalItem],
    temperature: f32,
    text_only_draft: bool,
    seed: u64,
) -> Result<Vec<GenStats>> {
    let target = models.target(target_name)?;
    let drafter = models.drafter_for(target_name, variant)?;
    let mut dec = SpecDecoder::new(target, drafter);
    dec.text_only_draft = text_only_draft;
    items
        .iter()
        .enumerate()
        .map(|(i, it)| {
            let cfg = GenConfig {
                temperature,
                top_p: 1.0,
                max_new: models.manifest.gen_max,
                seed: seed.wrapping_add(i as u64),
                tree: None,
            };
            dec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg)
        })
        .collect()
}

/// Run the non-speculative target baseline over a task's eval set.
pub fn run_baseline(
    models: &Arc<ModelSet>,
    target_name: &str,
    items: &[EvalItem],
    temperature: f32,
    seed: u64,
) -> Result<Vec<GenStats>> {
    let target = models.target(target_name)?;
    items
        .iter()
        .enumerate()
        .map(|(i, it)| {
            let cfg = GenConfig {
                temperature,
                top_p: 1.0,
                max_new: models.manifest.gen_max,
                seed: seed.wrapping_add(i as u64),
                tree: None,
            };
            SpecDecoder::generate_baseline(&target, &it.image, &it.prompt_ids, it.prompt_len, &cfg)
        })
        .collect()
}

/// Pooled mean accepted length over a batch of runs (paper metric).
pub fn pooled_mal(stats: &[GenStats]) -> f64 {
    let emitted: usize = stats.iter().map(|s| s.emitted_sum).sum();
    let verifies: usize = stats.iter().map(|s| s.verify_calls).sum();
    if verifies == 0 {
        0.0
    } else {
        emitted as f64 / verifies as f64
    }
}

/// Modeled speedup: tau tokens per SD iteration, each iteration costing one
/// target verify plus one (fused) gamma-token draft.  `c` is the measured
/// cost of the draft call relative to a target forward.  The classic
/// analysis (Leviathan et al. Eq. 5 shape) adapted to the fused draft.
pub fn modeled_speedup(mal: f64, draft_cost_ratio: f64) -> f64 {
    if mal <= 0.0 {
        return 0.0;
    }
    mal / (1.0 + draft_cost_ratio)
}

/// One evaluation cell, optionally with the wallclock baseline.
#[allow(clippy::too_many_arguments)]
pub fn eval_cell(
    models: &Arc<ModelSet>,
    target_name: &str,
    variant: &str,
    task: &str,
    items: &[EvalItem],
    temperature: f32,
    text_only_draft: bool,
    with_baseline: bool,
) -> Result<CellResult> {
    // Warm the executable cache: HLO parse + compile of a cold entry point
    // costs O(seconds) and must not pollute decode-time measurements (it is
    // reported separately by micro_runtime).
    let _ = run_spec(models, target_name, variant, &items[..1.min(items.len())],
                     temperature, text_only_draft, 1)?;
    if with_baseline {
        let _ = run_baseline(models, target_name, &items[..1.min(items.len())], temperature, 1)?;
    }

    let spec = run_spec(models, target_name, variant, items, temperature, text_only_draft, 7)?;
    let mal = pooled_mal(&spec);
    let spec_ms: f64 = spec.iter().map(|s| s.decode_micros as f64 / 1000.0).sum();
    let spec_tokens: usize = spec.iter().map(|s| s.tokens.len()).sum();

    let (base_ms, base_tokens) = if with_baseline {
        let base = run_baseline(models, target_name, items, temperature, 7)?;
        (
            base.iter().map(|s| s.decode_micros as f64 / 1000.0).sum::<f64>(),
            base.iter().map(|s| s.tokens.len()).sum::<usize>(),
        )
    } else {
        (0.0, 0)
    };

    // wallclock speedup normalized per generated token (sequences can end
    // at different lengths under T>0)
    let wall_speedup = if base_ms > 0.0 && spec_ms > 0.0 && spec_tokens > 0 && base_tokens > 0 {
        (base_ms / base_tokens as f64) / (spec_ms / spec_tokens as f64)
    } else {
        0.0
    };

    // measured draft/target cost ratio from the runtime's own counters
    let c = draft_cost_ratio(models, target_name, variant);
    Ok(CellResult {
        task: task.to_string(),
        mal,
        wall_speedup,
        model_speedup: modeled_speedup(mal, c),
        spec_decode_ms: spec_ms,
        base_decode_ms: base_ms,
        n_requests: items.len(),
        tokens: spec_tokens,
    })
}

/// Measured mean(draft call) / mean(verify call) from exec counters;
/// falls back to the FLOP-derived estimate when counters are empty.
pub fn draft_cost_ratio(models: &Arc<ModelSet>, target: &str, variant: &str) -> f64 {
    let stats = models.exec_stats();
    let find = |suffix: &str| {
        stats
            .iter()
            .find(|(n, c, _)| n.ends_with(suffix) && *c > 0)
            .map(|(_, _, us)| *us)
    };
    let d = find("::draft");
    let v = find("::verify");
    let _ = (target, variant);
    match (d, v) {
        (Some(d), Some(v)) if v > 0.0 => d / v,
        _ => 0.35, // FLOP-ratio estimate for the S vs L configs
    }
}

/// Per-position TVD between the drafter's and target's next-token
/// distributions along the target's greedy trajectory (Figure 4, Eq. 6).
pub fn tvd_histogram(
    models: &Arc<ModelSet>,
    target_name: &str,
    variant: &str,
    items: &[EvalItem],
    bins: usize,
    max_positions_per_item: usize,
) -> Result<(FixedHistogram, Vec<f64>)> {
    let target = models.target(target_name)?;
    let drafter = models.drafter_for(target_name, variant)?;
    let mut hist = FixedHistogram::new(0.0, 1.0, bins);
    let mut all = Vec::new();
    let (mut pp, mut qp) = (Vec::new(), Vec::new());
    for it in items {
        let (mut plogits, mut tstate) =
            target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len)?;
        let mut dstate = drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false)?;
        let mut tok = sampler::argmax(&plogits) as i32;
        for _ in 0..max_positions_per_item {
            if tok == models.manifest.eos_id {
                break;
            }
            // advance both models on the same (target-greedy) token
            plogits = target.decode(&mut tstate, tok)?;
            let qlogits = drafter.decode(&mut dstate, tok)?;
            sampler::softmax_t(&plogits, 1.0, &mut pp);
            sampler::softmax_t(&qlogits, 1.0, &mut qp);
            let d = tvd(&pp, &qp);
            hist.record(d);
            all.push(d);
            tok = sampler::argmax(&plogits) as i32;
        }
    }
    Ok((hist, all))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_stats(per_iter: Vec<usize>) -> GenStats {
        GenStats {
            verify_calls: per_iter.len(),
            iters: per_iter.len(),
            emitted_sum: per_iter.iter().sum(),
            emitted_max: per_iter.iter().copied().max().unwrap_or(0),
            ..Default::default()
        }
    }

    #[test]
    fn pooled_mal_weights_by_iterations() {
        // request A: 2 iters emitting 3+3; request B: 1 iter emitting 1
        let s = vec![gen_stats(vec![3, 3]), gen_stats(vec![1])];
        assert!((pooled_mal(&s) - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(pooled_mal(&[]), 0.0);
    }

    #[test]
    fn modeled_speedup_shape() {
        // tau=3, free drafting -> 3x; tau=3, drafts as costly as target -> 1.5x
        assert!((modeled_speedup(3.0, 0.0) - 3.0).abs() < 1e-12);
        assert!((modeled_speedup(3.0, 1.0) - 1.5).abs() < 1e-12);
        assert_eq!(modeled_speedup(0.0, 0.3), 0.0);
    }
}
