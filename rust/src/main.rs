//! `massv` CLI: serve, generate, eval, and inspect the artifact registry.
//!
//! Subcommands:
//!   serve     start the TCP serving front-end
//!   generate  one-shot generation from the command line
//!   models    list targets/drafters in the artifact manifest
//!   eval      quick MAL evaluation of one (target, variant, task) cell
//!
//! Common options: --artifacts DIR (or $MASSV_ARTIFACTS), --target NAME.

use std::sync::Arc;

use anyhow::Result;
use massv::cluster::{ClusterConfig, ClusterEngine, RoutingPolicy};
use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};
use massv::eval::{eval_cell, tables};
use massv::models::ModelSet;
use massv::server::http::{GatewayConfig, HttpServer, Quota};
use massv::server::Server;
use massv::spec::GenConfig;
use massv::tokenizer::Tokenizer;
use massv::util::cli::Args;
use massv::workload;

const USAGE: &str = "\
massv — multimodal speculative decoding for VLMs (MASSV reproduction)

USAGE:
  massv serve    [--addr 127.0.0.1:7700] [--target qwensim-L] [--workers N]
                 [--replicas N] [--routing affinity|roundrobin|random]
                 [--http-addr 127.0.0.1:7780] [--rps N] [--burst N]
                 [--max-concurrent N] [--tenant-weights NAME=W,NAME=W...]
  massv generate --prompt \"describe the image briefly .\" [--task coco]
                 [--mode massv|massv_wo_sdvit|baseline|tree|target_only]
                 [--variant V] [--adaptive] [--temperature T] [--item N]
                 [--draft-vision-ratio R]
  massv eval     [--target qwensim-L] [--variant massv] [--task coco]
                 [--temperature 0] [--n 20]
  massv models

OPTIONS:
  --artifacts DIR   artifact directory (default: ./artifacts or $MASSV_ARTIFACTS)
";

fn main() -> Result<()> {
    let args = Args::parse(&["serve", "generate", "eval", "models"]);
    let artifacts = args
        .get("artifacts")
        .map(String::from)
        .unwrap_or_else(massv::util::artifacts_dir);

    match args.subcommand.as_deref() {
        Some("serve") => serve(&artifacts, &args),
        Some("generate") => generate(&artifacts, &args),
        Some("eval") => eval(&artifacts, &args),
        Some("models") => models(&artifacts),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn engine(artifacts: &str, args: &Args) -> Result<Engine> {
    Engine::start(
        artifacts,
        EngineConfig {
            default_target: args.get_or("target", "qwensim-L").to_string(),
            workers: args.get_usize("workers", 4),
            queue_capacity: args.get_usize("queue", 256),
            ..EngineConfig::default()
        },
    )
}

/// Parse `--tenant-weights gold=3,free=1` into scheduler weights.
fn parse_tenant_weights(spec: &str) -> Vec<(String, u32)> {
    spec.split(',')
        .filter_map(|pair| {
            let (name, w) = pair.split_once('=')?;
            Some((name.trim().to_string(), w.trim().parse::<u32>().ok()?))
        })
        .collect()
}

fn serve(artifacts: &str, args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7700");
    let replicas = args.get_usize("replicas", 1);
    let routing = match args.get_or("routing", "affinity") {
        "roundrobin" => RoutingPolicy::RoundRobin,
        "random" => RoutingPolicy::Random,
        _ => RoutingPolicy::Affinity,
    };
    let tenant_weights = parse_tenant_weights(args.get_or("tenant-weights", ""));
    // the server always fronts a ClusterEngine; replicas=1 is a single
    // engine behind a router that always picks it (docs/cluster.md)
    let cluster = Arc::new(ClusterEngine::start(
        artifacts,
        ClusterConfig {
            replicas,
            routing,
            engine: EngineConfig {
                default_target: args.get_or("target", "qwensim-L").to_string(),
                workers: args.get_usize("workers", 4),
                queue_capacity: args.get_usize("queue", 256),
                tenant_weights,
                ..EngineConfig::default()
            },
            ..ClusterConfig::default()
        },
    )?);
    // optional HTTP/SSE gateway alongside the TCP front end, sharing the
    // same cluster (docs/gateway.md)
    if let Some(http_addr) = args.get("http-addr").map(String::from) {
        let quota = Quota {
            rps: args.get_f64("rps", 0.0),
            burst: args.get_f64("burst", 0.0),
            max_concurrent: args.get_usize("max-concurrent", 0),
        };
        let http = HttpServer::new(
            cluster.clone(),
            GatewayConfig { default_quota: quota, tenant_quotas: Vec::new() },
        );
        std::thread::spawn(move || {
            if let Err(e) = http.serve(&http_addr, |a| println!("http bound {a}")) {
                eprintln!("http gateway failed: {e:#}");
            }
        });
    }
    println!(
        "massv serving on {addr} (target {}, {replicas} replica(s), {routing:?} routing)",
        args.get_or("target", "qwensim-L")
    );
    Server::new(cluster).serve(addr, |a| println!("bound {a}"))
}

fn load_item(artifacts: &str, task: &str, idx: usize) -> Result<workload::EvalItem> {
    let tok = Tokenizer::load(artifacts)?;
    let manifest = massv::manifest::Manifest::load(artifacts)?;
    let items = workload::load_task(artifacts, task, &tok, manifest.p_max)?;
    items
        .into_iter()
        .nth(idx)
        .ok_or_else(|| anyhow::anyhow!("item {idx} out of range"))
}

fn generate(artifacts: &str, args: &Args) -> Result<()> {
    let task = args.get_or("task", "coco");
    let item = load_item(artifacts, task, args.get_usize("item", 0))?;
    let eng = engine(artifacts, args)?;
    let mode = match args.get_or("mode", "massv") {
        "target_only" => DecodeMode::TargetOnly,
        "tree" => DecodeMode::Tree {
            variant: args.get_or("variant", "massv").to_string(),
            text_only_draft: args.has_flag("text-only-draft"),
            adaptive: args.has_flag("adaptive"),
        },
        v => DecodeMode::Speculative {
            variant: v.to_string(),
            text_only_draft: args.has_flag("text-only-draft"),
            adaptive: args.has_flag("adaptive"),
        },
    };
    let prompt = args.get("prompt").map(String::from).unwrap_or(item.prompt.clone());
    let req = Request {
        id: eng.next_id(),
        task: task.to_string(),
        prompt,
        image: item.image.clone(),
        image_id: None,
        target: args.get_or("target", "").to_string(),
        mode,
        gen: GenConfig {
            temperature: args.get_f64("temperature", 0.0) as f32,
            top_p: args.get_f64("top-p", 1.0) as f32,
            max_new: args.get_usize("max-new", 48),
            seed: args.get_usize("seed", 0) as u64,
            tree: None,
        },
        draft_vision_ratio: match args.get_usize("draft-vision-ratio", 0) {
            0 => None,
            r => Some(r as u32),
        },
        priority: massv::coordinator::Priority::Interactive,
        deadline_ms: None,
        tenant: massv::coordinator::DEFAULT_TENANT.into(),
    };
    let resp = eng.run(req);
    println!("prompt:    {}", item.prompt);
    println!("reference: {}", item.reference);
    println!("output:    {}", resp.text);
    println!(
        "mal {:.2} | verify calls {} | accepted {} | {:.1} ms",
        resp.mal, resp.verify_calls, resp.accepted_draft, resp.latency_ms
    );
    eng.shutdown();
    Ok(())
}

fn eval(artifacts: &str, args: &Args) -> Result<()> {
    let models = ModelSet::load(artifacts)?;
    let tok = Tokenizer::load(artifacts)?;
    let target = args.get_or("target", "qwensim-L");
    let variant = args.get_or("variant", "massv");
    let task = args.get_or("task", "coco");
    let temp = args.get_f64("temperature", 0.0) as f32;
    let n = args.get_usize("n", 20);
    let mut items = workload::load_task(artifacts, task, &tok, models.manifest.p_max)?;
    items.truncate(n);
    let cell = eval_cell(&models, target, variant, task, &items, temp, false, true)?;
    println!(
        "{target} x {variant} on {task} (T={temp}): {}",
        tables::cell(cell.mal, cell.wall_speedup)
    );
    println!(
        "  modeled speedup {:.2}x | spec {:.0} ms vs base {:.0} ms over {} reqs / {} tokens",
        cell.model_speedup, cell.spec_decode_ms, cell.base_decode_ms, cell.n_requests, cell.tokens
    );
    if args.has_flag("exec-stats") {
        let mut stats = models.exec_stats();
        stats.sort_by(|a, b| a.0.cmp(&b.0));
        for (name, calls, mean_us) in stats {
            println!("  {name:<40} calls={calls:<6} mean {mean_us:>9.1} us");
        }
    }
    Ok(())
}

fn models(artifacts: &str) -> Result<()> {
    let m = massv::manifest::Manifest::load(artifacts)?;
    println!("targets:");
    for t in &m.targets {
        println!(
            "  {:<12} family={:<8} d={} L={} ({})",
            t.name, t.family, t.d_model, t.n_layers, t.paper_analog
        );
    }
    println!("drafters:");
    for d in &m.drafters {
        println!(
            "  {:<12} variant={:<16} mm={} aligned_to={} ({})",
            d.name,
            d.variant.as_deref().unwrap_or("?"),
            d.multimodal,
            d.aligned_target.as_deref().unwrap_or("?"),
            d.paper_analog
        );
    }
    println!("gamma={} t_max={} vocab={}", m.gamma, m.t_max, m.vocab_size);
    Ok(())
}
