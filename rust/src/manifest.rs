//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.  The manifest enumerates every AOT-lowered executable
//! (weights are baked into the HLO, so a "model" is just a set of HLO text
//! files plus shape metadata).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone)]
pub struct Manifest {
    /// Execution backend: "pjrt" (compiled HLO artifacts; the default) or
    /// "scripted" (deterministic host-side model simulacra -- used by the
    /// integration tests and any environment without the PJRT runtime; see
    /// `models::scripted`).
    pub backend: String,
    pub gamma: usize,
    pub t_max: usize,
    pub p_max: usize,
    pub n_visual: usize,
    pub gen_max: usize,
    pub vocab_size: usize,
    /// Raw image tensor shape the vision tower consumes (row-major
    /// [h, w, c]); absent in older manifests, defaulting to the original
    /// hard-coded 16x16x3.
    pub image_shape: Vec<usize>,
    /// Default drafter-side vision token compression ratio (1 = the
    /// drafter consumes the full vision sequence, 4/16 = pooled views;
    /// see `docs/drafting.md`).  The target always runs at full
    /// resolution, so this knob changes drafter cost/agreement only --
    /// never emitted tokens.  Absent in older manifests, defaulting to 1.
    pub draft_vision_ratio: u32,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
    pub sep_id: i32,
    pub use_kernel: bool,
    pub targets: Vec<ModelEntry>,
    pub drafters: Vec<ModelEntry>,
}

/// One lowered model (target, or one drafter variant).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub family: String,
    pub paper_analog: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab: usize,
    pub window: Option<usize>,
    pub kv_shape: Vec<usize>,
    /// entry point name -> HLO file path relative to the artifacts dir
    pub entries: HashMap<String, String>,
    // drafter-only fields
    pub variant: Option<String>,
    pub aligned_target: Option<String>,
    pub multimodal: bool,
}

fn parse_entry(v: &Json) -> Result<ModelEntry> {
    let entries = v
        .req("entries")?
        .as_obj()?
        .iter()
        .map(|(k, e)| Ok((k.clone(), e.req("file")?.as_str()?.to_string())))
        .collect::<Result<HashMap<_, _>>>()?;
    Ok(ModelEntry {
        name: v.req("name")?.as_str()?.to_string(),
        kind: v.req("kind")?.as_str()?.to_string(),
        family: v.req("family")?.as_str()?.to_string(),
        paper_analog: v.req("paper_analog")?.as_str()?.to_string(),
        d_model: v.req("d_model")?.as_usize()?,
        n_layers: v.req("n_layers")?.as_usize()?,
        n_heads: v.req("n_heads")?.as_usize()?,
        d_head: v.req("d_head")?.as_usize()?,
        vocab: v.req("vocab")?.as_usize()?,
        window: match v.get("window") {
            Some(Json::Num(n)) => Some(*n as usize),
            _ => None,
        },
        kv_shape: v
            .req("kv_shape")?
            .as_arr()?
            .iter()
            .map(|x| x.as_usize().map_err(Into::into))
            .collect::<Result<_>>()?,
        entries,
        variant: v.get("variant").and_then(|x| x.as_str().ok()).map(String::from),
        aligned_target: v
            .get("aligned_target")
            .and_then(|x| x.as_str().ok())
            .map(String::from),
        multimodal: v
            .get("multimodal")
            .map(|x| x.as_bool().unwrap_or(false))
            .unwrap_or(true),
    })
}

impl Manifest {
    pub fn from_json(text: &str) -> Result<Manifest> {
        let v = parse(text)?;
        let schema = v.req("schema")?.as_i64()?;
        if schema != 1 {
            return Err(anyhow!("unsupported manifest schema {schema}"));
        }
        Ok(Manifest {
            backend: v
                .get("backend")
                .and_then(|b| b.as_str().ok())
                .unwrap_or("pjrt")
                .to_string(),
            gamma: v.req("gamma")?.as_usize()?,
            t_max: v.req("t_max")?.as_usize()?,
            p_max: v.req("p_max")?.as_usize()?,
            n_visual: v.req("n_visual")?.as_usize()?,
            gen_max: v.req("gen_max")?.as_usize()?,
            vocab_size: v.req("vocab_size")?.as_usize()?,
            image_shape: match v.get("image_shape") {
                Some(s) => s
                    .as_arr()?
                    .iter()
                    .map(|x| x.as_usize().map_err(Into::into))
                    .collect::<Result<_>>()?,
                None => vec![16, 16, 3],
            },
            draft_vision_ratio: match v.get("draft_vision_ratio") {
                Some(r) => (r.as_usize()? as u32).max(1),
                None => 1,
            },
            pad_id: v.req("pad_id")?.as_i64()? as i32,
            bos_id: v.req("bos_id")?.as_i64()? as i32,
            eos_id: v.req("eos_id")?.as_i64()? as i32,
            sep_id: v.req("sep_id")?.as_i64()? as i32,
            use_kernel: v.req("use_kernel")?.as_bool()?,
            targets: v
                .req("targets")?
                .as_arr()?
                .iter()
                .map(parse_entry)
                .collect::<Result<_>>()?,
            drafters: v
                .req("drafters")?
                .as_arr()?
                .iter()
                .map(parse_entry)
                .collect::<Result<_>>()?,
        })
    }

    pub fn load(artifacts_dir: &str) -> Result<Manifest> {
        Manifest::from_json(&crate::util::read_file(&format!(
            "{artifacts_dir}/manifest.json"
        ))?)
    }

    /// Total f32 elements of one raw input image (the wire/protocol and
    /// prefill layers validate against this instead of a hard-coded size).
    pub fn image_elems(&self) -> usize {
        self.image_shape.iter().product()
    }

    pub fn target(&self, name: &str) -> Result<&ModelEntry> {
        self.targets
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("unknown target model {name:?}"))
    }

    pub fn drafter(&self, name: &str, variant: &str) -> Result<&ModelEntry> {
        self.drafters
            .iter()
            .find(|d| d.name == name && d.variant.as_deref() == Some(variant))
            .ok_or_else(|| anyhow!("unknown drafter {name:?} variant {variant:?}"))
    }

    /// The drafter aligned with (trained against) a given target's family.
    pub fn drafter_for_target(&self, target: &str, variant: &str) -> Result<&ModelEntry> {
        let fam = &self.target(target)?.family;
        self.drafters
            .iter()
            .find(|d| &d.family == fam && d.variant.as_deref() == Some(variant))
            .ok_or_else(|| anyhow!("no {variant:?} drafter for family {fam:?}"))
    }

    pub fn target_names(&self) -> Vec<&str> {
        self.targets.iter().map(|t| t.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub const TOY: &str = r#"{
      "schema": 1, "gamma": 5, "t_max": 128, "p_max": 32, "n_visual": 16,
      "gen_max": 48, "vocab_size": 120, "pad_id": 0, "bos_id": 1,
      "eos_id": 2, "sep_id": 3, "use_kernel": true,
      "targets": [
        {"name": "qwensim-L", "kind": "target", "family": "qwensim",
         "paper_analog": "Qwen2.5-VL 7B Instruct", "d_model": 96,
         "n_layers": 3, "n_heads": 4, "d_head": 24, "vocab": 120,
         "window": null, "kv_shape": [3, 2, 4, 128, 24],
         "entries": {"verify": {"file": "hlo/t.verify.hlo.txt", "bytes": 10}}}
      ],
      "drafters": [
        {"name": "qwensim-S", "kind": "draft", "family": "qwensim",
         "paper_analog": "Qwen2.5-1.5B Instruct", "d_model": 48,
         "n_layers": 2, "n_heads": 4, "d_head": 12, "vocab": 120,
         "window": null, "kv_shape": [2, 2, 4, 128, 12],
         "entries": {"draft": {"file": "hlo/d.draft.hlo.txt", "bytes": 10}},
         "variant": "massv", "aligned_target": "qwensim-L", "multimodal": true}
      ]
    }"#;

    #[test]
    fn image_shape_defaults_and_parses() {
        let m = Manifest::from_json(TOY).unwrap();
        assert_eq!(m.image_shape, vec![16, 16, 3]);
        assert_eq!(m.image_elems(), 768);
        let custom =
            TOY.replacen("\"schema\": 1,", "\"schema\": 1, \"image_shape\": [8, 8, 3],", 1);
        let m = Manifest::from_json(&custom).unwrap();
        assert_eq!(m.image_shape, vec![8, 8, 3]);
        assert_eq!(m.image_elems(), 192);
    }

    #[test]
    fn draft_vision_ratio_defaults_and_parses() {
        let m = Manifest::from_json(TOY).unwrap();
        assert_eq!(m.draft_vision_ratio, 1, "older manifests default to full resolution");
        let custom = TOY.replacen("\"schema\": 1,", "\"schema\": 1, \"draft_vision_ratio\": 4,", 1);
        assert_eq!(Manifest::from_json(&custom).unwrap().draft_vision_ratio, 4);
        // a zero ratio would divide by zero downstream; clamp to 1
        let zero = TOY.replacen("\"schema\": 1,", "\"schema\": 1, \"draft_vision_ratio\": 0,", 1);
        assert_eq!(Manifest::from_json(&zero).unwrap().draft_vision_ratio, 1);
    }

    #[test]
    fn backend_defaults_to_pjrt() {
        let m = Manifest::from_json(TOY).unwrap();
        assert_eq!(m.backend, "pjrt");
        let scripted = TOY.replacen("\"schema\": 1,", "\"schema\": 1, \"backend\": \"scripted\",", 1);
        assert_eq!(Manifest::from_json(&scripted).unwrap().backend, "scripted");
    }

    #[test]
    fn parses_toy_manifest() {
        let m = Manifest::from_json(TOY).unwrap();
        assert_eq!(m.gamma, 5);
        assert_eq!(m.targets.len(), 1);
        let t = m.target("qwensim-L").unwrap();
        assert_eq!(t.kv_shape, vec![3, 2, 4, 128, 24]);
        assert_eq!(t.entries["verify"], "hlo/t.verify.hlo.txt");
        assert!(t.window.is_none());
        let d = m.drafter("qwensim-S", "massv").unwrap();
        assert_eq!(d.aligned_target.as_deref(), Some("qwensim-L"));
        assert!(d.multimodal);
        assert_eq!(
            m.drafter_for_target("qwensim-L", "massv").unwrap().name,
            "qwensim-S"
        );
    }

    #[test]
    fn unknown_lookups_error() {
        let m = Manifest::from_json(TOY).unwrap();
        assert!(m.target("nope").is_err());
        assert!(m.drafter("qwensim-S", "baseline").is_err());
        assert!(m.drafter_for_target("qwensim-L", "nope").is_err());
    }

    #[test]
    fn bad_schema_rejected() {
        let bad = TOY.replacen("\"schema\": 1", "\"schema\": 9", 1);
        assert!(Manifest::from_json(&bad).is_err());
    }
}
