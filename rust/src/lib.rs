//! # MASSV — Multimodal Adaptation and Self-Data Distillation for
//! # Speculative Decoding of Vision-Language Models
//!
//! Rust serving layer (Layer 3) of the three-layer reproduction:
//!
//! * **L1** `python/compile/kernels/` — Pallas fused-attention kernel
//!   (build time, lowered into the model HLO).
//! * **L2** `python/compile/` — JAX model families + the MASSV two-phase
//!   training pipeline (build time; produces `artifacts/`).
//! * **L3** this crate — the request path: PJRT runtime, speculative
//!   decoding engine (chain and token-tree drafting, see
//!   `docs/tree_speculation.md`; resumable per-request sessions,
//!   `spec::session`), coordinator (router/scheduler/worker pool with
//!   iteration-level continuous batching, cross-request batched model
//!   execution with a bit-identity guarantee, streaming, cancellation,
//!   and deadlines -- see `docs/serving.md`), multimodal prefix cache
//!   (content-addressed vision-encode reuse + KV snapshot forking,
//!   `cache`, see `docs/prefix_cache.md`), multi-replica scale-out with
//!   prefix-affinity routing (`cluster`, see `docs/cluster.md`), TCP
//!   server, workload + evaluation harness.  Python never runs here.
//!
//! Decoding modes (`coordinator::DecodeMode`): `Speculative` (the paper's
//! chain algorithm), `Tree` (token-tree speculation with lossless
//! multi-path verification, `spec::tree`), and `TargetOnly` (the 1.00x
//! reference).  The adaptive controller (`spec::adaptive`) switches
//! between shapes per request on acceptance/utilization EMAs.
//!
//! Backends: model execution is abstracted behind
//! `spec::{TargetBackend, DraftBackend}`; the manifest selects "pjrt"
//! (compiled HLO artifacts) or "scripted" (deterministic host-side
//! simulacra, `models::scripted`) so the full serving stack is testable
//! without the PJRT runtime.
//!
//! Quick start (after `make artifacts`):
//! ```no_run
//! use massv::coordinator::{Engine, EngineConfig, Request};
//! let engine = Engine::start("artifacts", EngineConfig::default()).unwrap();
//! let image = vec![0.0f32; 768]; // 16x16x3
//! let resp = engine.run(Request::simple(1, "describe the image briefly .", image));
//! println!("{} (mal {:.2})", resp.text, resp.mal);
//! ```

pub mod cache;
pub mod cluster;
pub mod coordinator;
pub mod eval;
pub mod kv;
pub mod manifest;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod server;
pub mod spec;
pub mod stats;
pub mod tokenizer;
pub mod util;
pub mod workload;
