//! PJRT runtime: load AOT-compiled HLO text and execute it on the hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU plugin):
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `client.compile` -> `execute`.  Adapted from /opt/xla-example/load_hlo.
//!
//! Design notes
//! * HLO **text** is the interchange format (64-bit proto ids from jax>=0.5
//!   are rejected by this XLA version; the text parser reassigns ids).
//! * Every entry point is lowered with `return_tuple=True`; execution
//!   returns one tuple buffer that we sync to host and decompose.  The KV
//!   cache therefore round-trips through host literals -- measured in the
//!   micro_runtime bench and discussed in EXPERIMENTS.md section Perf.
//! * PJRT CPU (TFRT) clients and loaded executables are thread-safe in the
//!   C++ runtime; the `xla` crate just doesn't mark them `Send`/`Sync`
//!   because they hold raw pointers.  `Exec`/`Runtime` wrap them with
//!   unsafe impls so the coordinator's worker pool can share compiled
//!   executables.  Literals are NOT shared across threads.

pub mod tensor;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

pub use tensor::{lit_f32, lit_i32, scalar_f32, scalar_i32, scalar_u32, to_vec_f32, Tensor};

/// Shared PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

// SAFETY: the TFRT CPU PjRtClient is internally synchronized; all methods
// used here (compile, buffer upload) are safe to call concurrently.  See
// module docs.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it to an executable.
    pub fn load_exec(&self, path: &str, name: &str) -> Result<Exec> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e}"))?;
        log::debug!("compiled {name} from {path} in {:?}", t0.elapsed());
        Ok(Exec {
            exe,
            name: name.to_string(),
            calls: AtomicU64::new(0),
            exec_nanos: AtomicU64::new(0),
        })
    }
}

/// A compiled entry point.  Tracks call count + cumulative latency for the
/// metrics endpoint and the section-Perf profiling.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    calls: AtomicU64,
    exec_nanos: AtomicU64,
}

// SAFETY: PJRT loaded executables support concurrent Execute calls; the
// underlying TFRT CPU executable is immutable after compilation.
unsafe impl Send for Exec {}
unsafe impl Sync for Exec {}

impl Exec {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn call(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", self.name))?;
        let mut lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("syncing output of {}: {e}", self.name))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing output of {}: {e}", self.name))?;
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(parts)
    }

    pub fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Cumulative wall time spent inside `call` (nanoseconds).
    pub fn total_nanos(&self) -> u64 {
        self.exec_nanos.load(Ordering::Relaxed)
    }

    pub fn mean_micros(&self) -> f64 {
        let c = self.call_count();
        if c == 0 {
            0.0
        } else {
            self.total_nanos() as f64 / c as f64 / 1000.0
        }
    }
}
