//! Literal construction/extraction helpers for the PJRT boundary.

use anyhow::{anyhow, Result};

/// A plain host tensor (f32, row-major) -- what the coordinator reasons
/// about; converted to/from `xla::Literal` at the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, dims: Vec<usize>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} wants {n} elems, got {}", dims, data.len()));
        }
        Ok(Tensor { data, dims })
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { data: vec![0.0; n], dims }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.dims.len(), 2);
        let w = self.dims[1];
        &self.data[i * w..(i + 1) * w]
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        lit_f32(&self.data, &self.dims)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(Tensor { data: to_vec_f32(lit)?, dims })
    }
}

pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape f32{dims:?}: {e}"))
}

pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape i32{dims:?}: {e}"))
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_u32(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32 vec: {e}"))
}

pub fn to_vec_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32 vec: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![1.0; 6], vec![2, 3]).is_ok());
        assert!(Tensor::new(vec![1.0; 5], vec![2, 3]).is_err());
    }

    #[test]
    fn tensor_row() {
        let t = Tensor::new((0..6).map(|x| x as f32).collect(), vec![2, 3]).unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn literal_round_trip() {
        let t = Tensor::new((0..24).map(|x| x as f32).collect(), vec![2, 3, 4]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_literal_round_trip() {
        let lit = lit_i32(&[1, -2, 3, 4], &[4]).unwrap();
        assert_eq!(to_vec_i32(&lit).unwrap(), vec![1, -2, 3, 4]);
    }
}
