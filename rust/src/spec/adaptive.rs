//! Adaptive speculation controller (extension beyond the paper).
//!
//! Speculation only pays when the drafter is reasonably aligned: each SD
//! iteration costs one (fused) draft call plus one verify, so with
//! per-iteration emitted tokens tau and a draft/verify cost ratio c, SD
//! beats plain decoding iff tau > 1 + c.  The paper fixes gamma = 5 and
//! always speculates; on hard prompts (or with a badly aligned drafter --
//! its own Table 2 shows MASSV-w/o-SDViT *regressing* below 1.00x) this
//! wastes the draft call.  `AdaptiveDecoder` monitors a per-request EMA of
//! emitted-tokens-per-iteration and falls back to plain target decoding
//! for the remainder of the request once the EMA drops below a break-even
//! threshold -- bounding the worst case at approximately plain-decoding
//! cost while preserving exact losslessness (both paths sample from the
//! target distribution).
//!
//! With token-tree speculation (`spec::tree`) the controller also switches
//! *between* drafting shapes per request:
//!
//!   * chain -> tree when the emitted EMA saturates the chain window
//!     (`tree_upgrade_tau`): acceptance is bottlenecked by single-path
//!     drafting, so branching can raise the ceiling;
//!   * tree -> chain when the EMA of branch utilization (accepted path
//!     length / drafted nodes) drops below `min_branch_utilization`:
//!     the extra branches are drafting work the verifier keeps throwing
//!     away.
//!
//! Every mode samples from the target distribution, so switching is
//! trajectory-safe: position bookkeeping is shared and the output stays
//! exactly lossless.  Tested against scripted mocks below; exercised end
//! to end by examples/ablation_drafting.rs and tests/tree_integration.rs.

use anyhow::Result;

use crate::spec::decoder::{DraftBackend, GenConfig, GenStats, SpecDecoder, TargetBackend};
use crate::spec::session::DecodeSession;

/// Which speculative drafting shape to run (the adaptive controller moves
/// between these, and may abandon both for plain decoding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecMode {
    Chain,
    Tree,
}

#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EMA smoothing factor for emitted-tokens-per-iteration.
    pub ema_alpha: f64,
    /// Fall back to plain decoding when the EMA drops below this
    /// (break-even is 1 + draft_cost_ratio; default assumes c ~ 0.5).
    pub min_tau: f64,
    /// Never fall back before this many SD iterations (avoid reacting to
    /// one unlucky window).
    pub patience: usize,
    /// Upgrade chain -> tree when the emitted EMA reaches this (the chain
    /// window is saturating).  `f64::INFINITY` disables upgrades.
    pub tree_upgrade_tau: f64,
    /// Downgrade tree -> chain when the branch-utilization EMA falls below
    /// this.  `0.0` disables downgrades.
    pub min_branch_utilization: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            ema_alpha: 0.5,
            min_tau: 1.5,
            patience: 3,
            tree_upgrade_tau: 4.5,
            min_branch_utilization: 0.2,
        }
    }
}

pub struct AdaptiveDecoder<T: TargetBackend, D: DraftBackend> {
    pub inner: SpecDecoder<T, D>,
    pub adaptive: AdaptiveConfig,
}

impl<T: TargetBackend, D: DraftBackend> AdaptiveDecoder<T, D> {
    pub fn new(inner: SpecDecoder<T, D>, adaptive: AdaptiveConfig) -> Self {
        AdaptiveDecoder { inner, adaptive }
    }

    /// Speculative generation with fallback, starting in chain mode
    /// (back-compat entry point).
    pub fn generate(
        &self,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        self.generate_with_mode(SpecMode::Chain, image, prompt, len, cfg)
    }

    /// Speculative generation with the full controller: starts in `start`
    /// mode, switches chain<->tree on the acceptance/utilization EMAs, and
    /// abandons speculation entirely when it stops paying.  The controller
    /// itself lives in `spec::session`; this is the blocking driver.
    pub fn generate_with_mode(
        &self,
        start: SpecMode,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        DecodeSession::new(
            &self.inner.target,
            Some(&self.inner.drafter),
            self.inner.params.clone(),
            cfg.clone(),
            Some(start),
            Some(self.adaptive.clone()),
            self.inner.text_only_draft,
        )
        .run_to_completion(image, prompt, len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::testing::{params, MockDraft, MockTarget, MockTreeDraft};
    use crate::spec::tree::TreeConfig;

    fn dec(
        script: Vec<i32>,
        dscript: Vec<i32>,
        acfg: AdaptiveConfig,
    ) -> AdaptiveDecoder<MockTarget, MockDraft> {
        AdaptiveDecoder::new(
            SpecDecoder::with_params(MockTarget::new(script), MockDraft::new(dscript), params()),
            acfg,
        )
    }

    #[test]
    fn aligned_drafter_never_falls_back() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let d = dec(script.clone(), script.clone(), AdaptiveConfig::default());
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.tokens, script);
        assert_eq!(stats.fallback_at, None);
        assert!(stats.mal() > 5.0);
    }

    #[test]
    fn hopeless_drafter_triggers_fallback_and_stays_lossless() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let d = dec(script.clone(), wrong, AdaptiveConfig::default());
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.tokens, script, "fallback must preserve the greedy output");
        assert_eq!(stats.fallback_at, Some(3), "patience=3 iterations of tau=1");
        // after fallback no more draft calls happen
        assert_eq!(stats.draft_calls, 3);
        assert!(stats.verify_calls > 3);
    }

    #[test]
    fn fallback_reduces_draft_calls_vs_plain_spec() {
        let script: Vec<i32> = (10..45).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let plain = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(wrong.clone()),
            params(),
        );
        let plain_stats = plain.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        let adaptive = dec(script, wrong, AdaptiveConfig::default());
        let ad_stats = adaptive.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(plain_stats.tokens, ad_stats.tokens);
        assert!(
            ad_stats.draft_calls < plain_stats.draft_calls,
            "adaptive {} vs plain {}",
            ad_stats.draft_calls,
            plain_stats.draft_calls
        );
    }

    #[test]
    fn patience_delays_fallback() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let d = dec(
            script,
            wrong,
            AdaptiveConfig { patience: 7, ..AdaptiveConfig::default() },
        );
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.fallback_at, Some(7));
    }

    #[test]
    fn recovering_ema_requires_sustained_agreement() {
        // drafter agrees on even-indexed windows only -> EMA hovers; with a
        // high threshold it falls back, with a low one it never does
        let script: Vec<i32> = (10..60).collect();
        let mut mixed = script.clone();
        for i in (0..mixed.len()).step_by(3) {
            mixed[i] = 99;
        }
        let low = dec(
            script.clone(),
            mixed.clone(),
            AdaptiveConfig { min_tau: 1.01, ..Default::default() },
        );
        let cfg = GenConfig { max_new: 30, ..GenConfig::default() };
        let s_low = low.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(s_low.fallback_at, None, "tau ~2 stays above 1.01");
        let high = dec(
            script,
            mixed,
            AdaptiveConfig { min_tau: 4.5, ..Default::default() },
        );
        let s_high = high.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert!(s_high.fallback_at.is_some(), "tau ~2 falls below 4.5");
        assert_eq!(s_low.tokens, s_high.tokens);
    }

    // ---------------------------------------------------- chain <-> tree

    fn tree_dec(
        script: Vec<i32>,
        branches: Vec<Vec<i32>>,
        acfg: AdaptiveConfig,
    ) -> AdaptiveDecoder<MockTarget, MockTreeDraft> {
        AdaptiveDecoder::new(
            SpecDecoder::with_params(
                MockTarget::new(script),
                MockTreeDraft::new(branches),
                params(),
            ),
            acfg,
        )
    }

    #[test]
    fn chain_upgrades_to_tree_when_window_saturates() {
        // perfectly aligned drafter: chain EMA hits 6 immediately, so after
        // `patience` iterations the controller moves to tree drafting
        let script: Vec<i32> = (10..58).collect();
        let d = tree_dec(
            script.clone(),
            vec![script.clone()],
            AdaptiveConfig::default(),
        );
        let cfg = GenConfig {
            tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
            ..GenConfig::default()
        };
        let stats = d.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens, script, "mode switches stay lossless");
        assert!(
            stats.tree_iters > 0,
            "controller should have upgraded to tree iterations"
        );
        assert!(stats.tree_iters < stats.verify_calls,
            "the first `patience` iterations ran as chain");
        assert_eq!(stats.fallback_at, None);
    }

    #[test]
    fn tree_downgrades_to_chain_on_low_utilization() {
        // branches agree with the target for 2 tokens per window then all
        // diverge: decent tau (3) but poor utilization -> back to chain,
        // without abandoning speculation
        let script: Vec<i32> = (10..58).collect();
        let mut b1 = script.clone();
        let mut b2 = script.clone();
        for i in 0..script.len() {
            if i % 6 >= 2 {
                b1[i] = 90;
                b2[i] = 91;
            }
        }
        let d = tree_dec(
            script.clone(),
            vec![b1, b2],
            AdaptiveConfig {
                min_branch_utilization: 0.6,
                min_tau: 1.01,
                ..AdaptiveConfig::default()
            },
        );
        let cfg = GenConfig {
            tree: Some(TreeConfig { branch: vec![2, 2, 2, 2, 2], max_nodes: 24 }),
            ..GenConfig::default()
        };
        let stats = d
            .generate_with_mode(SpecMode::Tree, &[], &[0; 8], 3, &cfg)
            .unwrap();
        assert_eq!(stats.tokens, script, "downgrade stays lossless");
        let tree_iters = stats.tree_iters;
        assert!(tree_iters >= 3, "ran at least `patience` tree iterations");
        assert!(
            tree_iters < stats.verify_calls,
            "later iterations must have run as chain ({} of {})",
            tree_iters,
            stats.verify_calls
        );
        assert_eq!(stats.fallback_at, None, "speculation itself kept paying");
    }

    #[test]
    fn tree_start_matches_plain_tree_decoder_when_stable() {
        // with comfortable thresholds the adaptive tree path must equal the
        // plain tree decoder exactly at T=0
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let mut alt = script.clone();
        for i in (1..alt.len()).step_by(4) {
            alt[i] = 77;
        }
        let cfg = GenConfig {
            tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
            ..GenConfig::default()
        };
        let plain = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![script.clone(), alt.clone()]),
            params(),
        )
        .generate_tree(&[], &[0; 8], 3, &cfg)
        .unwrap();
        let adaptive = tree_dec(
            script.clone(),
            vec![script, alt],
            AdaptiveConfig {
                min_branch_utilization: 0.0,
                min_tau: 0.0,
                ..AdaptiveConfig::default()
            },
        );
        let stats = adaptive
            .generate_with_mode(SpecMode::Tree, &[], &[0; 8], 3, &cfg)
            .unwrap();
        assert_eq!(stats.tokens, plain.tokens);
        assert!(stats.same_generation(&plain));
        assert_eq!(stats.tree_nodes_drafted, plain.tree_nodes_drafted);
    }
}
