//! Adaptive speculation controller (extension beyond the paper).
//!
//! Speculation only pays when the drafter is reasonably aligned: each SD
//! iteration costs one (fused) draft call plus one verify, so with
//! per-iteration emitted tokens tau and a draft/verify cost ratio c, SD
//! beats plain decoding iff tau > 1 + c.  The paper fixes gamma = 5 and
//! always speculates; on hard prompts (or with a badly aligned drafter --
//! its own Table 2 shows MASSV-w/o-SDViT *regressing* below 1.00x) this
//! wastes the draft call.  `AdaptiveDecoder` monitors a per-request EMA of
//! emitted-tokens-per-iteration and falls back to plain target decoding
//! for the remainder of the request once the EMA drops below a break-even
//! threshold -- bounding the worst case at approximately plain-decoding
//! cost while preserving exact losslessness (both paths sample from the
//! target distribution).
//!
//! Tested against scripted mocks below; exercised end-to-end by
//! examples/ablation_drafting.rs.

use anyhow::Result;

use crate::spec::decoder::{
    generate_baseline, sample_token, DraftBackend, GenConfig, GenStats, SpecDecoder, SpecParams,
    TargetBackend,
};
use crate::spec::acceptance::{accept_stochastic, Scratch};
use crate::util::rng::Rng;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// EMA smoothing factor for emitted-tokens-per-iteration.
    pub ema_alpha: f64,
    /// Fall back to plain decoding when the EMA drops below this
    /// (break-even is 1 + draft_cost_ratio; default assumes c ~ 0.5).
    pub min_tau: f64,
    /// Never fall back before this many SD iterations (avoid reacting to
    /// one unlucky window).
    pub patience: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { ema_alpha: 0.5, min_tau: 1.5, patience: 3 }
    }
}

pub struct AdaptiveDecoder<T: TargetBackend, D: DraftBackend> {
    pub inner: SpecDecoder<T, D>,
    pub adaptive: AdaptiveConfig,
}

impl<T: TargetBackend, D: DraftBackend> AdaptiveDecoder<T, D> {
    pub fn new(inner: SpecDecoder<T, D>, adaptive: AdaptiveConfig) -> Self {
        AdaptiveDecoder { inner, adaptive }
    }

    /// Speculative generation with fallback.  Mirrors
    /// `SpecDecoder::generate` but tracks the acceptance EMA and switches
    /// to target-only decoding mid-request when speculation stops paying.
    pub fn generate(
        &self,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        let p: &SpecParams = &self.inner.params;
        let eos = p.eos_id;
        let mut rng = Rng::seeded(cfg.seed);
        let mut scratch = Scratch::default();
        let mut stats = GenStats::default();
        let max_new = cfg.max_new.min(p.gen_max);

        let t0 = Instant::now();
        let (last_logits, mut tstate) = self.inner.target.prefill(image, prompt, len)?;
        let mut dstate = self
            .inner
            .drafter
            .prefill(Some(image), prompt, len, self.inner.text_only_draft)?;
        stats.prefill_micros = t0.elapsed().as_micros() as u64;

        let td = Instant::now();
        let mut probs = Vec::new();
        let t0_tok = sample_token(&last_logits, cfg, &mut probs, &mut rng);
        stats.tokens.push(t0_tok);
        if t0_tok == eos {
            stats.finished_by_eos = true;
            stats.decode_micros = td.elapsed().as_micros() as u64;
            return Ok(stats);
        }

        let mut last = t0_tok;
        let mut ema: Option<f64> = None;
        let mut speculating = true;

        'outer: while stats.tokens.len() < max_new {
            if speculating {
                let seed = rng.next_u32();
                let out = self.inner.drafter.draft(&mut dstate, last, cfg.temperature, seed)?;
                stats.draft_calls += 1;
                let mut vtokens = Vec::with_capacity(p.gamma + 1);
                vtokens.push(last);
                vtokens.extend_from_slice(&out.tokens);
                let plogits = self.inner.target.verify(&mut tstate, &vtokens)?;
                stats.verify_calls += 1;
                let dec = accept_stochastic(
                    &out.tokens, &out.qlogits, &plogits,
                    cfg.temperature, cfg.top_p, &mut rng, &mut scratch,
                );

                let mut emitted = 0usize;
                for &tok in &out.tokens[..dec.accepted] {
                    stats.tokens.push(tok);
                    emitted += 1;
                    if tok == eos {
                        stats.finished_by_eos = true;
                        stats.accepted_draft += emitted;
                        stats.per_iter_emitted.push(emitted);
                        break 'outer;
                    }
                    if stats.tokens.len() >= max_new {
                        stats.accepted_draft += emitted;
                        stats.per_iter_emitted.push(emitted);
                        break 'outer;
                    }
                }
                stats.accepted_draft += emitted;
                stats.tokens.push(dec.next_token);
                emitted += 1;
                stats.per_iter_emitted.push(emitted);
                if dec.next_token == eos {
                    stats.finished_by_eos = true;
                    break;
                }
                tstate.pos += 1 + dec.accepted as i32;
                dstate.pos += 1 + dec.accepted as i32;
                last = dec.next_token;

                // controller update
                let a = self.adaptive.ema_alpha;
                ema = Some(match ema {
                    None => emitted as f64,
                    Some(e) => a * emitted as f64 + (1.0 - a) * e,
                });
                if stats.verify_calls >= self.adaptive.patience
                    && ema.unwrap() < self.adaptive.min_tau
                {
                    speculating = false;
                    stats.fallback_at = Some(stats.verify_calls);
                    // the target cache holds the accepted prefix; continue
                    // decoding from `last` at tstate.pos (write position)
                }
            } else {
                // plain target decoding for the rest of the request
                let logits = self.inner.target.decode(&mut tstate, last)?;
                stats.verify_calls += 1;
                let tok = sample_token(&logits, cfg, &mut probs, &mut rng);
                stats.tokens.push(tok);
                stats.per_iter_emitted.push(1);
                if tok == eos {
                    stats.finished_by_eos = true;
                    break;
                }
                last = tok;
            }
        }
        stats.decode_micros = td.elapsed().as_micros() as u64;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::testing::{params, MockDraft, MockTarget};

    fn dec(
        script: Vec<i32>,
        dscript: Vec<i32>,
        acfg: AdaptiveConfig,
    ) -> AdaptiveDecoder<MockTarget, MockDraft> {
        AdaptiveDecoder::new(
            SpecDecoder::with_params(MockTarget::new(script), MockDraft::new(dscript), params()),
            acfg,
        )
    }

    #[test]
    fn aligned_drafter_never_falls_back() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let d = dec(script.clone(), script.clone(), AdaptiveConfig::default());
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.tokens, script);
        assert_eq!(stats.fallback_at, None);
        assert!(stats.mal() > 5.0);
    }

    #[test]
    fn hopeless_drafter_triggers_fallback_and_stays_lossless() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let d = dec(script.clone(), wrong, AdaptiveConfig::default());
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.tokens, script, "fallback must preserve the greedy output");
        assert_eq!(stats.fallback_at, Some(3), "patience=3 iterations of tau=1");
        // after fallback no more draft calls happen
        assert_eq!(stats.draft_calls, 3);
        assert!(stats.verify_calls > 3);
    }

    #[test]
    fn fallback_reduces_draft_calls_vs_plain_spec() {
        let script: Vec<i32> = (10..45).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let plain = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(wrong.clone()),
            params(),
        );
        let plain_stats = plain.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        let adaptive = dec(script, wrong, AdaptiveConfig::default());
        let ad_stats = adaptive.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(plain_stats.tokens, ad_stats.tokens);
        assert!(
            ad_stats.draft_calls < plain_stats.draft_calls,
            "adaptive {} vs plain {}",
            ad_stats.draft_calls,
            plain_stats.draft_calls
        );
    }

    #[test]
    fn patience_delays_fallback() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let wrong: Vec<i32> = (50..99).collect();
        let d = dec(
            script,
            wrong,
            AdaptiveConfig { patience: 7, ..AdaptiveConfig::default() },
        );
        let stats = d.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
        assert_eq!(stats.fallback_at, Some(7));
    }

    #[test]
    fn recovering_ema_requires_sustained_agreement() {
        // drafter agrees on even-indexed windows only -> EMA hovers; with a
        // high threshold it falls back, with a low one it never does
        let script: Vec<i32> = (10..60).collect();
        let mut mixed = script.clone();
        for i in (0..mixed.len()).step_by(3) {
            mixed[i] = 99;
        }
        let low = dec(
            script.clone(),
            mixed.clone(),
            AdaptiveConfig { min_tau: 1.01, ..Default::default() },
        );
        let mut cfg = GenConfig::default();
        cfg.max_new = 30;
        let s_low = low.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(s_low.fallback_at, None, "tau ~2 stays above 1.01");
        let high = dec(
            script,
            mixed,
            AdaptiveConfig { min_tau: 4.5, ..Default::default() },
        );
        let s_high = high.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert!(s_high.fallback_at.is_some(), "tau ~2 falls below 4.5");
        assert_eq!(s_low.tokens, s_high.tokens);
    }
}
