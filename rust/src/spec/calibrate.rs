//! Online speculation calibration (the serving-time half of MASSV's
//! self-data distillation loop).
//!
//! `spec::adaptive` reacts *within* one request: per-request EMAs decide
//! fallback and chain<->tree switches, then the state dies with the
//! session.  This module learns *across* requests: every speculative
//! iteration reports an `IterObs` (how many tokens were drafted, how many
//! the target accepted, which workload class the request belongs to,
//! whether its image was a cache reuse), and the `Calibrator` maintains a
//! per-class EWMA estimate of the per-token acceptance probability
//! alpha.  From alpha it derives the two serving-time recommendations the
//! engine asks for when admitting the next request of that class:
//!
//!   * `gamma_for(class)`: the draft length maximizing expected emitted
//!     tokens per unit cost.  With per-token acceptance alpha, a chain
//!     window of gamma drafts emits E(gamma) = (1 - alpha^(gamma+1)) /
//!     (1 - alpha) tokens in expectation (accepted prefix + the
//!     correction/bonus token); one iteration costs `1 + gamma * c`
//!     verifies where `c` is the per-token draft/verify cost ratio.  The
//!     calibrator picks argmax over [gamma_min, gamma_max] of
//!     E(gamma) / (1 + gamma * c) -- the standard speculative-decoding
//!     throughput model.
//!   * `mode_for(class)`: chain vs tree drafting, from an EWMA of the
//!     accepted length per iteration with hysteresis (upgrade to tree when
//!     the chain window saturates, downgrade when acceptance collapses) --
//!     the cross-request analogue of the adaptive controller's in-request
//!     switch.
//!
//! Both recommendations stay at their engine defaults until a class has
//! `min_obs` observations, so cold classes behave exactly like an
//! uncalibrated engine.  Recommendations only change *drafting* shape --
//! acceptance still only depends on target logits, so calibration can
//! never alter the emitted stream of any single request, only how cheaply
//! it is produced.
//!
//! The same observations can be streamed to a JSONL file
//! (`log_jsonl_to`), one record per iteration -- the training-data export
//! `python/compile/selfdistill.py` consumes to build self-distillation
//! fine-tuning sets from live traffic.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::spec::adaptive::SpecMode;

/// One speculative iteration's acceptance outcome, as reported by
/// `DecodeSession` (`set_telemetry`).
#[derive(Debug, Clone)]
pub struct IterObs {
    /// Workload class of the owning request (`Request::task`).
    pub class: Arc<str>,
    /// Drafting shape the iteration ran under.
    pub mode: SpecMode,
    /// Tokens drafted this iteration (chain: the gamma window; tree: the
    /// configured depth).
    pub drafted: usize,
    /// Tokens the target accepted (chain: accepted prefix; tree: accepted
    /// root-to-leaf path length).
    pub accepted: usize,
    /// Whether the owning request's image was served from the prefix
    /// cache (reused images correlate with higher drafter agreement).
    pub image_reuse: bool,
}

fn mode_name(mode: SpecMode) -> &'static str {
    match mode {
        SpecMode::Chain => "chain",
        SpecMode::Tree => "tree",
    }
}

#[derive(Debug, Clone)]
pub struct CalibratorConfig {
    /// EWMA smoothing for the per-token acceptance estimate (weight of
    /// one new Bernoulli outcome).
    pub ema_alpha: f64,
    /// Per-token draft cost relative to one target verify (the `c` in the
    /// throughput model).
    pub draft_cost: f64,
    /// Observations a class needs before recommendations deviate from the
    /// engine defaults.
    pub min_obs: usize,
    /// Recommended gamma is clamped to [gamma_min, gamma_max].
    pub gamma_min: usize,
    pub gamma_max: usize,
    /// Upgrade a class to tree drafting when its accepted-length EWMA
    /// reaches this ...
    pub tree_tau: f64,
    /// ... and back to chain when it falls below this (< tree_tau, so the
    /// recommendation cannot flap on boundary noise).
    pub chain_tau: f64,
}

impl Default for CalibratorConfig {
    fn default() -> Self {
        CalibratorConfig {
            ema_alpha: 0.05,
            draft_cost: 0.15,
            min_obs: 16,
            gamma_min: 1,
            gamma_max: 8,
            tree_tau: 3.5,
            chain_tau: 2.0,
        }
    }
}

/// Per-class running state.
#[derive(Debug, Clone)]
struct ClassStats {
    /// EWMA per-token acceptance probability.
    alpha: f64,
    /// EWMA accepted length per iteration.
    acc_len: f64,
    /// Iterations observed.
    obs: usize,
    /// Iterations observed with a cache-reused image.
    reuse_obs: usize,
    /// Current chain/tree recommendation (hysteresis state).
    tree: bool,
}

impl ClassStats {
    fn new() -> Self {
        ClassStats { alpha: 0.5, acc_len: 0.0, obs: 0, reuse_obs: 0, tree: false }
    }
}

/// Read-only view of one class's calibration state (metrics export).
#[derive(Debug, Clone)]
pub struct ClassSnapshot {
    pub class: String,
    pub alpha: f64,
    pub accepted_len_ema: f64,
    pub obs: usize,
    pub reuse_obs: usize,
    pub gamma: usize,
    pub tree: bool,
    /// Whether the class has enough observations to steer admissions.
    pub warmed: bool,
}

/// Cross-request acceptance-driven speculation calibrator (shared by all
/// engine workers via `Arc`).
pub struct Calibrator {
    cfg: CalibratorConfig,
    /// Gamma recommended while a class is still warming up.
    default_gamma: usize,
    classes: Mutex<HashMap<Arc<str>, ClassStats>>,
    jsonl: Mutex<Option<BufWriter<File>>>,
}

impl Calibrator {
    pub fn new(cfg: CalibratorConfig, default_gamma: usize) -> Self {
        Calibrator {
            cfg,
            default_gamma,
            classes: Mutex::new(HashMap::new()),
            jsonl: Mutex::new(None),
        }
    }

    /// Also append every observation to `path` as one JSON object per
    /// line (the selfdistill.py training-data export).
    pub fn log_jsonl_to(&self, path: &Path) -> Result<()> {
        let f = File::create(path)?;
        *self.jsonl.lock().unwrap() = Some(BufWriter::new(f));
        Ok(())
    }

    /// Flush the JSONL buffer (tests / graceful shutdown; dropping the
    /// calibrator also flushes).
    pub fn flush_jsonl(&self) {
        if let Some(w) = self.jsonl.lock().unwrap().as_mut() {
            let _ = w.flush();
        }
    }

    /// Fold one iteration's outcome into its class EWMA state.
    pub fn observe(&self, obs: &IterObs) {
        if obs.drafted == 0 {
            return;
        }
        {
            let mut classes = self.classes.lock().unwrap();
            let st = classes.entry(obs.class.clone()).or_insert_with(ClassStats::new);
            let w = self.cfg.ema_alpha;
            // the iteration is `accepted` per-token successes, plus one
            // rejection when the window was cut short -- full-window
            // acceptances carry no rejection evidence
            let accepted = obs.accepted.min(obs.drafted);
            for _ in 0..accepted {
                st.alpha = w + (1.0 - w) * st.alpha;
            }
            if accepted < obs.drafted {
                st.alpha = (1.0 - w) * st.alpha;
            }
            st.acc_len = if st.obs == 0 {
                accepted as f64
            } else {
                w * accepted as f64 + (1.0 - w) * st.acc_len
            };
            st.obs += 1;
            if obs.image_reuse {
                st.reuse_obs += 1;
            }
            if st.obs >= self.cfg.min_obs {
                // hysteresis: saturating acceptance upgrades to tree,
                // collapsed acceptance downgrades to chain
                if !st.tree && st.acc_len >= self.cfg.tree_tau {
                    st.tree = true;
                } else if st.tree && st.acc_len < self.cfg.chain_tau {
                    st.tree = false;
                }
            }
        }
        let mut jsonl = self.jsonl.lock().unwrap();
        if let Some(w) = jsonl.as_mut() {
            // classes come from Request::task (protocol-validated short
            // strings); escape the two JSON-significant characters anyway
            let class = obs.class.replace('\\', "\\\\").replace('"', "\\\"");
            let _ = writeln!(
                w,
                "{{\"class\":\"{}\",\"mode\":\"{}\",\"drafted\":{},\"accepted\":{},\"image_reuse\":{}}}",
                class,
                mode_name(obs.mode),
                obs.drafted,
                obs.accepted,
                obs.image_reuse
            );
        }
    }

    /// Expected emitted tokens per iteration for draft length `gamma`
    /// under per-token acceptance `alpha`.
    fn expected_emitted(alpha: f64, gamma: usize) -> f64 {
        if (1.0 - alpha).abs() < 1e-9 {
            return gamma as f64 + 1.0;
        }
        (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
    }

    /// Throughput-optimal gamma for acceptance `alpha` under this config's
    /// cost model (deterministic argmax over the clamped range).
    fn best_gamma(&self, alpha: f64) -> usize {
        let mut best = self.cfg.gamma_min;
        let mut best_score = f64::MIN;
        for g in self.cfg.gamma_min..=self.cfg.gamma_max {
            let score =
                Self::expected_emitted(alpha, g) / (1.0 + g as f64 * self.cfg.draft_cost);
            if score > best_score {
                best_score = score;
                best = g;
            }
        }
        best
    }

    /// Recommended draft length for `class` (the engine default until the
    /// class warms up).
    pub fn gamma_for(&self, class: &str) -> usize {
        let classes = self.classes.lock().unwrap();
        match classes.get(class) {
            Some(st) if st.obs >= self.cfg.min_obs => self.best_gamma(st.alpha),
            _ => self.default_gamma,
        }
    }

    /// Recommended drafting shape for `class`; `None` while the class is
    /// still warming up (the engine keeps the request's own mode).
    pub fn mode_for(&self, class: &str) -> Option<SpecMode> {
        let classes = self.classes.lock().unwrap();
        match classes.get(class) {
            Some(st) if st.obs >= self.cfg.min_obs => {
                Some(if st.tree { SpecMode::Tree } else { SpecMode::Chain })
            }
            _ => None,
        }
    }

    /// Per-class state for the metrics scrape, sorted by class name for a
    /// deterministic render.
    pub fn snapshot(&self) -> Vec<ClassSnapshot> {
        let classes = self.classes.lock().unwrap();
        let mut out: Vec<ClassSnapshot> = classes
            .iter()
            .map(|(class, st)| ClassSnapshot {
                class: class.to_string(),
                alpha: st.alpha,
                accepted_len_ema: st.acc_len,
                obs: st.obs,
                reuse_obs: st.reuse_obs,
                gamma: if st.obs >= self.cfg.min_obs {
                    self.best_gamma(st.alpha)
                } else {
                    self.default_gamma
                },
                tree: st.tree,
                warmed: st.obs >= self.cfg.min_obs,
            })
            .collect();
        out.sort_by(|a, b| a.class.cmp(&b.class));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(class: &str, drafted: usize, accepted: usize) -> IterObs {
        IterObs {
            class: Arc::from(class),
            mode: SpecMode::Chain,
            drafted,
            accepted,
            image_reuse: false,
        }
    }

    fn cal() -> Calibrator {
        Calibrator::new(CalibratorConfig::default(), 5)
    }

    #[test]
    fn warmup_keeps_engine_defaults() {
        let c = cal();
        assert_eq!(c.gamma_for("chat"), 5);
        assert_eq!(c.mode_for("chat"), None);
        for _ in 0..CalibratorConfig::default().min_obs - 1 {
            c.observe(&obs("chat", 5, 5));
        }
        assert_eq!(c.gamma_for("chat"), 5, "one short of min_obs stays default");
        assert_eq!(c.mode_for("chat"), None);
        c.observe(&obs("chat", 5, 5));
        assert_ne!(c.mode_for("chat"), None, "min_obs-th observation warms the class");
    }

    #[test]
    fn gamma_converges_to_known_optimum_on_synthetic_traces() {
        // perfect acceptance -> alpha -> 1 -> E/(1+gc) is increasing in g
        // for small c, so the optimum is gamma_max
        let c = cal();
        for _ in 0..400 {
            c.observe(&obs("caption", 8, 8));
        }
        assert_eq!(c.gamma_for("caption"), CalibratorConfig::default().gamma_max);

        // zero acceptance -> alpha -> 0 -> every drafted token is wasted
        // cost, so the optimum is gamma_min
        let c = cal();
        for _ in 0..400 {
            c.observe(&obs("doc", 8, 0));
        }
        assert_eq!(c.gamma_for("doc"), CalibratorConfig::default().gamma_min);

        // the analytic optimum for a converged mid alpha must match a
        // brute-force argmax of the same objective
        let c = Calibrator::new(
            CalibratorConfig { ema_alpha: 0.02, ..CalibratorConfig::default() },
            5,
        );
        // alternating 3-of-5 acceptance: alpha settles near its fixed
        // point; whatever it is, gamma_for must equal the model's argmax
        for _ in 0..600 {
            c.observe(&obs("mix", 5, 3));
        }
        let snap = &c.snapshot()[0];
        assert!(snap.warmed);
        assert!(snap.alpha > 0.4 && snap.alpha < 0.95, "alpha {}", snap.alpha);
        let cfg = CalibratorConfig { ema_alpha: 0.02, ..CalibratorConfig::default() };
        let brute = (cfg.gamma_min..=cfg.gamma_max)
            .max_by(|&a, &b| {
                let s = |g: usize| {
                    Calibrator::expected_emitted(snap.alpha, g)
                        / (1.0 + g as f64 * cfg.draft_cost)
                };
                s(a).partial_cmp(&s(b)).unwrap()
            })
            .unwrap();
        assert_eq!(c.gamma_for("mix"), brute);
        // monotonicity: a better-aligned class never gets a shorter window
        assert!(c.gamma_for("mix") <= CalibratorConfig::default().gamma_max);
    }

    #[test]
    fn classes_stay_independent_under_mixing() {
        // interleave a high-acceptance and a zero-acceptance class: each
        // must converge to its own optimum with no cross-contamination,
        // and stay there as mixing continues
        let c = cal();
        for _ in 0..300 {
            c.observe(&obs("chat", 6, 6));
            c.observe(&obs("doc", 6, 0));
        }
        let chat_gamma = c.gamma_for("chat");
        let doc_gamma = c.gamma_for("doc");
        assert_eq!(chat_gamma, CalibratorConfig::default().gamma_max);
        assert_eq!(doc_gamma, CalibratorConfig::default().gamma_min);
        // stability: more mixed traffic must not move either class
        for _ in 0..300 {
            c.observe(&obs("chat", 6, 6));
            c.observe(&obs("doc", 6, 0));
        }
        assert_eq!(c.gamma_for("chat"), chat_gamma);
        assert_eq!(c.gamma_for("doc"), doc_gamma);
        assert_eq!(c.mode_for("chat"), Some(SpecMode::Tree));
        assert_eq!(c.mode_for("doc"), Some(SpecMode::Chain));
    }

    #[test]
    fn mode_hysteresis_does_not_flap() {
        let c = cal();
        // saturate -> tree
        for _ in 0..100 {
            c.observe(&obs("chat", 5, 5));
        }
        assert_eq!(c.mode_for("chat"), Some(SpecMode::Tree));
        // hover between chain_tau and tree_tau: the recommendation must
        // hold (no downgrade above chain_tau)
        for _ in 0..200 {
            c.observe(&obs("chat", 5, 3));
        }
        assert_eq!(c.mode_for("chat"), Some(SpecMode::Tree));
        // collapse -> chain
        for _ in 0..200 {
            c.observe(&obs("chat", 5, 0));
        }
        assert_eq!(c.mode_for("chat"), Some(SpecMode::Chain));
    }

    #[test]
    fn jsonl_export_writes_one_record_per_observation() {
        let dir = std::env::temp_dir().join(format!("massv_calib_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("telemetry.jsonl");
        let c = cal();
        c.log_jsonl_to(&path).unwrap();
        c.observe(&obs("chat", 5, 3));
        c.observe(&IterObs {
            class: Arc::from("caption"),
            mode: SpecMode::Tree,
            drafted: 5,
            accepted: 5,
            image_reuse: true,
        });
        c.flush_jsonl();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"class\":\"chat\""));
        assert!(lines[0].contains("\"mode\":\"chain\""));
        assert!(lines[0].contains("\"drafted\":5"));
        assert!(lines[0].contains("\"accepted\":3"));
        assert!(lines[0].contains("\"image_reuse\":false"));
        assert!(lines[1].contains("\"mode\":\"tree\""));
        assert!(lines[1].contains("\"image_reuse\":true"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_tracks_reuse_and_sorts_classes() {
        let c = cal();
        c.observe(&IterObs {
            class: Arc::from("b"),
            mode: SpecMode::Chain,
            drafted: 5,
            accepted: 2,
            image_reuse: true,
        });
        c.observe(&obs("a", 5, 2));
        let snap = c.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].class, "a");
        assert_eq!(snap[1].class, "b");
        assert_eq!(snap[1].reuse_obs, 1);
        assert_eq!(snap[0].reuse_obs, 0);
        assert!(!snap[0].warmed);
    }
}
