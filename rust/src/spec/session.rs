//! Resumable decode sessions: the speculative decoding loop split into an
//! explicit state machine so the serving engine can interleave many
//! requests at *iteration* granularity (continuous batching).
//!
//! A `DecodeSession` owns everything one in-flight request needs between
//! speculative iterations -- both models' `SeqState`s, the sampler RNG,
//! acceptance scratch, the adaptive controller, and the partial `GenStats`
//! -- and exposes exactly two operations:
//!
//!   * `prefill(image, prompt, len)` runs both prefills and samples the
//!     "free" first token;
//!   * `step()` runs ONE speculative iteration (draft -> verify -> accept,
//!     or a single plain decode for target-only / post-fallback sessions).
//!
//! Both return `StepOutcome`: `Emitted(tokens)` while the request is still
//! running (the newly produced tokens, ready to stream), or
//! `Finished(stats)` when the request terminated (EOS or token budget).
//! Between calls the session is inert and can sit in a queue -- which is
//! what lets one worker serve a short interactive request in the gaps of a
//! long batch decode instead of parking a thread per request.
//!
//! `step()` is itself composed of two resumable *half-steps* so the engine
//! can gang model passes across sessions (`coordinator::engine`'s batched
//! tick, `docs/serving.md`):
//!
//!   * `propose()` stages one iteration: it draws the per-iteration draft
//!     seed from the session RNG and records what the models owe this lane
//!     (a drafter pass for chain/tree lanes, then a target pass);
//!   * `absorb_decode` / `absorb_verify` consume the target's logits and
//!     run acceptance, emission, cache-position bookkeeping, and the
//!     adaptive-controller update.
//!
//! Between the halves the engine extracts per-lane model arguments
//! (`chain_draft_parts`, `plain_verify_parts`, ...) and runs the fused
//! batched entry points (`TargetBackend::verify_batch` et al).  All
//! cross-iteration state -- the RNG, both `SeqState`s, the adaptive EMAs --
//! is per-session, so batched execution consumes exactly the same RNG
//! draws and produces exactly the same tokens as sequential `step()`
//! loops: the bit-identity property `spec::testing::
//! run_batched_vs_sequential` checks.
//!
//! The run-to-completion entry points (`SpecDecoder::generate`,
//! `generate_tree`, `AdaptiveDecoder::generate_with_mode`,
//! `generate_baseline`) are thin drivers over this state machine, so the
//! decoder-level losslessness property tests in `spec::decoder` and
//! `spec::adaptive` pin the session semantics: token streams, RNG draws,
//! and every `GenStats` field are identical to the pre-session loops.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::kv::KvPool;
use crate::models::{DraftModel, DraftOutput, PrefixSnapshot, SeqState, TargetModel, VisionEncoding};
use crate::runtime::Tensor;
use crate::spec::acceptance::{accept_stochastic, accept_tree_stochastic, Scratch};
use crate::spec::adaptive::{AdaptiveConfig, SpecMode};
use crate::spec::calibrate::{Calibrator, IterObs};
use crate::spec::decoder::{
    sample_token, DraftBackend, GenConfig, GenStats, SpecParams, TargetBackend,
};
use crate::spec::tree::{DraftTree, TreeConfig};
use crate::util::rng::Rng;

/// Target-pass shape of a session's next decode step.  The engine's batch
/// planner gangs lanes of the same kind (and the same model identity) into
/// one fused pass; the kind only changes inside `absorb_*` (the adaptive
/// controller), never between scheduling and execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LaneKind {
    /// One plain target decode (target-only sessions, post-fallback).
    Plain,
    /// Chain speculation: a fused gamma-draft then a (gamma+1)-window
    /// verify.
    Chain,
    /// Tree speculation: a branching draft then a flattened tree verify.
    Tree,
}

/// In-flight half-step state between `propose()` and `absorb_*`.
enum Pending {
    None,
    /// `propose()` staged a drafter pass (chain/tree lanes): the drafter
    /// owes a draft from `last` under this per-iteration `seed`.
    AwaitDraft { last: i32, seed: u32 },
    /// Plain lane: the target owes one decode of `last`.
    VerifyPlain { last: i32 },
    /// Chain lane: the target owes a verify of `vtokens` (= `last` + the
    /// drafted window); `out` is retained for acceptance.
    VerifyChain { vtokens: Vec<i32>, out: DraftOutput },
    /// Tree lane: the target owes a flattened tree verify.
    VerifyTree { last: i32, tree: DraftTree },
}

/// Result of one `prefill`/`step` call.
#[derive(Debug)]
pub enum StepOutcome {
    /// The request is still running; these are the tokens this call
    /// emitted (already appended to the session's `GenStats::tokens`).
    Emitted(Vec<i32>),
    /// The request terminated; the full generation record (the final
    /// iteration's tokens are included in `stats.tokens` -- callers that
    /// stream incrementally should flush `stats.tokens[streamed..]`).
    Finished(GenStats),
}

/// Placeholder drafter type for target-only sessions (never invoked; every
/// call path is gated on `mode.is_some()`, which requires a drafter).
pub struct NoDraft;

impl DraftBackend for NoDraft {
    fn prefill(
        &self,
        _image: Option<&[f32]>,
        _prompt: &[i32],
        _len: usize,
        _text_only: bool,
    ) -> Result<SeqState> {
        Err(anyhow!("target-only session has no drafter"))
    }

    fn draft(
        &self,
        _st: &mut SeqState,
        _last: i32,
        _temperature: f32,
        _seed: u32,
    ) -> Result<DraftOutput> {
        Err(anyhow!("target-only session has no drafter"))
    }
}

/// Adaptive-controller state carried across steps (mirrors the EMA logic
/// documented in `spec::adaptive`).
struct AdaptiveState {
    cfg: AdaptiveConfig,
    /// EMA of emitted-tokens-per-iteration.
    ema: Option<f64>,
    /// EMA of branch utilization over tree iterations.
    util_ema: Option<f64>,
    tree_iters: usize,
    tree_banned: bool,
}

/// Where a session reports its per-iteration acceptance observations.
struct Telemetry {
    cal: Arc<Calibrator>,
    class: Arc<str>,
    image_reuse: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Created,
    Running,
    Finished,
}

enum IterResult {
    /// Newly emitted tokens; the session remains runnable.
    Running(Vec<i32>),
    Done,
}

pub struct DecodeSession<T: TargetBackend = TargetModel, D: DraftBackend = DraftModel> {
    target: T,
    drafter: Option<D>,
    params: SpecParams,
    cfg: GenConfig,
    text_only_draft: bool,
    tree_cfg: TreeConfig,
    max_new: usize,
    rng: Rng,
    scratch: Scratch,
    probs: Vec<f32>,
    stats: GenStats,
    tstate: Option<SeqState>,
    dstate: Option<SeqState>,
    /// The target's prefill logits, retained between prefill and the first
    /// step so `export_prefix` can snapshot the complete warm-start state;
    /// cleared on the first `step()` (exports are only valid post-prefill).
    prefill_logits: Option<Vec<f32>>,
    last: i32,
    /// Current drafting shape; `None` = plain target decoding (target-only
    /// sessions, or an adaptive session after fallback).
    mode: Option<SpecMode>,
    adaptive: Option<AdaptiveState>,
    /// Adaptive sessions record plain post-fallback decodes in the
    /// emitted-iteration summary (they are SD-loop iterations); pure
    /// target-only sessions do not (back-compat with `generate_baseline`
    /// accounting).
    count_plain_iters: bool,
    /// Drafter-side vision compression ratio (1 = full resolution).  Only
    /// the drafter's prefill sees the pooled sequence; the target always
    /// prefills at full resolution, so the emitted stream is unchanged.
    draft_vision_ratio: u32,
    /// Per-iteration acceptance telemetry destination (the engine's online
    /// calibrator), tagged with this request's workload class.
    telemetry: Option<Telemetry>,
    phase: Phase,
    /// Half-step state between `propose()` and `absorb_*` (always `None`
    /// when the session sits in a scheduler queue).
    pending: Pending,
    /// When set, both model states are paged into this pool right after
    /// prefill: forks (prefix-cache exports, tree branches) become
    /// per-block refcount bumps, and the engine can preempt this session
    /// by swapping its blocks out (`kv_swap_out`).
    kv_pool: Option<Arc<KvPool>>,
}

impl<T: TargetBackend, D: DraftBackend> DecodeSession<T, D> {
    /// Build a session.  `start` picks the drafting shape (`None` = plain
    /// target-only decoding; forced to `None` when there is no drafter);
    /// `adaptive` enables the chain<->tree/fallback controller.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        target: T,
        drafter: Option<D>,
        params: SpecParams,
        cfg: GenConfig,
        start: Option<SpecMode>,
        adaptive: Option<AdaptiveConfig>,
        text_only_draft: bool,
    ) -> Self {
        let tree_cfg = cfg.tree.clone().unwrap_or_else(|| params.tree.clone());
        let max_new = cfg.max_new.min(params.gen_max);
        let mode = if drafter.is_some() { start } else { None };
        let count_plain_iters = adaptive.is_some();
        DecodeSession {
            rng: Rng::seeded(cfg.seed),
            target,
            drafter,
            params,
            cfg,
            text_only_draft,
            tree_cfg,
            max_new,
            scratch: Scratch::default(),
            probs: Vec::new(),
            stats: GenStats::default(),
            tstate: None,
            dstate: None,
            prefill_logits: None,
            last: 0,
            mode,
            adaptive: adaptive.map(|acfg| AdaptiveState {
                cfg: acfg,
                ema: None,
                util_ema: None,
                tree_iters: 0,
                tree_banned: false,
            }),
            count_plain_iters,
            draft_vision_ratio: 1,
            telemetry: None,
            phase: Phase::Created,
            pending: Pending::None,
            kv_pool: None,
        }
    }

    /// Compress the drafter's vision prefill by `ratio` (call before
    /// prefill; 1 = full resolution, clamped up from 0).  Lossless: only
    /// the drafter's agreement rate and prefill cost move.
    pub fn set_draft_vision_ratio(&mut self, ratio: u32) {
        self.draft_vision_ratio = ratio.max(1);
    }

    /// Route per-iteration accept/reject observations to `cal`, tagged
    /// with this request's workload `class` and whether its image was
    /// served from cache (call before stepping).
    pub fn set_telemetry(&mut self, cal: Arc<Calibrator>, class: &str, image_reuse: bool) {
        self.telemetry = Some(Telemetry { cal, class: Arc::from(class), image_reuse });
    }

    fn observe_accept(&self, mode: SpecMode, drafted: usize, accepted: usize) {
        if let Some(t) = &self.telemetry {
            t.cal.observe(&IterObs {
                class: t.class.clone(),
                mode,
                drafted,
                accepted,
                image_reuse: t.image_reuse,
            });
        }
    }

    /// Page this session's KV through `pool` (call before prefill; paging
    /// is transparent to decoding -- block storage is bit-exact -- so
    /// output is identical with or without it).
    pub fn set_kv_pool(&mut self, pool: Arc<KvPool>) {
        self.kv_pool = Some(pool);
    }

    fn paginate_states(&mut self) {
        if let Some(pool) = &self.kv_pool {
            if let Some(st) = self.tstate.as_mut() {
                st.paginate(pool);
            }
            if let Some(st) = self.dstate.as_mut() {
                st.paginate(pool);
            }
        }
    }

    /// Preemption: release this session's pool blocks to a compacted host
    /// copy (no-op for unpaged states).  The session must be between
    /// steps; the engine swaps backlogged queue entries, never the lane it
    /// is executing.
    pub fn kv_swap_out(&mut self) {
        if let Some(st) = self.tstate.as_mut() {
            st.kv.swap_out();
        }
        if let Some(st) = self.dstate.as_mut() {
            st.kv.swap_out();
        }
    }

    /// Resume a preempted session: re-page any swapped state.  The word
    /// round-trip is bit-exact, so the continuation is identical to a
    /// never-preempted run.
    pub fn kv_swap_in(&mut self) {
        if let Some(st) = self.tstate.as_mut() {
            st.kv.swap_in();
        }
        if let Some(st) = self.dstate.as_mut() {
            st.kv.swap_in();
        }
    }

    /// Whether any of this session's states is currently swapped out.
    pub fn kv_swapped(&self) -> bool {
        self.tstate.as_ref().is_some_and(|st| st.kv.is_swapped())
            || self.dstate.as_ref().is_some_and(|st| st.kv.is_swapped())
    }

    pub fn finished(&self) -> bool {
        self.phase == Phase::Finished
    }

    /// Partial generation record so far (tokens already emitted, counters);
    /// empty after the session finished (the stats moved out).
    pub fn stats(&self) -> &GenStats {
        &self.stats
    }

    /// Abort a running session (cancellation / deadline): marks it finished
    /// and returns the partial generation record.  Any staged half-step is
    /// discarded.
    pub fn abort(&mut self) -> GenStats {
        self.phase = Phase::Finished;
        self.pending = Pending::None;
        std::mem::take(&mut self.stats)
    }

    fn finish_now(&mut self) -> StepOutcome {
        self.phase = Phase::Finished;
        StepOutcome::Finished(std::mem::take(&mut self.stats))
    }

    /// Run both prefills (image encode included) and sample the free first
    /// token from the target's prefill logits.
    pub fn prefill(&mut self, image: &[f32], prompt: &[i32], len: usize) -> Result<StepOutcome> {
        if self.phase != Phase::Created {
            return Err(anyhow!("prefill on an already-started session"));
        }
        let t0 = Instant::now();
        let enc = self.target.encode_image(image)?;
        let encode_micros = t0.elapsed().as_micros() as u64;
        self.prefill_encoded(&enc, prompt, len, encode_micros)
    }

    /// Prefill from an already-built vision encoding (the engine's
    /// cache-aware admission path: the encode may have been served from
    /// the prefix cache or run once under single-flight for many waiting
    /// requests).  `encode_micros` is the time *this* request spent
    /// encoding -- 0 when the encoding was cached.
    pub fn prefill_encoded(
        &mut self,
        enc: &VisionEncoding,
        prompt: &[i32],
        len: usize,
        encode_micros: u64,
    ) -> Result<StepOutcome> {
        if self.phase != Phase::Created {
            return Err(anyhow!("prefill on an already-started session"));
        }
        let t0 = Instant::now();
        let (last_logits, tstate) = self.target.prefill_encoded(enc, prompt, len)?;
        self.tstate = Some(tstate);
        if self.mode.is_some() {
            let drafter = self.drafter.as_ref().expect("speculative session without drafter");
            let td = Instant::now();
            self.dstate = Some(drafter.prefill_encoded(
                Some(enc),
                prompt,
                len,
                self.text_only_draft,
                self.draft_vision_ratio,
            )?);
            self.stats.draft_prefill_micros = td.elapsed().as_micros() as u64;
        }
        self.paginate_states();
        self.stats.encode_micros = encode_micros;
        self.stats.prefill_micros = encode_micros + t0.elapsed().as_micros() as u64;
        self.finish_prefill(last_logits)
    }

    /// Warm-start from a cached post-prefill prefix: fork both snapshots
    /// instead of running either model.  Sampling (the free first token,
    /// this session's RNG/seed/temperature) happens exactly as on the cold
    /// path, so warm output is bit-identical to cold output.
    pub fn prefill_from(&mut self, prefix: &PrefixSnapshot) -> Result<StepOutcome> {
        if self.phase != Phase::Created {
            return Err(anyhow!("prefill on an already-started session"));
        }
        if self.mode.is_some() && prefix.dstate.is_none() {
            return Err(anyhow!(
                "cached prefix carries no drafter state but this session speculates"
            ));
        }
        let t0 = Instant::now();
        self.tstate = Some(prefix.tstate.fork());
        if self.mode.is_some() {
            self.dstate = prefix.dstate.as_ref().map(SeqState::fork);
        }
        // paged snapshots fork as refcount bumps, so this only pages
        // owned-state snapshots (pool added after the cache was filled)
        self.paginate_states();
        self.stats.prefill_cache_hit = true;
        self.stats.prefill_micros = t0.elapsed().as_micros() as u64;
        self.finish_prefill(prefix.last_logits.clone())
    }

    /// Shared prefill tail: record the logits for `export_prefix`, sample
    /// the free first token, and settle the phase.
    fn finish_prefill(&mut self, last_logits: Vec<f32>) -> Result<StepOutcome> {
        let td = Instant::now();
        let t0_tok = sample_token(&last_logits, &self.cfg, &mut self.probs, &mut self.rng);
        self.prefill_logits = Some(last_logits);
        self.stats.tokens.push(t0_tok);
        self.last = t0_tok;
        self.stats.decode_micros += td.elapsed().as_micros() as u64;
        if t0_tok == self.params.eos_id {
            self.stats.finished_by_eos = true;
            return Ok(self.finish_now());
        }
        if self.stats.tokens.len() >= self.max_new {
            return Ok(self.finish_now());
        }
        self.phase = Phase::Running;
        Ok(StepOutcome::Emitted(vec![t0_tok]))
    }

    /// Snapshot the post-prefill prefix for the cache: forks of both model
    /// states plus the prefill logits.  Only valid between prefill and the
    /// first `step()` (decode steps mutate the states); returns `None`
    /// otherwise.  Sampling state is deliberately excluded -- the snapshot
    /// is taken *before* the free token draw, so one cached prefix serves
    /// every (seed, temperature) combination losslessly.
    pub fn export_prefix(&self) -> Option<PrefixSnapshot> {
        let last_logits = self.prefill_logits.clone()?;
        let tstate = self.tstate.as_ref()?;
        Some(PrefixSnapshot {
            last_logits,
            tstate: tstate.fork(),
            dstate: self.dstate.as_ref().map(SeqState::fork),
        })
    }

    /// Run exactly one decode iteration: a full draft -> verify -> accept
    /// round in chain/tree mode, or one plain target decode otherwise.
    /// Composed of the `propose`/`absorb_*` half-steps, driving this
    /// session's own backends -- the sequential reference the batched
    /// engine path must reproduce bit for bit.
    pub fn step(&mut self) -> Result<StepOutcome> {
        let td = Instant::now();
        let kind = self.propose()?;
        let r = self.drive_staged(kind);
        if r.is_ok() {
            self.stats.decode_micros += td.elapsed().as_micros() as u64;
        }
        r
    }

    /// Target-pass shape of this session's next `step()` (the batch
    /// planner's lane-compatibility input).
    pub fn lane_kind(&self) -> LaneKind {
        match self.mode {
            None => LaneKind::Plain,
            Some(SpecMode::Chain) => LaneKind::Chain,
            Some(SpecMode::Tree) => LaneKind::Tree,
        }
    }

    /// The verify-window draft length (for `verify_tree_batch` callers).
    pub fn gamma(&self) -> usize {
        self.params.gamma
    }

    /// Credit externally measured model time to this session's decode
    /// clock (the engine's per-lane share of a fused batched pass --
    /// `step()` times its own model calls instead).
    pub fn add_decode_micros(&mut self, micros: u64) {
        self.stats.decode_micros += micros;
    }

    /// Half-step 1: stage one decode iteration.  Draws the per-iteration
    /// draft seed from the session RNG for chain/tree lanes -- the draw
    /// order is identical to `step()`, so batched and sequential execution
    /// consume the RNG identically.  Returns the staged lane kind.
    pub fn propose(&mut self) -> Result<LaneKind> {
        match self.phase {
            Phase::Created => return Err(anyhow!("step before prefill")),
            Phase::Finished => return Err(anyhow!("step on a finished session")),
            Phase::Running => {}
        }
        if !matches!(self.pending, Pending::None) {
            return Err(anyhow!("propose while a half-step is already staged"));
        }
        // decode steps mutate the model states, so the post-prefill prefix
        // stops being exportable from here on
        self.prefill_logits = None;
        match self.mode {
            None => self.pending = Pending::VerifyPlain { last: self.last },
            Some(_) => {
                let seed = self.rng.next_u32();
                self.pending = Pending::AwaitDraft { last: self.last, seed };
            }
        }
        Ok(self.lane_kind())
    }

    /// Per-lane arguments for the ganged chain draft pass: the drafter
    /// state plus (last, temperature, seed) staged by `propose()`.
    pub fn chain_draft_parts(&mut self) -> Result<(&mut SeqState, i32, f32, u32)> {
        let (last, seed) = match self.pending {
            Pending::AwaitDraft { last, seed } => (last, seed),
            _ => return Err(anyhow!("no draft staged (propose a chain lane first)")),
        };
        if self.mode != Some(SpecMode::Chain) {
            return Err(anyhow!("staged lane is not in chain mode"));
        }
        let t = self.cfg.temperature;
        let st = self
            .dstate
            .as_mut()
            .ok_or_else(|| anyhow!("speculative session without drafter state"))?;
        Ok((st, last, t, seed))
    }

    /// Per-lane arguments for the ganged tree draft pass.
    pub fn tree_draft_parts(&mut self) -> Result<(&mut SeqState, i32, &TreeConfig, f32, u32)> {
        let (last, seed) = match self.pending {
            Pending::AwaitDraft { last, seed } => (last, seed),
            _ => return Err(anyhow!("no draft staged (propose a tree lane first)")),
        };
        if self.mode != Some(SpecMode::Tree) {
            return Err(anyhow!("staged lane is not in tree mode"));
        }
        let t = self.cfg.temperature;
        match self.dstate.as_mut() {
            Some(st) => Ok((st, last, &self.tree_cfg, t, seed)),
            None => Err(anyhow!("speculative session without drafter state")),
        }
    }

    /// Hand the drafter's chain output back (stages the verify window).
    pub fn supply_draft(&mut self, out: DraftOutput) -> Result<()> {
        let last = match self.pending {
            Pending::AwaitDraft { last, .. } => last,
            _ => return Err(anyhow!("no draft staged to supply")),
        };
        if self.mode != Some(SpecMode::Chain) {
            return Err(anyhow!("staged lane is not in chain mode"));
        }
        self.stats.draft_calls += 1;
        let mut vtokens = Vec::with_capacity(self.params.gamma + 1);
        vtokens.push(last);
        vtokens.extend_from_slice(&out.tokens);
        self.pending = Pending::VerifyChain { vtokens, out };
        Ok(())
    }

    /// Hand the drafter's tree back (stages the tree verify).
    pub fn supply_draft_tree(&mut self, tree: DraftTree) -> Result<()> {
        let last = match self.pending {
            Pending::AwaitDraft { last, .. } => last,
            _ => return Err(anyhow!("no draft staged to supply")),
        };
        if self.mode != Some(SpecMode::Tree) {
            return Err(anyhow!("staged lane is not in tree mode"));
        }
        self.stats.draft_calls += 1;
        self.stats.tree_nodes_drafted += tree.len();
        self.pending = Pending::VerifyTree { last, tree };
        Ok(())
    }

    /// Per-lane arguments for the ganged plain decode pass.
    pub fn plain_verify_parts(&mut self) -> Result<(&mut SeqState, i32)> {
        let last = match self.pending {
            Pending::VerifyPlain { last } => last,
            _ => return Err(anyhow!("no plain decode staged")),
        };
        Ok((self.tstate.as_mut().expect("running session without target state"), last))
    }

    /// Per-lane arguments for the ganged chain verify pass.
    pub fn chain_verify_parts(&mut self) -> Result<(&mut SeqState, &[i32])> {
        match &self.pending {
            Pending::VerifyChain { vtokens, .. } => Ok((
                self.tstate.as_mut().expect("running session without target state"),
                vtokens,
            )),
            _ => Err(anyhow!("no chain verify staged")),
        }
    }

    /// Per-lane arguments for the ganged tree verify pass.
    pub fn tree_verify_parts(&mut self) -> Result<(&mut SeqState, i32, &DraftTree)> {
        match &self.pending {
            Pending::VerifyTree { last, tree } => Ok((
                self.tstate.as_mut().expect("running session without target state"),
                *last,
                tree,
            )),
            _ => Err(anyhow!("no tree verify staged")),
        }
    }

    /// Half-step 2 for plain lanes: consume the target's decode logits
    /// (the decode already advanced the state position), sample, emit.
    pub fn absorb_decode(&mut self, logits: Vec<f32>) -> Result<StepOutcome> {
        match self.pending {
            Pending::VerifyPlain { .. } => {}
            _ => return Err(anyhow!("no plain decode staged")),
        }
        self.pending = Pending::None;
        let r = self.absorb_decode_inner(&logits);
        self.settle(r)
    }

    /// Half-step 2 for speculative lanes: consume the target's verify
    /// logits, run acceptance, emit, advance both caches, and update the
    /// adaptive controller -- identical math and RNG consumption to the
    /// fused `step()`.
    pub fn absorb_verify(&mut self, plogits: Tensor) -> Result<StepOutcome> {
        let pending = std::mem::replace(&mut self.pending, Pending::None);
        let r = match pending {
            Pending::VerifyChain { out, .. } => self.absorb_chain(out, plogits),
            Pending::VerifyTree { tree, .. } => self.absorb_tree(tree, plogits),
            other => {
                self.pending = other;
                return Err(anyhow!("no verify staged"));
            }
        };
        self.settle(r)
    }

    /// Map an iteration result onto the session phase (any error finishes
    /// the session, matching the pre-split `step()` contract).
    fn settle(&mut self, r: Result<IterResult>) -> Result<StepOutcome> {
        match r {
            Ok(IterResult::Running(tokens)) => Ok(StepOutcome::Emitted(tokens)),
            Ok(IterResult::Done) => Ok(self.finish_now()),
            Err(e) => {
                self.phase = Phase::Finished;
                Err(e)
            }
        }
    }

    /// Sequential driver over the staged half-step: run the owed model
    /// passes with this session's own backends, then absorb.
    fn drive_staged(&mut self, kind: LaneKind) -> Result<StepOutcome> {
        let r = self.drive_staged_inner(kind);
        if r.is_err() {
            self.phase = Phase::Finished;
        }
        r
    }

    fn drive_staged_inner(&mut self, kind: LaneKind) -> Result<StepOutcome> {
        if kind != LaneKind::Plain {
            let (last, seed) = match self.pending {
                Pending::AwaitDraft { last, seed } => (last, seed),
                _ => return Err(anyhow!("no draft staged")),
            };
            let drafter = self.drafter.as_ref().expect("speculative session without drafter");
            match kind {
                LaneKind::Chain => {
                    let out = drafter.draft(
                        self.dstate.as_mut().unwrap(),
                        last,
                        self.cfg.temperature,
                        seed,
                    )?;
                    self.supply_draft(out)?;
                }
                LaneKind::Tree => {
                    let tree = drafter.draft_tree(
                        self.dstate.as_mut().unwrap(),
                        last,
                        &self.tree_cfg,
                        self.cfg.temperature,
                        seed,
                    )?;
                    self.supply_draft_tree(tree)?;
                }
                LaneKind::Plain => unreachable!(),
            }
        }
        enum Absorb {
            Decode(Vec<f32>),
            Verify(Tensor),
        }
        let gamma = self.params.gamma;
        let staged = match &self.pending {
            Pending::VerifyPlain { last } => {
                let last = *last;
                Absorb::Decode(self.target.decode(self.tstate.as_mut().unwrap(), last)?)
            }
            Pending::VerifyChain { vtokens, .. } => {
                Absorb::Verify(self.target.verify(self.tstate.as_mut().unwrap(), vtokens)?)
            }
            Pending::VerifyTree { last, tree } => {
                let last = *last;
                Absorb::Verify(self.target.verify_tree(
                    self.tstate.as_mut().unwrap(),
                    last,
                    tree,
                    gamma,
                )?)
            }
            Pending::None | Pending::AwaitDraft { .. } => {
                return Err(anyhow!("no verify staged"))
            }
        };
        match staged {
            Absorb::Decode(logits) => self.absorb_decode(logits),
            Absorb::Verify(plogits) => self.absorb_verify(plogits),
        }
    }

    /// Drive the session to completion (the classic blocking entry point;
    /// `SpecDecoder::generate*` and friends are wrappers over this).
    pub fn run_to_completion(
        mut self,
        image: &[f32],
        prompt: &[i32],
        len: usize,
    ) -> Result<GenStats> {
        if let StepOutcome::Finished(stats) = self.prefill(image, prompt, len)? {
            return Ok(stats);
        }
        loop {
            if let StepOutcome::Finished(stats) = self.step()? {
                return Ok(stats);
            }
        }
    }

    /// Plain target decoding (target-only, or adaptive fallback): the
    /// decode already ran (and advanced `tstate.pos`); sample and emit.
    fn absorb_decode_inner(&mut self, logits: &[f32]) -> Result<IterResult> {
        let eos = self.params.eos_id;
        self.stats.verify_calls += 1;
        let tok = sample_token(logits, &self.cfg, &mut self.probs, &mut self.rng);
        self.stats.tokens.push(tok);
        if self.count_plain_iters {
            self.stats.record_emitted(1);
        }
        if tok == eos {
            self.stats.finished_by_eos = true;
            return Ok(IterResult::Done);
        }
        if self.stats.tokens.len() >= self.max_new {
            return Ok(IterResult::Done);
        }
        self.last = tok;
        Ok(IterResult::Running(vec![tok]))
    }

    /// Chain acceptance: emit the accepted prefix (may contain EOS), then
    /// the shared iteration tail.
    fn absorb_chain(&mut self, out: DraftOutput, plogits: Tensor) -> Result<IterResult> {
        let eos = self.params.eos_id;
        self.stats.verify_calls += 1;
        let dec = accept_stochastic(
            &out.tokens,
            &out.qlogits,
            &plogits,
            self.cfg.temperature,
            self.cfg.top_p,
            &mut self.rng,
            &mut self.scratch,
        );
        self.observe_accept(SpecMode::Chain, out.tokens.len(), dec.accepted);
        let mut emitted_tokens: Vec<i32> = Vec::new();
        let mut emitted = 0usize;
        for &tok in &out.tokens[..dec.accepted] {
            self.stats.tokens.push(tok);
            emitted_tokens.push(tok);
            emitted += 1;
            if tok == eos {
                self.stats.finished_by_eos = true;
                self.stats.accepted_draft += emitted;
                self.stats.record_emitted(emitted);
                return Ok(IterResult::Done);
            }
            if self.stats.tokens.len() >= self.max_new {
                self.stats.accepted_draft += emitted;
                self.stats.record_emitted(emitted);
                return Ok(IterResult::Done);
            }
        }
        self.stats.accepted_draft += emitted;
        self.finish_iteration(SpecMode::Chain, dec.accepted, dec.next_token, emitted_tokens)
    }

    /// Tree acceptance: emit the accepted root-to-leaf path (may contain
    /// EOS), update the branch-utilization EMA, then the shared tail.
    fn absorb_tree(&mut self, tree: DraftTree, plogits: Tensor) -> Result<IterResult> {
        let eos = self.params.eos_id;
        self.stats.verify_calls += 1;
        let dec = accept_tree_stochastic(
            &tree,
            &plogits,
            self.cfg.temperature,
            self.cfg.top_p,
            &mut self.rng,
            &mut self.scratch,
        );
        self.observe_accept(SpecMode::Tree, self.tree_cfg.depth(), dec.path.len());
        let mut emitted_tokens: Vec<i32> = Vec::new();
        let mut emitted = 0usize;
        for &node in &dec.path {
            let tok = tree.tokens[node];
            self.stats.tokens.push(tok);
            emitted_tokens.push(tok);
            emitted += 1;
            if tok == eos {
                self.stats.finished_by_eos = true;
                self.stats.accepted_draft += emitted;
                self.stats.record_emitted(emitted);
                self.stats.record_path_depth(emitted);
                return Ok(IterResult::Done);
            }
            if self.stats.tokens.len() >= self.max_new {
                self.stats.accepted_draft += emitted;
                self.stats.record_emitted(emitted);
                self.stats.record_path_depth(emitted);
                return Ok(IterResult::Done);
            }
        }
        self.stats.accepted_draft += emitted;
        self.stats.record_path_depth(dec.path.len());
        if let Some(ad) = self.adaptive.as_mut() {
            ad.tree_iters += 1;
            let util = if tree.is_empty() {
                0.0
            } else {
                dec.path.len() as f64 / tree.len() as f64
            };
            let a = ad.cfg.ema_alpha;
            ad.util_ema = Some(match ad.util_ema {
                None => util,
                Some(u) => a * util + (1.0 - a) * u,
            });
        }
        self.finish_iteration(SpecMode::Tree, dec.path.len(), dec.next_token, emitted_tokens)
    }

    /// Shared speculative-iteration tail: the target-sampled token
    /// (correction or bonus) always emits; advance both caches past `last`
    /// plus the accepted region (stale tails are position-masked by the
    /// backends); run the adaptive-controller update.
    fn finish_iteration(
        &mut self,
        cur_mode: SpecMode,
        accepted_len: usize,
        next_token: i32,
        mut emitted_tokens: Vec<i32>,
    ) -> Result<IterResult> {
        let eos = self.params.eos_id;
        let emitted = emitted_tokens.len() + 1;
        self.stats.tokens.push(next_token);
        emitted_tokens.push(next_token);
        self.stats.record_emitted(emitted);
        if next_token == eos {
            self.stats.finished_by_eos = true;
            return Ok(IterResult::Done);
        }
        if self.stats.tokens.len() >= self.max_new {
            return Ok(IterResult::Done);
        }

        self.tstate.as_mut().unwrap().pos += 1 + accepted_len as i32;
        self.dstate.as_mut().unwrap().pos += 1 + accepted_len as i32;
        self.last = next_token;

        // ---- adaptive controller update ----------------------------------
        if let Some(ad) = self.adaptive.as_mut() {
            let a = ad.cfg.ema_alpha;
            ad.ema = Some(match ad.ema {
                None => emitted as f64,
                Some(e) => a * emitted as f64 + (1.0 - a) * e,
            });
            if self.stats.verify_calls >= ad.cfg.patience && ad.ema.unwrap() < ad.cfg.min_tau {
                // speculation stopped paying: plain decoding from here on
                self.mode = None;
                self.stats.fallback_at = Some(self.stats.verify_calls);
                return Ok(IterResult::Running(emitted_tokens));
            }
            match cur_mode {
                SpecMode::Chain => {
                    if !ad.tree_banned
                        && self.stats.verify_calls >= ad.cfg.patience
                        && ad.ema.unwrap() >= ad.cfg.tree_upgrade_tau
                    {
                        self.mode = Some(SpecMode::Tree);
                    }
                }
                SpecMode::Tree => {
                    if ad.tree_iters >= ad.cfg.patience
                        && ad.util_ema.unwrap_or(0.0) < ad.cfg.min_branch_utilization
                    {
                        self.mode = Some(SpecMode::Chain);
                        ad.tree_banned = true; // don't flip-flop within a request
                    }
                }
            }
        }
        Ok(IterResult::Running(emitted_tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::decoder::TargetBackend;
    use crate::spec::testing::{params, MockDraft, MockTarget, MockTreeDraft, MOCK_GAMMA};

    /// Drive a session to completion given its prefill outcome.
    fn run_out<T: TargetBackend, D: DraftBackend>(
        first: StepOutcome,
        sess: &mut DecodeSession<T, D>,
    ) -> Result<GenStats> {
        if let StepOutcome::Finished(st) = first {
            return Ok(st);
        }
        loop {
            match sess.step()? {
                StepOutcome::Emitted(_) => {}
                StepOutcome::Finished(st) => return Ok(st),
            }
        }
    }

    /// THE cold-vs-warm losslessness property at the session level: a
    /// session warm-started from an exported post-prefill prefix must
    /// produce a bit-identical generation record -- tokens, RNG draws
    /// (pinned by per-seed T=1 determinism over sharp logits), and every
    /// semantic `GenStats` field -- across chain, tree, and adaptive
    /// modes, including the drafter-side state.
    #[test]
    fn prop_warm_prefill_is_bit_identical_to_cold() {
        crate::util::prop::propcheck("warm prefill == cold prefill", 48, |rng| {
            let n = 3 + rng.range(24);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2); // EOS
            let dscript: Vec<i32> = (0..n + 8)
                .map(|i| {
                    if rng.range(3) == 0 {
                        *script.get(i).unwrap_or(&2)
                    } else {
                        4 + rng.range(90) as i32
                    }
                })
                .collect();
            let mode = rng.range(3); // 0 = chain, 1 = tree, 2 = adaptive
            let cfg = GenConfig {
                temperature: if rng.range(2) == 0 { 0.0 } else { 1.0 },
                seed: rng.next_u64(),
                tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
                ..GenConfig::default()
            };
            let make = || {
                DecodeSession::new(
                    MockTarget::new(script.clone()),
                    Some(MockTreeDraft::new(vec![dscript.clone(), script.clone()])),
                    params(),
                    cfg.clone(),
                    Some(if mode == 1 { SpecMode::Tree } else { SpecMode::Chain }),
                    if mode == 2 { Some(AdaptiveConfig::default()) } else { None },
                    false,
                )
            };

            let mut cold = make();
            let out = cold.prefill(&[], &[0; 8], 3).map_err(|e| format!("{e:#}"))?;
            let snap = cold.export_prefix().ok_or("post-prefill export failed")?;
            let cold_stats = run_out(out, &mut cold).map_err(|e| format!("{e:#}"))?;

            let mut warm = make();
            let out = warm.prefill_from(&snap).map_err(|e| format!("{e:#}"))?;
            let warm_stats = run_out(out, &mut warm).map_err(|e| format!("{e:#}"))?;

            if cold_stats.tokens != warm_stats.tokens {
                return Err(format!(
                    "mode {mode}: warm tokens {:?} != cold tokens {:?}",
                    warm_stats.tokens, cold_stats.tokens
                ));
            }
            if !cold_stats.same_generation(&warm_stats) {
                return Err(format!(
                    "mode {mode}: warm stats diverge: cold {cold_stats:?} warm {warm_stats:?}"
                ));
            }
            if !warm_stats.prefill_cache_hit || cold_stats.prefill_cache_hit {
                return Err("cache-hit flags mislabelled".into());
            }
            Ok(())
        });
    }

    /// The cold-vs-warm property again with the paged KV pool attached on
    /// both sides, against an unpaged reference -- plus a swap-out/swap-in
    /// cycle before every warm step, emulating repeated engine preemption.
    /// Paging, paged forking, and preemption must all be invisible in the
    /// generation record.
    #[test]
    fn prop_paged_sessions_match_unpaged_and_survive_swaps() {
        use crate::kv::{KvPool, KvPoolConfig};
        crate::util::prop::propcheck("paged == unpaged (+preemption)", 32, |rng| {
            let n = 3 + rng.range(24);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2); // EOS
            let dscript: Vec<i32> = (0..n + 8)
                .map(|i| {
                    if rng.range(3) == 0 {
                        *script.get(i).unwrap_or(&2)
                    } else {
                        4 + rng.range(90) as i32
                    }
                })
                .collect();
            let mode = rng.range(3); // 0 = chain, 1 = tree, 2 = adaptive
            let cfg = GenConfig {
                temperature: if rng.range(2) == 0 { 0.0 } else { 1.0 },
                seed: rng.next_u64(),
                tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
                ..GenConfig::default()
            };
            let make = || {
                DecodeSession::new(
                    MockTarget::new(script.clone()),
                    Some(MockTreeDraft::new(vec![dscript.clone(), script.clone()])),
                    params(),
                    cfg.clone(),
                    Some(if mode == 1 { SpecMode::Tree } else { SpecMode::Chain }),
                    if mode == 2 { Some(AdaptiveConfig::default()) } else { None },
                    false,
                )
            };

            // unpaged reference
            let mut plain = make();
            let out = plain.prefill(&[], &[0; 8], 3).map_err(|e| format!("{e:#}"))?;
            let plain_stats = run_out(out, &mut plain).map_err(|e| format!("{e:#}"))?;

            // paged cold session; tiny blocks to exercise multi-block tables
            let pool = KvPool::with_metrics(
                KvPoolConfig { block_words: 4, budget_bytes: 1 << 20 },
                None,
            );
            let mut cold = make();
            cold.set_kv_pool(pool.clone());
            let out = cold.prefill(&[], &[0; 8], 3).map_err(|e| format!("{e:#}"))?;
            let snap = cold.export_prefix().ok_or("post-prefill export failed")?;
            let cold_stats = run_out(out, &mut cold).map_err(|e| format!("{e:#}"))?;

            // paged warm session forked from the paged snapshot, preempted
            // before every step
            let mut warm = make();
            warm.set_kv_pool(pool.clone());
            let mut out = warm.prefill_from(&snap).map_err(|e| format!("{e:#}"))?;
            let warm_stats = loop {
                match out {
                    StepOutcome::Finished(st) => break st,
                    StepOutcome::Emitted(_) => {
                        warm.kv_swap_out();
                        if !warm.kv_swapped() {
                            return Err("paged warm session must actually swap".into());
                        }
                        warm.kv_swap_in();
                        if warm.kv_swapped() {
                            return Err("swap_in must restore residency".into());
                        }
                        out = warm.step().map_err(|e| format!("{e:#}"))?;
                    }
                }
            };

            if plain_stats.tokens != cold_stats.tokens {
                return Err(format!(
                    "mode {mode}: paged tokens {:?} != unpaged {:?}",
                    cold_stats.tokens, plain_stats.tokens
                ));
            }
            if !plain_stats.same_generation(&cold_stats) {
                return Err(format!("mode {mode}: paged cold stats diverge"));
            }
            if plain_stats.tokens != warm_stats.tokens
                || !plain_stats.same_generation(&warm_stats)
            {
                return Err(format!(
                    "mode {mode}: preempted warm generation diverges: {:?} vs {:?}",
                    warm_stats.tokens, plain_stats.tokens
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn export_prefix_only_valid_before_first_step() {
        let script: Vec<i32> = (10..40).collect();
        let mut sess = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockDraft::new(script)),
            params(),
            GenConfig::default(),
            Some(SpecMode::Chain),
            None,
            false,
        );
        assert!(sess.export_prefix().is_none(), "nothing to export before prefill");
        sess.prefill(&[], &[0; 8], 3).unwrap();
        let snap = sess.export_prefix().expect("post-prefill export");
        assert!(snap.dstate.is_some(), "speculative prefix carries drafter state");
        sess.step().unwrap();
        assert!(sess.export_prefix().is_none(), "stepped states are not a prefix");
    }

    #[test]
    fn prefill_from_rejects_drafterless_prefix_for_speculation() {
        let script = vec![5, 6, 7, 2];
        // target-only cold session: its prefix has no drafter state
        let mut cold = DecodeSession::<MockTarget, NoDraft>::new(
            MockTarget::new(script.clone()),
            None,
            params(),
            GenConfig::default(),
            None,
            None,
            false,
        );
        cold.prefill(&[], &[0; 8], 3).unwrap();
        let snap = cold.export_prefix().unwrap();
        assert!(snap.dstate.is_none());
        let mut warm = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockDraft::new(script)),
            params(),
            GenConfig::default(),
            Some(SpecMode::Chain),
            None,
            false,
        );
        assert!(warm.prefill_from(&snap).is_err());
    }

    #[test]
    fn stepwise_emission_concatenates_to_generate_output() {
        // the concatenation of Emitted chunks plus the terminal tokens must
        // equal the one-shot generate() output, chunk boundaries at
        // iteration boundaries
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let mut dscript = script.clone();
        dscript[4] = 99;
        let oneshot = crate::spec::SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(dscript.clone()),
            params(),
        )
        .generate(&[], &[0; 8], 3, &GenConfig::default())
        .unwrap();

        let mut sess = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockDraft::new(dscript)),
            params(),
            GenConfig::default(),
            Some(SpecMode::Chain),
            None,
            false,
        );
        let mut streamed: Vec<i32> = Vec::new();
        match sess.prefill(&[], &[0; 8], 3).unwrap() {
            StepOutcome::Emitted(t) => streamed.extend(t),
            StepOutcome::Finished(_) => panic!("finished at prefill"),
        }
        let stats = loop {
            match sess.step().unwrap() {
                StepOutcome::Emitted(t) => streamed.extend(t),
                StepOutcome::Finished(stats) => break stats,
            }
        };
        // flush the terminal iteration's tokens
        streamed.extend_from_slice(&stats.tokens[streamed.len()..]);
        assert_eq!(streamed, oneshot.tokens);
        assert_eq!(stats.tokens, oneshot.tokens);
        assert!(stats.same_generation(&oneshot));
        assert!(sess.finished());
        assert!(sess.step().is_err(), "stepping a finished session errors");
    }

    #[test]
    fn tree_session_matches_generate_tree() {
        let script: Vec<i32> = (10..40).chain([2]).collect();
        let mut alt = script.clone();
        for i in (1..alt.len()).step_by(4) {
            alt[i] = 77;
        }
        let cfg = GenConfig {
            tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
            ..GenConfig::default()
        };
        let oneshot = crate::spec::SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![script.clone(), alt.clone()]),
            params(),
        )
        .generate_tree(&[], &[0; 8], 3, &cfg)
        .unwrap();

        let sess = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockTreeDraft::new(vec![script, alt])),
            params(),
            cfg,
            Some(SpecMode::Tree),
            None,
            false,
        );
        let stats = sess.run_to_completion(&[], &[0; 8], 3).unwrap();
        assert_eq!(stats.tokens, oneshot.tokens);
        assert!(stats.same_generation(&oneshot));
        assert_eq!(stats.tree_nodes_drafted, oneshot.tree_nodes_drafted);
    }

    #[test]
    fn abort_returns_partial_stats() {
        let script: Vec<i32> = (10..60).collect(); // no EOS
        let mut sess = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockDraft::new(script)),
            params(),
            GenConfig::default(),
            Some(SpecMode::Chain),
            None,
            false,
        );
        sess.prefill(&[], &[0; 8], 3).unwrap();
        sess.step().unwrap();
        let partial = sess.abort();
        assert!(sess.finished());
        assert!(!partial.tokens.is_empty());
        assert!(partial.tokens.len() < 48, "aborted well before the budget");
        assert!(!partial.finished_by_eos);
    }

    /// Drive a session with explicit half-steps (the engine's batched
    /// protocol) against twin backends, checking bit-identity with the
    /// fused `step()` driver -- chain, tree, and plain lanes.
    #[test]
    fn prop_half_steps_match_fused_step() {
        crate::util::prop::propcheck("half-steps == step()", 40, |rng| {
            let n = 3 + rng.range(20);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2); // EOS
            let dscript: Vec<i32> = (0..n + 8)
                .map(|i| {
                    if rng.range(3) == 0 {
                        *script.get(i).unwrap_or(&2)
                    } else {
                        4 + rng.range(90) as i32
                    }
                })
                .collect();
            let mode = rng.range(3); // 0 = chain, 1 = tree, 2 = plain
            let cfg = GenConfig {
                temperature: if rng.range(2) == 0 { 0.0 } else { 1.0 },
                seed: rng.next_u64(),
                tree: Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }),
                ..GenConfig::default()
            };
            let make = || {
                DecodeSession::new(
                    MockTarget::new(script.clone()),
                    if mode == 2 {
                        None
                    } else {
                        Some(MockTreeDraft::new(vec![dscript.clone(), script.clone()]))
                    },
                    params(),
                    cfg.clone(),
                    if mode == 2 {
                        None
                    } else {
                        Some(if mode == 1 { SpecMode::Tree } else { SpecMode::Chain })
                    },
                    None,
                    false,
                )
            };
            // twin backends for the external (engine-side) model calls
            let target = MockTarget::new(script.clone());
            let drafter = MockTreeDraft::new(vec![dscript.clone(), script.clone()]);

            let mut fused = make();
            let out = fused.prefill(&[], &[0; 8], 3).map_err(|e| format!("{e:#}"))?;
            let fused_stats = run_out(out, &mut fused).map_err(|e| format!("{e:#}"))?;

            let mut half = make();
            let mut out = half.prefill(&[], &[0; 8], 3).map_err(|e| format!("{e:#}"))?;
            let half_stats = loop {
                match out {
                    StepOutcome::Finished(st) => break st,
                    StepOutcome::Emitted(_) => {}
                }
                let kind = half.propose().map_err(|e| format!("{e:#}"))?;
                out = (|| -> Result<StepOutcome> {
                    match kind {
                        LaneKind::Plain => {
                            let (st, last) = half.plain_verify_parts()?;
                            let logits = target.decode(st, last)?;
                            half.absorb_decode(logits)
                        }
                        LaneKind::Chain => {
                            let d = {
                                let (st, last, t, seed) = half.chain_draft_parts()?;
                                drafter.draft(st, last, t, seed)?
                            };
                            half.supply_draft(d)?;
                            let p = {
                                let (st, toks) = half.chain_verify_parts()?;
                                target.verify(st, toks)?
                            };
                            half.absorb_verify(p)
                        }
                        LaneKind::Tree => {
                            let d = {
                                let (st, last, cfg, t, seed) = half.tree_draft_parts()?;
                                drafter.draft_tree(st, last, cfg, t, seed)?
                            };
                            half.supply_draft_tree(d)?;
                            let p = {
                                let (st, last, tree) = half.tree_verify_parts()?;
                                target.verify_tree(st, last, tree, MOCK_GAMMA)?
                            };
                            half.absorb_verify(p)
                        }
                    }
                })()
                .map_err(|e| format!("{e:#}"))?;
            };
            if fused_stats.tokens != half_stats.tokens {
                return Err(format!(
                    "mode {mode}: half-step tokens {:?} != step() tokens {:?}",
                    half_stats.tokens, fused_stats.tokens
                ));
            }
            if !fused_stats.same_generation(&half_stats) {
                return Err(format!(
                    "mode {mode}: half-step stats diverge: {half_stats:?} vs {fused_stats:?}"
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn half_step_protocol_rejects_misuse() {
        let script: Vec<i32> = (10..40).collect();
        let mut sess = DecodeSession::new(
            MockTarget::new(script.clone()),
            Some(MockDraft::new(script.clone())),
            params(),
            GenConfig::default(),
            Some(SpecMode::Chain),
            None,
            false,
        );
        assert!(sess.propose().is_err(), "propose before prefill must error");
        sess.prefill(&[], &[0; 8], 3).unwrap();
        assert!(sess.absorb_verify(Tensor::new(vec![0.0], vec![1, 1]).unwrap()).is_err());
        assert_eq!(sess.propose().unwrap(), LaneKind::Chain);
        assert!(sess.propose().is_err(), "double propose must error");
        assert!(sess.plain_verify_parts().is_err(), "chain lane has no plain decode staged");
        assert!(sess.chain_verify_parts().is_err(), "verify not staged before the draft");
        // supplying the draft stages the verify window
        let target = MockTarget::new(script.clone());
        let drafter = MockDraft::new(script.clone());
        let d = {
            let (st, last, t, seed) = sess.chain_draft_parts().unwrap();
            drafter.draft(st, last, t, seed).unwrap()
        };
        sess.supply_draft(d).unwrap();
        assert!(sess.chain_draft_parts().is_err(), "draft already supplied");
        let p = {
            let (st, toks) = sess.chain_verify_parts().unwrap();
            assert_eq!(toks.len(), MOCK_GAMMA + 1);
            target.verify(st, toks).unwrap()
        };
        match sess.absorb_verify(p).unwrap() {
            StepOutcome::Emitted(tokens) => assert!(!tokens.is_empty()),
            StepOutcome::Finished(_) => panic!("48-token budget cannot finish in one step"),
        }
        // the session is inert again: a fused step continues normally
        sess.step().unwrap();
    }

    #[test]
    fn target_only_session_needs_no_drafter() {
        let script = vec![5, 6, 7, 2];
        let sess = DecodeSession::<MockTarget, NoDraft>::new(
            MockTarget::new(script.clone()),
            None,
            params(),
            GenConfig::default(),
            None,
            None,
            false,
        );
        let stats = sess.run_to_completion(&[], &[0; 8], 3).unwrap();
        assert_eq!(stats.tokens, script);
        assert_eq!(stats.verify_calls, 3);
        assert!(stats.finished_by_eos);
    }
}
