//! Speculative-decoding acceptance rules (Section 2.1, Leviathan et al.).
//!
//! Greedy (T = 0): draft token i is accepted iff it equals the target's
//! argmax at that position; on rejection the argmax is emitted instead.
//!
//! Stochastic (T > 0): draft token x_i ~ q is accepted with probability
//! min(1, p(x_i)/q(x_i)); on rejection a replacement is drawn from the
//! residual norm(max(p - q, 0)).  If all gamma drafts are accepted a bonus
//! token is drawn from the target's distribution at the last position.
//! This preserves the target's output distribution exactly -- property
//! tested below (`prop_output_distribution_preserved`).

//!
//! Tree acceptance (`accept_tree_*`) generalizes both rules to a drafted
//! token tree: walk from the root context, and at each level test the
//! candidate children in node order.  Greedy accepts the child matching
//! the target argmax; stochastic accepts child `x ~ q` with probability
//! min(1, p(x)/q(x)) and, on rejection, continues to the next sibling
//! against the residual target `norm(max(p - q, 0))` (the SpecInfer
//! multi-candidate scheme).  When no child survives, the continuation is
//! sampled from the final residual; when an accepted path reaches a leaf,
//! the bonus token is sampled from that leaf's own target row.  Each level
//! is therefore an instance of single-token speculative sampling, so the
//! emitted token at every position is distributed exactly as the target's
//! -- the same losslessness argument as the chain, applied per level
//! (property-tested below and at the decoder level).

use crate::runtime::Tensor;
use crate::spec::sampler;
use crate::spec::tree::DraftTree;
use crate::util::rng::Rng;

/// Outcome of verifying one speculation window.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// How many draft tokens were accepted (0..=gamma).
    pub accepted: usize,
    /// The extra target-sampled token: the correction on rejection, or the
    /// bonus token when everything was accepted.
    pub next_token: i32,
    /// True when `next_token` is the bonus (all drafts accepted).
    pub bonus: bool,
}

/// Reusable scratch buffers so the hot loop does not allocate.
#[derive(Default)]
pub struct Scratch {
    p: Vec<f32>,
    q: Vec<f32>,
    r: Vec<f32>,
    perm: Vec<u32>,
}

/// Greedy verification.  `plogits` has gamma+1 rows; row i is the target
/// distribution conditioned on the prefix ending at draft token i-1.
pub fn accept_greedy(draft: &[i32], plogits: &Tensor) -> Decision {
    debug_assert_eq!(plogits.dims[0], draft.len() + 1);
    for (i, &x) in draft.iter().enumerate() {
        let best = sampler::argmax(plogits.row(i)) as i32;
        if x != best {
            return Decision { accepted: i, next_token: best, bonus: false };
        }
    }
    let bonus = sampler::argmax(plogits.row(draft.len())) as i32;
    Decision { accepted: draft.len(), next_token: bonus, bonus: true }
}

/// Stochastic verification at `temperature` with optional nucleus filtering
/// of the *target* distribution (`top_p`; 1.0 disables).  `qlogits` are the
/// drafter's raw logits (row i produced draft token i via plain temperature
/// sampling, so q_i = softmax(qlogits_i / T) exactly).
#[allow(clippy::too_many_arguments)]
pub fn accept_stochastic(
    draft: &[i32],
    qlogits: &Tensor,
    plogits: &Tensor,
    temperature: f32,
    top_p: f32,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> Decision {
    debug_assert_eq!(plogits.dims[0], draft.len() + 1);
    debug_assert_eq!(qlogits.dims[0], draft.len());
    if temperature <= 0.0 {
        return accept_greedy(draft, plogits);
    }
    for (i, &x) in draft.iter().enumerate() {
        sampler::softmax_t(plogits.row(i), temperature, &mut scratch.p);
        sampler::top_p_filter(&mut scratch.p, top_p, &mut scratch.perm);
        sampler::softmax_t(qlogits.row(i), temperature, &mut scratch.q);
        let px = scratch.p[x as usize];
        let qx = scratch.q[x as usize].max(1e-30);
        let ratio = (px / qx) as f64;
        if rng.f64() < ratio {
            continue; // accepted
        }
        sampler::residual(&scratch.p, &scratch.q, &mut scratch.r);
        let tok = sampler::sample(&scratch.r, rng) as i32;
        return Decision { accepted: i, next_token: tok, bonus: false };
    }
    sampler::softmax_t(plogits.row(draft.len()), temperature, &mut scratch.p);
    sampler::top_p_filter(&mut scratch.p, top_p, &mut scratch.perm);
    let tok = sampler::sample(&scratch.p, rng) as i32;
    Decision { accepted: draft.len(), next_token: tok, bonus: true }
}

// ---------------------------------------------------------------------------
// Tree acceptance
// ---------------------------------------------------------------------------

/// Outcome of verifying one drafted token tree.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeDecision {
    /// Accepted node indices, root to leaf (possibly empty).
    pub path: Vec<usize>,
    /// The extra target-sampled token after the accepted path.
    pub next_token: i32,
    /// True when the accepted path ended at a leaf (no candidate was
    /// rejected; `next_token` is the bonus from the leaf's own row).
    pub bonus: bool,
}

/// Row of `plogits` conditioning on the path ending at `node` (`None` = the
/// verified context itself).
fn row_of(node: Option<usize>) -> usize {
    node.map(|i| i + 1).unwrap_or(0)
}

/// Greedy tree verification.  `plogits` has `tree.len() + 1` rows laid out
/// as `row_of` describes.  The walk follows the unique child matching the
/// target argmax at each level, so the emitted tokens equal plain greedy
/// target decoding token for token -- with the longest matching
/// root-to-leaf path accepted in one verify call.
pub fn accept_tree_greedy(tree: &DraftTree, plogits: &Tensor) -> TreeDecision {
    debug_assert_eq!(plogits.dims[0], tree.len() + 1);
    let mut cur: Option<usize> = None;
    let mut path = Vec::new();
    loop {
        let best = sampler::argmax(plogits.row(row_of(cur))) as i32;
        match tree.children_of(cur).find(|&c| tree.tokens[c] == best) {
            Some(c) => {
                path.push(c);
                cur = Some(c);
            }
            None => {
                let bonus = tree.children_of(cur).next().is_none();
                return TreeDecision { path, next_token: best, bonus };
            }
        }
    }
}

/// Stochastic tree verification at `temperature` with optional nucleus
/// filtering of the target rows.  Lossless: see the module docs.
///
/// Q-ROW CONTRACT: exactness of the output distribution requires each
/// node's `qlogits` row to be the drafter distribution that node's token
/// was actually *sampled from*, with sibling candidates drawn i.i.d. from
/// it (the SpecInfer precondition).  Deterministically-chosen siblings
/// (e.g. `TreeBuilder::add_topk_children`) satisfy only the greedy rule;
/// point-mass rows (each child certain of its own token, as the scripted
/// backend emits) are a valid degenerate case.
pub fn accept_tree_stochastic(
    tree: &DraftTree,
    plogits: &Tensor,
    temperature: f32,
    top_p: f32,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> TreeDecision {
    debug_assert_eq!(plogits.dims[0], tree.len() + 1);
    if temperature <= 0.0 {
        return accept_tree_greedy(tree, plogits);
    }
    let mut cur: Option<usize> = None;
    let mut path = Vec::new();
    loop {
        sampler::softmax_t(plogits.row(row_of(cur)), temperature, &mut scratch.p);
        sampler::top_p_filter(&mut scratch.p, top_p, &mut scratch.perm);
        let mut accepted = None;
        let mut had_children = false;
        for c in tree.children_of(cur) {
            had_children = true;
            let x = tree.tokens[c];
            sampler::softmax_t(tree.qlogits.row(c), temperature, &mut scratch.q);
            let px = scratch.p[x as usize];
            let qx = scratch.q[x as usize].max(1e-30);
            if rng.f64() < (px / qx) as f64 {
                accepted = Some(c);
                break;
            }
            // this candidate is ruled out: continue siblings against the
            // residual target norm(max(p - q, 0))
            sampler::residual(&scratch.p, &scratch.q, &mut scratch.r);
            std::mem::swap(&mut scratch.p, &mut scratch.r);
        }
        match accepted {
            Some(c) => {
                path.push(c);
                cur = Some(c);
            }
            None => {
                let tok = sampler::sample(&scratch.p, rng) as i32;
                return TreeDecision { path, next_token: tok, bonus: !had_children };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{propcheck, random_distribution};

    fn tensor(rows: Vec<Vec<f32>>) -> Tensor {
        let r = rows.len();
        let c = rows[0].len();
        Tensor::new(rows.into_iter().flatten().collect(), vec![r, c]).unwrap()
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        // vocab 3; target argmaxes: [2, 0, 1, 2] over 4 rows
        let p = tensor(vec![
            vec![0.0, 0.1, 0.9],
            vec![0.9, 0.0, 0.1],
            vec![0.1, 0.8, 0.1],
            vec![0.0, 0.2, 0.8],
        ]);
        // draft matches first two, diverges at third
        let d = accept_greedy(&[2, 0, 0], &p);
        assert_eq!(d, Decision { accepted: 2, next_token: 1, bonus: false });
    }

    #[test]
    fn greedy_all_accepted_yields_bonus() {
        let p = tensor(vec![vec![0.0, 1.0], vec![1.0, 0.0], vec![0.0, 1.0]]);
        let d = accept_greedy(&[1, 0], &p);
        assert_eq!(d, Decision { accepted: 2, next_token: 1, bonus: true });
    }

    #[test]
    fn greedy_immediate_rejection() {
        let p = tensor(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let d = accept_greedy(&[1], &p);
        assert_eq!(d, Decision { accepted: 0, next_token: 0, bonus: false });
    }

    #[test]
    fn stochastic_identical_distributions_accept_everything() {
        // when p == q the ratio is 1 -> always accepted
        let logits = vec![vec![0.5, 1.5, -0.3]; 4];
        let p = tensor(logits.clone());
        let q = tensor(logits[..3].to_vec());
        let mut rng = Rng::seeded(0);
        let mut s = Scratch::default();
        for _ in 0..100 {
            let d = accept_stochastic(&[1, 1, 1], &q, &p, 1.0, 1.0, &mut rng, &mut s);
            assert_eq!(d.accepted, 3);
            assert!(d.bonus);
        }
    }

    #[test]
    fn stochastic_temperature_zero_delegates_to_greedy() {
        let p = tensor(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let q = tensor(vec![vec![0.0, 1.0]]);
        let mut rng = Rng::seeded(0);
        let mut s = Scratch::default();
        let d = accept_stochastic(&[0], &q, &p, 0.0, 1.0, &mut rng, &mut s);
        assert_eq!(d, accept_greedy(&[0], &p));
    }

    /// THE speculative-sampling theorem: for a single position, the emitted
    /// token (draft if accepted, else residual sample) is distributed
    /// exactly as p, for arbitrary p and q.  We verify empirically.
    #[test]
    fn prop_output_distribution_preserved() {
        propcheck("spec sampling preserves target dist", 12, |rng| {
            let v = 2 + rng.range(6);
            let p = random_distribution(rng, v);
            let q = random_distribution(rng, v);
            // build logits whose softmax(T=1) equals p and q
            let plog: Vec<f32> = p.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let qlog: Vec<f32> = q.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let pt = Tensor::new(
                plog.iter().chain(plog.iter()).cloned().collect(),
                vec![2, v],
            )
            .unwrap();
            let qt = Tensor::new(qlog.clone(), vec![1, v]).unwrap();
            let mut s = Scratch::default();
            let n = 60_000;
            let mut counts = vec![0usize; v];
            for _ in 0..n {
                // draw the draft token from q, then run acceptance
                let x = sampler::sample(&q, rng) as i32;
                let d = accept_stochastic(&[x], &qt, &pt, 1.0, 1.0, rng, &mut s);
                let emitted = if d.accepted == 1 { x } else { d.next_token };
                counts[emitted as usize] += 1;
            }
            for i in 0..v {
                let f = counts[i] as f64 / n as f64;
                let want = p[i] as f64;
                // generous tolerance: logit round-trip + sampling noise
                if (f - want).abs() > 0.02 + 0.05 * want {
                    return Err(format!("token {i}: got {f:.4}, want {want:.4}"));
                }
            }
            Ok(())
        });
    }

    // ------------------------------------------------------------- trees

    /// one-hot-ish rows sharp enough that softmax(T=1) is ~deterministic
    fn sharp(tok: i32, v: usize) -> Vec<f32> {
        let mut row = vec![0.0f32; v];
        row[tok as usize] = 50.0;
        row
    }

    /// Build a two-branch tree: branch A = [a0, a1], branch B = [b0, b1],
    /// all q rows one-hot at the proposed token.
    fn two_branch(v: usize, a: [i32; 2], b: [i32; 2]) -> DraftTree {
        let tokens = vec![a[0], a[1], b[0], b[1]];
        let parents = vec![None, Some(0), None, Some(2)];
        let depths = vec![0, 1, 0, 1];
        let q = Tensor::new(
            tokens.iter().flat_map(|&t| sharp(t, v)).collect(),
            vec![4, v],
        )
        .unwrap();
        DraftTree::new(tokens, parents, depths, q).unwrap()
    }

    #[test]
    fn tree_greedy_picks_longest_matching_path() {
        let v = 10;
        // target wants 5 then 6 then 7; branch A = [5, 9], branch B = [5->dup
        // collapses? no: B = [4, 6]] -- only A's root matches, then diverges.
        let t = two_branch(v, [5, 9], [4, 6]);
        // rows: ctx, after A0(5), after A1(9), after B0(4), after B1(6)
        let p = Tensor::new(
            [sharp(5, v), sharp(6, v), sharp(0, v), sharp(0, v), sharp(0, v)]
                .into_iter()
                .flatten()
                .collect(),
            vec![5, v],
        )
        .unwrap();
        let d = accept_tree_greedy(&t, &p);
        assert_eq!(d.path, vec![0]); // A0 accepted, A1 (9) != 6 rejected
        assert_eq!(d.next_token, 6); // correction from A0's row
        assert!(!d.bonus);
    }

    #[test]
    fn tree_greedy_second_branch_can_win() {
        let v = 10;
        let t = two_branch(v, [3, 9], [5, 6]);
        // target: ctx->5, after B0(5)->6, after B1(6)->7 (bonus)
        let p = Tensor::new(
            [sharp(5, v), sharp(0, v), sharp(0, v), sharp(6, v), sharp(7, v)]
                .into_iter()
                .flatten()
                .collect(),
            vec![5, v],
        )
        .unwrap();
        let d = accept_tree_greedy(&t, &p);
        assert_eq!(d.path, vec![2, 3]); // full branch B accepted
        assert_eq!(d.next_token, 7);
        assert!(d.bonus, "leaf reached -> bonus");
    }

    #[test]
    fn tree_greedy_zero_match_emits_correction() {
        let v = 10;
        let t = two_branch(v, [3, 4], [8, 9]);
        let p = Tensor::new(
            (0..5).flat_map(|_| sharp(6, v)).collect::<Vec<f32>>(),
            vec![5, v],
        )
        .unwrap();
        let d = accept_tree_greedy(&t, &p);
        assert!(d.path.is_empty());
        assert_eq!(d.next_token, 6);
        assert!(!d.bonus);
    }

    #[test]
    fn tree_empty_tree_is_plain_decoding() {
        let v = 6;
        let t = DraftTree::new(vec![], vec![], vec![], Tensor::new(vec![], vec![0, v]).unwrap())
            .unwrap();
        let p = Tensor::new(sharp(3, v), vec![1, v]).unwrap();
        let d = accept_tree_greedy(&t, &p);
        assert_eq!(d.path, Vec::<usize>::new());
        assert_eq!(d.next_token, 3);
        assert!(d.bonus, "no candidates to reject");
        let mut rng = Rng::seeded(4);
        let mut s = Scratch::default();
        let ds = accept_tree_stochastic(&t, &p, 1.0, 1.0, &mut rng, &mut s);
        assert_eq!(ds.next_token, 3, "sharp logits pin the sample");
    }

    /// For chain-shaped trees the tree rule must reproduce the classic rule
    /// exactly -- same rng stream, same decision.
    #[test]
    fn prop_tree_acceptance_degenerates_to_chain() {
        propcheck("tree == chain on linear trees", 60, |rng| {
            let v = 2 + rng.range(8);
            let n = 1 + rng.range(5);
            let draft: Vec<i32> = (0..n).map(|_| rng.range(v) as i32).collect();
            let rand_row = |rng: &mut Rng| -> Vec<f32> {
                (0..v).map(|_| rng.f32() * 6.0 - 3.0).collect()
            };
            let q = Tensor::new(
                (0..n).flat_map(|_| rand_row(rng)).collect::<Vec<f32>>(),
                vec![n, v],
            )
            .unwrap();
            let p = Tensor::new(
                (0..n + 1).flat_map(|_| rand_row(rng)).collect::<Vec<f32>>(),
                vec![n + 1, v],
            )
            .unwrap();
            let temperature = if rng.range(4) == 0 { 0.0 } else { 0.3 + rng.f32() };
            let top_p = if rng.range(2) == 0 { 1.0 } else { 0.5 + 0.5 * rng.f32() };
            let tree = DraftTree::chain(draft.clone(), q.clone());
            let seed = rng.next_u64();
            let mut s1 = Scratch::default();
            let mut s2 = Scratch::default();
            let chain = accept_stochastic(
                &draft, &q, &p, temperature, top_p, &mut Rng::seeded(seed), &mut s1,
            );
            let treed = accept_tree_stochastic(
                &tree, &p, temperature, top_p, &mut Rng::seeded(seed), &mut s2,
            );
            if treed.path.len() != chain.accepted
                || treed.next_token != chain.next_token
                || treed.bonus != chain.bonus
            {
                return Err(format!("tree {treed:?} != chain {chain:?}"));
            }
            Ok(())
        });
    }

    /// THE tree-level losslessness property: with k i.i.d. draft candidates
    /// per level, the emitted token is still distributed exactly as the
    /// target's p, for arbitrary p and q (SpecInfer multi-candidate
    /// speculative sampling).  Verified empirically at one level with k=2.
    #[test]
    fn prop_tree_output_distribution_preserved() {
        propcheck("tree sampling preserves target dist", 8, |rng| {
            let v = 2 + rng.range(6);
            let p = random_distribution(rng, v);
            let q = random_distribution(rng, v);
            let plog: Vec<f32> = p.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let qlog: Vec<f32> = q.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let mut s = Scratch::default();
            let n = 60_000;
            let mut counts = vec![0usize; v];
            for _ in 0..n {
                // two i.i.d. candidates from q as sibling root nodes
                let x0 = sampler::sample(&q, rng) as i32;
                let x1 = sampler::sample(&q, rng) as i32;
                let tree = DraftTree::new(
                    vec![x0, x1],
                    vec![None, None],
                    vec![0, 0],
                    Tensor::new(
                        qlog.iter().chain(qlog.iter()).cloned().collect(),
                        vec![2, v],
                    )
                    .unwrap(),
                )
                .unwrap();
                // rows: ctx + one per node, all the same target p
                let pt = Tensor::new(
                    plog.iter().cycle().take(3 * v).cloned().collect(),
                    vec![3, v],
                )
                .unwrap();
                let d = accept_tree_stochastic(&tree, &pt, 1.0, 1.0, rng, &mut s);
                let emitted = match d.path.first() {
                    Some(&node) => tree.tokens[node],
                    None => d.next_token,
                };
                counts[emitted as usize] += 1;
            }
            for i in 0..v {
                let f = counts[i] as f64 / n as f64;
                let want = p[i] as f64;
                if (f - want).abs() > 0.02 + 0.05 * want {
                    return Err(format!("token {i}: got {f:.4}, want {want:.4}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_acceptance_rate_increases_with_overlap() {
        // drafts from q == p should be accepted far more often than drafts
        // from a disjoint-ish q' -- the mechanism MASSV exploits.
        propcheck("overlap drives acceptance", 8, |rng| {
            let v = 8;
            let p = random_distribution(rng, v);
            let plog: Vec<f32> = p.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let pt = Tensor::new(
                plog.iter().chain(plog.iter()).cloned().collect(),
                vec![2, v],
            )
            .unwrap();
            let qt_good = Tensor::new(plog.clone(), vec![1, v]).unwrap();
            // bad drafter: uniform
            let qbad = vec![1.0 / v as f32; v];
            let qt_bad = Tensor::new(vec![0.0; v], vec![1, v]).unwrap();
            let mut s = Scratch::default();
            let trials = 4000;
            let mut acc_good = 0;
            let mut acc_bad = 0;
            for _ in 0..trials {
                let xg = sampler::sample(&p, rng) as i32;
                if accept_stochastic(&[xg], &qt_good, &pt, 1.0, 1.0, rng, &mut s).accepted == 1 {
                    acc_good += 1;
                }
                let xb = sampler::sample(&qbad, rng) as i32;
                if accept_stochastic(&[xb], &qt_bad, &pt, 1.0, 1.0, rng, &mut s).accepted == 1 {
                    acc_bad += 1;
                }
            }
            if acc_good <= acc_bad {
                return Err(format!("good {acc_good} <= bad {acc_bad}"));
            }
            Ok(())
        });
    }
}
