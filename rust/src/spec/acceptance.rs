//! Speculative-decoding acceptance rules (Section 2.1, Leviathan et al.).
//!
//! Greedy (T = 0): draft token i is accepted iff it equals the target's
//! argmax at that position; on rejection the argmax is emitted instead.
//!
//! Stochastic (T > 0): draft token x_i ~ q is accepted with probability
//! min(1, p(x_i)/q(x_i)); on rejection a replacement is drawn from the
//! residual norm(max(p - q, 0)).  If all gamma drafts are accepted a bonus
//! token is drawn from the target's distribution at the last position.
//! This preserves the target's output distribution exactly -- property
//! tested below (`prop_output_distribution_preserved`).

use crate::runtime::Tensor;
use crate::spec::sampler;
use crate::util::rng::Rng;

/// Outcome of verifying one speculation window.
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    /// How many draft tokens were accepted (0..=gamma).
    pub accepted: usize,
    /// The extra target-sampled token: the correction on rejection, or the
    /// bonus token when everything was accepted.
    pub next_token: i32,
    /// True when `next_token` is the bonus (all drafts accepted).
    pub bonus: bool,
}

/// Reusable scratch buffers so the hot loop does not allocate.
#[derive(Default)]
pub struct Scratch {
    p: Vec<f32>,
    q: Vec<f32>,
    r: Vec<f32>,
    perm: Vec<u32>,
}

/// Greedy verification.  `plogits` has gamma+1 rows; row i is the target
/// distribution conditioned on the prefix ending at draft token i-1.
pub fn accept_greedy(draft: &[i32], plogits: &Tensor) -> Decision {
    debug_assert_eq!(plogits.dims[0], draft.len() + 1);
    for (i, &x) in draft.iter().enumerate() {
        let best = sampler::argmax(plogits.row(i)) as i32;
        if x != best {
            return Decision { accepted: i, next_token: best, bonus: false };
        }
    }
    let bonus = sampler::argmax(plogits.row(draft.len())) as i32;
    Decision { accepted: draft.len(), next_token: bonus, bonus: true }
}

/// Stochastic verification at `temperature` with optional nucleus filtering
/// of the *target* distribution (`top_p`; 1.0 disables).  `qlogits` are the
/// drafter's raw logits (row i produced draft token i via plain temperature
/// sampling, so q_i = softmax(qlogits_i / T) exactly).
#[allow(clippy::too_many_arguments)]
pub fn accept_stochastic(
    draft: &[i32],
    qlogits: &Tensor,
    plogits: &Tensor,
    temperature: f32,
    top_p: f32,
    rng: &mut Rng,
    scratch: &mut Scratch,
) -> Decision {
    debug_assert_eq!(plogits.dims[0], draft.len() + 1);
    debug_assert_eq!(qlogits.dims[0], draft.len());
    if temperature <= 0.0 {
        return accept_greedy(draft, plogits);
    }
    for (i, &x) in draft.iter().enumerate() {
        sampler::softmax_t(plogits.row(i), temperature, &mut scratch.p);
        sampler::top_p_filter(&mut scratch.p, top_p, &mut scratch.perm);
        sampler::softmax_t(qlogits.row(i), temperature, &mut scratch.q);
        let px = scratch.p[x as usize];
        let qx = scratch.q[x as usize].max(1e-30);
        let ratio = (px / qx) as f64;
        if rng.f64() < ratio {
            continue; // accepted
        }
        sampler::residual(&scratch.p, &scratch.q, &mut scratch.r);
        let tok = sampler::sample(&scratch.r, rng) as i32;
        return Decision { accepted: i, next_token: tok, bonus: false };
    }
    sampler::softmax_t(plogits.row(draft.len()), temperature, &mut scratch.p);
    sampler::top_p_filter(&mut scratch.p, top_p, &mut scratch.perm);
    let tok = sampler::sample(&scratch.p, rng) as i32;
    Decision { accepted: draft.len(), next_token: tok, bonus: true }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{propcheck, random_distribution};

    fn tensor(rows: Vec<Vec<f32>>) -> Tensor {
        let r = rows.len();
        let c = rows[0].len();
        Tensor::new(rows.into_iter().flatten().collect(), vec![r, c]).unwrap()
    }

    #[test]
    fn greedy_accepts_matching_prefix() {
        // vocab 3; target argmaxes: [2, 0, 1, 2] over 4 rows
        let p = tensor(vec![
            vec![0.0, 0.1, 0.9],
            vec![0.9, 0.0, 0.1],
            vec![0.1, 0.8, 0.1],
            vec![0.0, 0.2, 0.8],
        ]);
        // draft matches first two, diverges at third
        let d = accept_greedy(&[2, 0, 0], &p);
        assert_eq!(d, Decision { accepted: 2, next_token: 1, bonus: false });
    }

    #[test]
    fn greedy_all_accepted_yields_bonus() {
        let p = tensor(vec![vec![0.0, 1.0], vec![1.0, 0.0], vec![0.0, 1.0]]);
        let d = accept_greedy(&[1, 0], &p);
        assert_eq!(d, Decision { accepted: 2, next_token: 1, bonus: true });
    }

    #[test]
    fn greedy_immediate_rejection() {
        let p = tensor(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let d = accept_greedy(&[1], &p);
        assert_eq!(d, Decision { accepted: 0, next_token: 0, bonus: false });
    }

    #[test]
    fn stochastic_identical_distributions_accept_everything() {
        // when p == q the ratio is 1 -> always accepted
        let logits = vec![vec![0.5, 1.5, -0.3]; 4];
        let p = tensor(logits.clone());
        let q = tensor(logits[..3].to_vec());
        let mut rng = Rng::seeded(0);
        let mut s = Scratch::default();
        for _ in 0..100 {
            let d = accept_stochastic(&[1, 1, 1], &q, &p, 1.0, 1.0, &mut rng, &mut s);
            assert_eq!(d.accepted, 3);
            assert!(d.bonus);
        }
    }

    #[test]
    fn stochastic_temperature_zero_delegates_to_greedy() {
        let p = tensor(vec![vec![1.0, 0.0], vec![0.0, 1.0]]);
        let q = tensor(vec![vec![0.0, 1.0]]);
        let mut rng = Rng::seeded(0);
        let mut s = Scratch::default();
        let d = accept_stochastic(&[0], &q, &p, 0.0, 1.0, &mut rng, &mut s);
        assert_eq!(d, accept_greedy(&[0], &p));
    }

    /// THE speculative-sampling theorem: for a single position, the emitted
    /// token (draft if accepted, else residual sample) is distributed
    /// exactly as p, for arbitrary p and q.  We verify empirically.
    #[test]
    fn prop_output_distribution_preserved() {
        propcheck("spec sampling preserves target dist", 12, |rng| {
            let v = 2 + rng.range(6);
            let p = random_distribution(rng, v);
            let q = random_distribution(rng, v);
            // build logits whose softmax(T=1) equals p and q
            let plog: Vec<f32> = p.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let qlog: Vec<f32> = q.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let pt = Tensor::new(
                plog.iter().chain(plog.iter()).cloned().collect(),
                vec![2, v],
            )
            .unwrap();
            let qt = Tensor::new(qlog.clone(), vec![1, v]).unwrap();
            let mut s = Scratch::default();
            let n = 60_000;
            let mut counts = vec![0usize; v];
            for _ in 0..n {
                // draw the draft token from q, then run acceptance
                let x = sampler::sample(&q, rng) as i32;
                let d = accept_stochastic(&[x], &qt, &pt, 1.0, 1.0, rng, &mut s);
                let emitted = if d.accepted == 1 { x } else { d.next_token };
                counts[emitted as usize] += 1;
            }
            for i in 0..v {
                let f = counts[i] as f64 / n as f64;
                let want = p[i] as f64;
                // generous tolerance: logit round-trip + sampling noise
                if (f - want).abs() > 0.02 + 0.05 * want {
                    return Err(format!("token {i}: got {f:.4}, want {want:.4}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_acceptance_rate_increases_with_overlap() {
        // drafts from q == p should be accepted far more often than drafts
        // from a disjoint-ish q' -- the mechanism MASSV exploits.
        propcheck("overlap drives acceptance", 8, |rng| {
            let v = 8;
            let p = random_distribution(rng, v);
            let plog: Vec<f32> = p.iter().map(|&x| (x.max(1e-9)).ln()).collect();
            let pt = Tensor::new(
                plog.iter().chain(plog.iter()).cloned().collect(),
                vec![2, v],
            )
            .unwrap();
            let qt_good = Tensor::new(plog.clone(), vec![1, v]).unwrap();
            // bad drafter: uniform
            let qbad = vec![1.0 / v as f32; v];
            let qt_bad = Tensor::new(vec![0.0; v], vec![1, v]).unwrap();
            let mut s = Scratch::default();
            let trials = 4000;
            let mut acc_good = 0;
            let mut acc_bad = 0;
            for _ in 0..trials {
                let xg = sampler::sample(&p, rng) as i32;
                if accept_stochastic(&[xg], &qt_good, &pt, 1.0, 1.0, rng, &mut s).accepted == 1 {
                    acc_good += 1;
                }
                let xb = sampler::sample(&qbad, rng) as i32;
                if accept_stochastic(&[xb], &qt_bad, &pt, 1.0, 1.0, rng, &mut s).accepted == 1 {
                    acc_bad += 1;
                }
            }
            if acc_good <= acc_bad {
                return Err(format!("good {acc_good} <= bad {acc_bad}"));
            }
            Ok(())
        });
    }
}
