//! Scripted mock backends for decoder-logic tests (no PJRT involved).
//!
//! Semantics: a mock target deterministically "wants" the token stream
//! `script[0], script[1], ...` -- prefill returns one-hot logits for
//! `script[0]`; a verify window whose first token is written at stream
//! position `st.pos` returns one-hot rows for `script[pos+1 ..= pos+gamma+1]`.
//! A mock drafter proposes its own script the same way.  Greedy speculative
//! decoding against these mocks must reproduce the target script exactly,
//! with acceptance counts equal to per-window prefix agreement -- which is
//! what the tests in spec::decoder assert.
//!
//! One-hot logits use a sharp magnitude (`SHARP`) so that softmax at T=1 is
//! numerically a point mass: the same mocks exercise temperature sampling
//! deterministically (fixed-seed speculative output must equal fixed-seed
//! target-only output -- the T>0 losslessness tests).
//!
//! For token-tree speculation, `MockTarget` overrides
//! `TargetBackend::verify_tree` (its stream is positional, so the row for a
//! node at depth d is just `script[pos + d + 2]`), and `MockTreeDraft`
//! drafts a genuine multi-branch tree: a prefix-trie over several candidate
//! scripts, exercising multi-path agreement deterministically.
//!
//! `SeqState.pos` is reused as the *stream* position (the mocks have no KV
//! cache; the dummy literal is never read).
//!
//! This module also hosts the **batched-execution determinism oracle**
//! (`run_batched_vs_sequential`): it replays a mix of sessions over the
//! scripted model backend both sequentially (`DecodeSession::step` loops)
//! and through engine-style fused ticks (propose -> batched draft ->
//! batched verify -> absorb), asserting bit-identical tokens, emission
//! boundaries, accept counts, and `GenStats` per lane -- the MASSV
//! losslessness guarantee extended to cross-request batching.

use std::sync::Arc;

use anyhow::Result;

use crate::models::scripted::sharp_row;
use crate::models::{scripted, DraftOutput, ModelSet, SeqState};
use crate::runtime::Tensor;
use crate::spec::adaptive::{AdaptiveConfig, SpecMode};
use crate::spec::decoder::{DraftBackend, GenConfig, GenStats, SpecParams, TargetBackend};
use crate::spec::session::{DecodeSession, LaneKind, StepOutcome};
use crate::spec::tree::{DraftTree, TreeBuilder, TreeConfig};

pub const MOCK_VOCAB: usize = 100;
pub const MOCK_EOS: i32 = 2;
pub const MOCK_GAMMA: usize = 5;

/// One-hot logit magnitude (shared with the scripted backend -- both
/// determinism arguments depend on the same constant): softmax_t(row, 1.0)
/// puts ~1 - 1e-20 mass on the hot token, so T=1 sampling follows the
/// script for every realizable rng draw.
pub use crate::models::scripted::SHARP;

/// Standard params used by the mock tests.
pub fn params() -> SpecParams {
    SpecParams {
        gamma: MOCK_GAMMA,
        eos_id: MOCK_EOS,
        gen_max: 48,
        tree: TreeConfig::for_depth(MOCK_GAMMA),
    }
}

fn one_hot(tok: i32) -> Vec<f32> {
    sharp_row(tok, MOCK_VOCAB)
}

fn dummy_state() -> SeqState {
    SeqState::new(xla::Literal::scalar(0.0f32), 0, None)
}

/// A target that greedily emits `script` (cyclic past the end, so budget
/// tests can run without EOS).
pub struct MockTarget {
    pub script: Vec<i32>,
}

impl MockTarget {
    pub fn new(script: Vec<i32>) -> Self {
        assert!(!script.is_empty());
        MockTarget { script }
    }

    fn at(&self, i: i32) -> i32 {
        crate::models::scripted::at(&self.script, i)
    }
}

impl TargetBackend for MockTarget {
    fn prefill(&self, _image: &[f32], _prompt: &[i32], _len: usize) -> Result<(Vec<f32>, SeqState)> {
        Ok((one_hot(self.at(0)), dummy_state()))
    }

    fn verify(&self, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        // row i conditions on the prefix ending at tokens[i] (stream index
        // st.pos + i) and predicts the token at stream index st.pos + i + 1
        let rows: Vec<f32> = (0..tokens.len())
            .flat_map(|i| one_hot(self.at(st.pos + i as i32 + 1)))
            .collect();
        Tensor::new(rows, vec![tokens.len(), MOCK_VOCAB])
    }

    fn decode(&self, st: &mut SeqState, _token: i32) -> Result<Vec<f32>> {
        let out = one_hot(self.at(st.pos + 1));
        st.pos += 1;
        Ok(out)
    }

    fn verify_tree(
        &self,
        st: &mut SeqState,
        _last: i32,
        tree: &DraftTree,
        _gamma: usize,
    ) -> Result<Tensor> {
        // The mock stream is positional, so the distribution after the path
        // to a node at depth d predicts stream index st.pos + d + 2; row 0
        // (after `last` itself) predicts st.pos + 1.
        let mut rows: Vec<f32> = Vec::with_capacity((tree.len() + 1) * MOCK_VOCAB);
        rows.extend(one_hot(self.at(st.pos + 1)));
        for d in &tree.depths {
            rows.extend(one_hot(self.at(st.pos + *d as i32 + 2)));
        }
        Tensor::new(rows, vec![tree.len() + 1, MOCK_VOCAB])
    }
}

/// A drafter that proposes its own script (cyclic), independent of the
/// tokens it is fed -- agreement with the target is purely positional,
/// which makes expected acceptance counts trivially computable in tests.
pub struct MockDraft {
    pub script: Vec<i32>,
}

impl MockDraft {
    pub fn new(script: Vec<i32>) -> Self {
        assert!(!script.is_empty());
        MockDraft { script }
    }

    fn at(&self, i: i32) -> i32 {
        crate::models::scripted::at(&self.script, i)
    }
}

impl DraftBackend for MockDraft {
    fn prefill(
        &self,
        _image: Option<&[f32]>,
        _prompt: &[i32],
        _len: usize,
        _text_only: bool,
    ) -> Result<SeqState> {
        Ok(dummy_state())
    }

    fn draft(
        &self,
        st: &mut SeqState,
        _last: i32,
        _temperature: f32,
        _seed: u32,
    ) -> Result<DraftOutput> {
        let tokens: Vec<i32> = (0..MOCK_GAMMA).map(|i| self.at(st.pos + 1 + i as i32)).collect();
        let qlogits = Tensor::new(
            tokens.iter().flat_map(|&t| one_hot(t)).collect(),
            vec![MOCK_GAMMA, MOCK_VOCAB],
        )?;
        Ok(DraftOutput { tokens, qlogits })
    }
}

/// A multi-branch drafter: each of `scripts` is one candidate continuation
/// line; `draft_tree` builds the prefix-trie over their windows at the
/// current stream position (so branches sharing tokens share nodes).
/// Chain-mode `draft` falls back to `scripts[0]`.
pub struct MockTreeDraft {
    pub scripts: Vec<Vec<i32>>,
}

impl MockTreeDraft {
    pub fn new(scripts: Vec<Vec<i32>>) -> Self {
        assert!(!scripts.is_empty());
        assert!(scripts.iter().all(|s| !s.is_empty()));
        MockTreeDraft { scripts }
    }

    fn at(&self, b: usize, i: i32) -> i32 {
        crate::models::scripted::at(&self.scripts[b], i)
    }
}

impl DraftBackend for MockTreeDraft {
    fn prefill(
        &self,
        _image: Option<&[f32]>,
        _prompt: &[i32],
        _len: usize,
        _text_only: bool,
    ) -> Result<SeqState> {
        Ok(dummy_state())
    }

    fn draft(
        &self,
        st: &mut SeqState,
        _last: i32,
        _temperature: f32,
        _seed: u32,
    ) -> Result<DraftOutput> {
        let tokens: Vec<i32> =
            (0..MOCK_GAMMA).map(|i| self.at(0, st.pos + 1 + i as i32)).collect();
        let qlogits = Tensor::new(
            tokens.iter().flat_map(|&t| one_hot(t)).collect(),
            vec![MOCK_GAMMA, MOCK_VOCAB],
        )?;
        Ok(DraftOutput { tokens, qlogits })
    }

    fn draft_tree(
        &self,
        st: &mut SeqState,
        _last: i32,
        cfg: &TreeConfig,
        _temperature: f32,
        _seed: u32,
    ) -> Result<DraftTree> {
        let mut b = TreeBuilder::new(MOCK_VOCAB);
        for branch in 0..self.scripts.len() {
            let path: Vec<(i32, Vec<f32>)> = (0..cfg.depth())
                .map(|d| {
                    let t = self.at(branch, st.pos + 1 + d as i32);
                    (t, one_hot(t))
                })
                .collect();
            b.add_path(&path, cfg);
        }
        b.build()
    }
}

// ---------------------------------------------------------------------------
// Batched-vs-sequential determinism oracle
// ---------------------------------------------------------------------------

/// One lane of the batched-vs-sequential oracle: how to build and prefill
/// one session over the scripted model backend.
#[derive(Debug, Clone)]
pub struct OracleLane {
    /// Drafting shape; `None` = target-only (a plain-decode lane).
    pub mode: Option<SpecMode>,
    /// Wrap the mode in the adaptive chain<->tree/fallback controller.
    pub adaptive: bool,
    pub cfg: GenConfig,
    /// `models::scripted::demo_image` phase (distinct per-lane streams).
    pub image_phase: usize,
    pub prompt: Vec<i32>,
    /// Replay through an exported post-prefill prefix (the prefix-cache
    /// hit path) instead of a cold prefill.
    pub warm: bool,
}

/// THE cross-request batching determinism oracle: replay `lanes` two ways
/// -- sequential `step()` loops vs engine-style fused ticks (every lane's
/// `propose`, then one batched drafter pass, one batched target pass, and
/// per-lane `absorb_*`) -- and require bit-identical tokens, per-step
/// emission boundaries, accept counts, and semantic `GenStats` per lane.
/// Returns `Err` naming the first divergence (propcheck-style).
pub fn run_batched_vs_sequential(
    set: &Arc<ModelSet>,
    target_name: &str,
    drafter_variant: &str,
    lanes: &[OracleLane],
) -> std::result::Result<(), String> {
    run_batched_vs_sequential_pooled(set, target_name, drafter_variant, lanes, None)
}

/// `run_batched_vs_sequential` with every session's KV paged through
/// `pool` (when given): the paged-pool determinism oracle.  Passing the
/// same lanes with and without a pool pins the headline paging invariant
/// -- the decode path cannot observe whether paging is on.
pub fn run_batched_vs_sequential_pooled(
    set: &Arc<ModelSet>,
    target_name: &str,
    drafter_variant: &str,
    lanes: &[OracleLane],
    pool: Option<&Arc<crate::kv::KvPool>>,
) -> std::result::Result<(), String> {
    struct Run {
        chunks: Vec<Vec<i32>>,
        stats: GenStats,
    }
    let err = |e: anyhow::Error| format!("{e:#}");
    let target = set.target(target_name).map_err(err)?;
    let drafter = set.drafter_for(target_name, drafter_variant).map_err(err)?;
    let params = SpecParams::from_manifest(&set.manifest);
    let make = |lane: &OracleLane| {
        let mut sess = DecodeSession::new(
            target.clone(),
            lane.mode.map(|_| drafter.clone()),
            params.clone(),
            lane.cfg.clone(),
            lane.mode,
            if lane.adaptive && lane.mode.is_some() {
                Some(AdaptiveConfig::default())
            } else {
                None
            },
            false,
        );
        if let Some(p) = pool {
            sess.set_kv_pool(p.clone());
        }
        sess
    };
    let prefill =
        |sess: &mut DecodeSession, lane: &OracleLane| -> std::result::Result<StepOutcome, String> {
            let image = scripted::demo_image(lane.image_phase);
            let len = lane.prompt.len();
            if lane.warm {
                // the prefix-cache path: fork an exported post-prefill
                // snapshot instead of running either model's prefill
                let mut probe = make(lane);
                probe.prefill(&image, &lane.prompt, len).map_err(err)?;
                let snap = probe
                    .export_prefix()
                    .ok_or_else(|| "post-prefill export failed".to_string())?;
                sess.prefill_from(&snap).map_err(err)
            } else {
                sess.prefill(&image, &lane.prompt, len).map_err(err)
            }
        };

    // ---- way 1: each lane sequentially, one step() at a time ------------
    let mut sequential: Vec<Run> = Vec::with_capacity(lanes.len());
    for lane in lanes {
        let mut sess = make(lane);
        let mut chunks = Vec::new();
        let mut out = prefill(&mut sess, lane)?;
        let stats = loop {
            match out {
                StepOutcome::Finished(stats) => break stats,
                StepOutcome::Emitted(t) => chunks.push(t),
            }
            out = sess.step().map_err(err)?;
        };
        sequential.push(Run { chunks, stats });
    }

    // ---- way 2: engine-style fused ticks over all live lanes ------------
    let mut results: Vec<Option<Run>> = lanes.iter().map(|_| None).collect();
    let mut live: Vec<(usize, DecodeSession, Vec<Vec<i32>>)> = Vec::new();
    for (i, lane) in lanes.iter().enumerate() {
        let mut sess = make(lane);
        let mut chunks = Vec::new();
        match prefill(&mut sess, lane)? {
            StepOutcome::Finished(stats) => results[i] = Some(Run { chunks, stats }),
            StepOutcome::Emitted(t) => {
                chunks.push(t);
                live.push((i, sess, chunks));
            }
        }
    }
    let gamma = params.gamma;
    let mut guard = 0usize;
    while !live.is_empty() {
        guard += 1;
        if guard > 100_000 {
            return Err("batched replay did not terminate".into());
        }
        // lane kinds are snapshotted per tick: a lane the adaptive
        // controller just switched joins its new group NEXT tick, exactly
        // like a requeued session under the engine's keyed pop
        let kinds: Vec<LaneKind> = live.iter().map(|l| l.1.lane_kind()).collect();
        for kind in [LaneKind::Plain, LaneKind::Chain, LaneKind::Tree] {
            if !kinds.contains(&kind) {
                continue;
            }
            for (l, k) in live.iter_mut().zip(&kinds) {
                if *k == kind {
                    l.1.propose().map_err(err)?;
                }
            }
            match kind {
                LaneKind::Plain => {}
                LaneKind::Chain => {
                    let outs = {
                        let mut dl = Vec::new();
                        for (l, k) in live.iter_mut().zip(&kinds) {
                            if *k == kind {
                                dl.push(l.1.chain_draft_parts().map_err(err)?);
                            }
                        }
                        drafter.draft_batch(&mut dl)
                    };
                    let mut outs = outs.into_iter();
                    for (l, k) in live.iter_mut().zip(&kinds) {
                        if *k == kind {
                            let out = outs.next().expect("one draft per lane").map_err(err)?;
                            l.1.supply_draft(out).map_err(err)?;
                        }
                    }
                }
                LaneKind::Tree => {
                    let trees = {
                        let mut dl = Vec::new();
                        for (l, k) in live.iter_mut().zip(&kinds) {
                            if *k == kind {
                                dl.push(l.1.tree_draft_parts().map_err(err)?);
                            }
                        }
                        drafter.draft_tree_batch(&mut dl)
                    };
                    let mut trees = trees.into_iter();
                    for (l, k) in live.iter_mut().zip(&kinds) {
                        if *k == kind {
                            let tree = trees.next().expect("one tree per lane").map_err(err)?;
                            l.1.supply_draft_tree(tree).map_err(err)?;
                        }
                    }
                }
            }
            // ganged target pass + per-lane absorb
            let mut absorbed: Vec<StepOutcome> = Vec::new();
            match kind {
                LaneKind::Plain => {
                    let rows = {
                        let mut vl = Vec::new();
                        for (l, k) in live.iter_mut().zip(&kinds) {
                            if *k == kind {
                                vl.push(l.1.plain_verify_parts().map_err(err)?);
                            }
                        }
                        target.decode_batch(&mut vl)
                    };
                    let mut rows = rows.into_iter();
                    for (l, k) in live.iter_mut().zip(&kinds) {
                        if *k == kind {
                            let row = rows.next().expect("one decode per lane").map_err(err)?;
                            absorbed.push(l.1.absorb_decode(row).map_err(err)?);
                        }
                    }
                }
                LaneKind::Chain => {
                    let outs = {
                        let mut vl = Vec::new();
                        for (l, k) in live.iter_mut().zip(&kinds) {
                            if *k == kind {
                                vl.push(l.1.chain_verify_parts().map_err(err)?);
                            }
                        }
                        target.verify_batch(&mut vl)
                    };
                    let mut outs = outs.into_iter();
                    for (l, k) in live.iter_mut().zip(&kinds) {
                        if *k == kind {
                            let p = outs.next().expect("one verify per lane").map_err(err)?;
                            absorbed.push(l.1.absorb_verify(p).map_err(err)?);
                        }
                    }
                }
                LaneKind::Tree => {
                    let outs = {
                        let mut vl = Vec::new();
                        for (l, k) in live.iter_mut().zip(&kinds) {
                            if *k == kind {
                                vl.push(l.1.tree_verify_parts().map_err(err)?);
                            }
                        }
                        target.verify_tree_batch(&mut vl, gamma)
                    };
                    let mut outs = outs.into_iter();
                    for (l, k) in live.iter_mut().zip(&kinds) {
                        if *k == kind {
                            let p = outs.next().expect("one verify per lane").map_err(err)?;
                            absorbed.push(l.1.absorb_verify(p).map_err(err)?);
                        }
                    }
                }
            }
            // scatter outcomes back (chunk bookkeeping, terminal stats)
            let mut absorbed = absorbed.into_iter();
            for (l, k) in live.iter_mut().zip(&kinds) {
                if *k == kind {
                    match absorbed.next().expect("one outcome per lane") {
                        StepOutcome::Emitted(t) => l.2.push(t),
                        StepOutcome::Finished(stats) => {
                            results[l.0] = Some(Run { chunks: std::mem::take(&mut l.2), stats });
                        }
                    }
                }
            }
        }
        live.retain(|l| !l.1.finished());
    }

    // ---- compare ---------------------------------------------------------
    for (i, (seq, got)) in sequential.iter().zip(&results).enumerate() {
        let Some(got) = got else {
            return Err(format!("lane {i}: batched replay never finished"));
        };
        if got.stats.tokens != seq.stats.tokens {
            return Err(format!(
                "lane {i} ({:?}): batched tokens {:?} != sequential {:?}",
                lanes[i].mode, got.stats.tokens, seq.stats.tokens
            ));
        }
        if !got.stats.same_generation(&seq.stats) {
            return Err(format!(
                "lane {i} ({:?}): stats diverge: batched {:?} vs sequential {:?}",
                lanes[i].mode, got.stats, seq.stats
            ));
        }
        if got.chunks != seq.chunks {
            return Err(format!(
                "lane {i} ({:?}): emission boundaries diverge: {:?} vs {:?}",
                lanes[i].mode, got.chunks, seq.chunks
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_target_scripts_greedy_stream() {
        let t = MockTarget::new(vec![7, 8, 9]);
        let (lg, mut st) = t.prefill(&[], &[], 0).unwrap();
        assert_eq!(crate::spec::sampler::argmax(&lg), 7);
        let lg = t.decode(&mut st, 7).unwrap();
        assert_eq!(crate::spec::sampler::argmax(&lg), 8);
        assert_eq!(st.pos, 1);
    }

    #[test]
    fn mock_verify_rows_follow_positions() {
        let t = MockTarget::new(vec![7, 8, 9, 10, 11, 12, 13, 14]);
        let mut st = dummy_state();
        let rows = t.verify(&mut st, &[7, 8, 9, 10, 11, 12]).unwrap();
        for i in 0..6 {
            assert_eq!(crate::spec::sampler::argmax(rows.row(i)), 8 + i);
        }
    }

    #[test]
    fn mock_draft_proposes_positionally() {
        let d = MockDraft::new(vec![5, 6, 7, 8, 9, 10, 11]);
        let mut st = dummy_state();
        st.pos = 2;
        let out = d.draft(&mut st, 0, 0.0, 0).unwrap();
        assert_eq!(out.tokens, vec![8, 9, 10, 11, 5]); // cyclic wrap at idx 7
    }

    #[test]
    fn mock_tree_draft_builds_trie_over_scripts() {
        // scripts agree on the first token then diverge
        let d = MockTreeDraft::new(vec![vec![5, 6, 7, 8, 9, 10], vec![5, 6, 40, 41, 42, 43]]);
        let mut st = dummy_state();
        let cfg = TreeConfig { branch: vec![2, 2, 2], max_nodes: 16 };
        let tree = d.draft_tree(&mut st, 0, &cfg, 0.0, 0).unwrap();
        // shared prefix [6, 7? no: window starts at pos+1 = scripts[..][1..]]
        // window A = [6, 7, 8], window B = [6, 40, 41]: trie = 6 -> {7->8, 40->41}
        assert_eq!(tree.len(), 5);
        assert_eq!(tree.children_of(None).count(), 1);
        let root = tree.children_of(None).next().unwrap();
        assert_eq!(tree.tokens[root], 6);
        assert_eq!(tree.children_of(Some(root)).count(), 2);
        assert_eq!(tree.leaf_count(), 2);
    }

    #[test]
    fn mock_verify_tree_rows_by_depth() {
        let t = MockTarget::new(vec![7, 8, 9, 10, 11, 12, 13, 14]);
        let d = MockTreeDraft::new(vec![vec![7, 8, 9, 10], vec![7, 8, 30, 31]]);
        let mut st = dummy_state();
        let cfg = TreeConfig { branch: vec![2, 2], max_nodes: 8 };
        let tree = d.draft_tree(&mut st, 7, &cfg, 0.0, 0).unwrap();
        let mut ts = dummy_state();
        let rows = t.verify_tree(&mut ts, 7, &tree, MOCK_GAMMA).unwrap();
        assert_eq!(rows.dims, vec![tree.len() + 1, MOCK_VOCAB]);
        // row 0 predicts stream index 1 -> token 8
        assert_eq!(crate::spec::sampler::argmax(rows.row(0)), 8);
        // every node at depth d gets the row predicting stream index d + 2
        for (i, &d) in tree.depths.iter().enumerate() {
            assert_eq!(crate::spec::sampler::argmax(rows.row(i + 1)), 9 + d);
        }
    }
}
