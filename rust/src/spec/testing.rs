//! Scripted mock backends for decoder-logic tests (no PJRT involved).
//!
//! Semantics: a mock target deterministically "wants" the token stream
//! `script[0], script[1], ...` -- prefill returns one-hot logits for
//! `script[0]`; a verify window whose first token is written at stream
//! position `st.pos` returns one-hot rows for `script[pos+1 ..= pos+gamma+1]`.
//! A mock drafter proposes its own script the same way.  Greedy speculative
//! decoding against these mocks must reproduce the target script exactly,
//! with acceptance counts equal to per-window prefix agreement -- which is
//! what the tests in spec::decoder assert.
//!
//! `SeqState.pos` is reused as the *stream* position (the mocks have no KV
//! cache; the dummy literal is never read).

use anyhow::Result;

use crate::models::{DraftOutput, SeqState};
use crate::runtime::Tensor;
use crate::spec::decoder::{DraftBackend, SpecParams, TargetBackend};

pub const MOCK_VOCAB: usize = 100;
pub const MOCK_EOS: i32 = 2;
pub const MOCK_GAMMA: usize = 5;

/// Standard params used by the mock tests.
pub fn params() -> SpecParams {
    SpecParams { gamma: MOCK_GAMMA, eos_id: MOCK_EOS, gen_max: 48 }
}

fn one_hot(tok: i32) -> Vec<f32> {
    let mut row = vec![0.0f32; MOCK_VOCAB];
    row[(tok as usize).min(MOCK_VOCAB - 1)] = 1.0;
    row
}

fn dummy_state() -> SeqState {
    SeqState { kv: xla::Literal::scalar(0.0f32), pos: 0 }
}

/// A target that greedily emits `script` (cyclic past the end, so budget
/// tests can run without EOS).
pub struct MockTarget {
    pub script: Vec<i32>,
}

impl MockTarget {
    pub fn new(script: Vec<i32>) -> Self {
        assert!(!script.is_empty());
        MockTarget { script }
    }

    fn at(&self, i: i32) -> i32 {
        self.script[(i.max(0) as usize) % self.script.len()]
    }
}

impl TargetBackend for MockTarget {
    fn prefill(&self, _image: &[f32], _prompt: &[i32], _len: usize) -> Result<(Vec<f32>, SeqState)> {
        Ok((one_hot(self.at(0)), dummy_state()))
    }

    fn verify(&self, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        // row i conditions on the prefix ending at tokens[i] (stream index
        // st.pos + i) and predicts the token at stream index st.pos + i + 1
        let rows: Vec<f32> = (0..tokens.len())
            .flat_map(|i| one_hot(self.at(st.pos + i as i32 + 1)))
            .collect();
        Tensor::new(rows, vec![tokens.len(), MOCK_VOCAB])
    }

    fn decode(&self, st: &mut SeqState, _token: i32) -> Result<Vec<f32>> {
        let out = one_hot(self.at(st.pos + 1));
        st.pos += 1;
        Ok(out)
    }
}

/// A drafter that proposes its own script (cyclic), independent of the
/// tokens it is fed -- agreement with the target is purely positional,
/// which makes expected acceptance counts trivially computable in tests.
pub struct MockDraft {
    pub script: Vec<i32>,
}

impl MockDraft {
    pub fn new(script: Vec<i32>) -> Self {
        assert!(!script.is_empty());
        MockDraft { script }
    }

    fn at(&self, i: i32) -> i32 {
        self.script[(i.max(0) as usize) % self.script.len()]
    }
}

impl DraftBackend for MockDraft {
    fn prefill(
        &self,
        _image: Option<&[f32]>,
        _prompt: &[i32],
        _len: usize,
        _text_only: bool,
    ) -> Result<SeqState> {
        Ok(dummy_state())
    }

    fn draft(
        &self,
        st: &mut SeqState,
        _last: i32,
        _temperature: f32,
        _seed: u32,
    ) -> Result<DraftOutput> {
        let tokens: Vec<i32> = (0..MOCK_GAMMA).map(|i| self.at(st.pos + 1 + i as i32)).collect();
        let qlogits = Tensor::new(
            tokens.iter().flat_map(|&t| one_hot(t)).collect(),
            vec![MOCK_GAMMA, MOCK_VOCAB],
        )?;
        Ok(DraftOutput { tokens, qlogits })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_target_scripts_greedy_stream() {
        let t = MockTarget::new(vec![7, 8, 9]);
        let (lg, mut st) = t.prefill(&[], &[], 0).unwrap();
        assert_eq!(crate::spec::sampler::argmax(&lg), 7);
        let lg = t.decode(&mut st, 7).unwrap();
        assert_eq!(crate::spec::sampler::argmax(&lg), 8);
        assert_eq!(st.pos, 1);
    }

    #[test]
    fn mock_verify_rows_follow_positions() {
        let t = MockTarget::new(vec![7, 8, 9, 10, 11, 12, 13, 14]);
        let mut st = dummy_state();
        let rows = t.verify(&mut st, &[7, 8, 9, 10, 11, 12]).unwrap();
        for i in 0..6 {
            assert_eq!(crate::spec::sampler::argmax(rows.row(i)), 8 + i);
        }
    }

    #[test]
    fn mock_draft_proposes_positionally() {
        let d = MockDraft::new(vec![5, 6, 7, 8, 9, 10, 11]);
        let mut st = dummy_state();
        st.pos = 2;
        let out = d.draft(&mut st, 0, 0.0, 0).unwrap();
        assert_eq!(out.tokens, vec![8, 9, 10, 11, 5]); // cyclic wrap at idx 7
    }
}
