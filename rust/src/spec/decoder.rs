//! The speculative decoding engine: drive a (target, drafter) pair through
//! prefill -> [draft gamma -> verify -> accept]* for one request.
//!
//! The iteration loop itself lives in `spec::session::DecodeSession` (a
//! resumable state machine the serving engine schedules step by step); the
//! `generate*` entry points here are blocking drivers over it, kept for
//! the eval harness, the examples, and the decoder-level property tests.
//!
//! The decoder is generic over `TargetBackend`/`DraftBackend` so its logic
//! (EOS handling, budget truncation, MAL accounting, cache-position
//! bookkeeping) is unit-testable against scripted mocks (`spec::testing`)
//! without a PJRT runtime; the real `models::{TargetModel, DraftModel}`
//! implement the same traits over compiled artifacts.
//!
//! Position bookkeeping (DESIGN.md section 3): both models keep absolute
//! positions into their own KV caches.  The drafter only ever *misses* the
//! target-sampled token of each iteration (correction or bonus), which is
//! fed to it as `last` on the next draft call -- so both caches stay
//! consistent without any rollback (stale tails are position-masked).

use anyhow::{anyhow, Result};

use crate::manifest::Manifest;
use crate::models::{DraftModel, DraftOutput, SeqState, TargetModel, VisionEncoding};
use crate::runtime::Tensor;
use crate::spec::adaptive::SpecMode;
use crate::spec::sampler;
use crate::spec::session::{DecodeSession, NoDraft};
use crate::spec::tree::{DraftTree, TreeConfig};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Backend abstraction
// ---------------------------------------------------------------------------

/// Target-model operations the decoder needs.
pub trait TargetBackend {
    fn prefill(&self, image: &[f32], prompt: &[i32], len: usize) -> Result<(Vec<f32>, SeqState)>;

    /// Prefill stage 1: the prompt-independent image encode (cacheable by
    /// content hash, shared with the drafter).  Backends without a
    /// separable vision stage wrap the raw pixels, so stage 2 degenerates
    /// to the fused `prefill`.
    fn encode_image(&self, image: &[f32]) -> Result<VisionEncoding> {
        Ok(VisionEncoding::raw(image))
    }

    /// Prefill stage 2: build the post-prefill state from an encoding.
    fn prefill_encoded(
        &self,
        enc: &VisionEncoding,
        prompt: &[i32],
        len: usize,
    ) -> Result<(Vec<f32>, SeqState)> {
        match enc.pixels() {
            Some(px) => self.prefill(px, prompt, len),
            None => Err(anyhow!(
                "this target backend cannot prefill from a non-raw vision encoding"
            )),
        }
    }
    /// Verify gamma+1 tokens written at `st.pos`; returns [(gamma+1) x V]
    /// logits.  Must NOT advance `st.pos` (the decoder advances by the
    /// accepted count).
    fn verify(&self, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor>;
    /// Single decode step; writes at `st.pos` and advances it.
    fn decode(&self, st: &mut SeqState, token: i32) -> Result<Vec<f32>>;

    /// Verify a flattened draft tree rooted after `last` (written at
    /// `st.pos`) in ONE forward pass.  Returns `[(n+1) x V]` logits: row 0
    /// conditions on the prefix ending at `last`, row `i+1` on the
    /// root-to-node-`i` path.  Must NOT advance `st.pos` (the decoder
    /// advances by the accepted path length).
    ///
    /// The default linearizes chain-shaped trees through `verify` --
    /// backends whose verify entry point has no tree-attention mask (the
    /// fixed-window PJRT executables) still serve tree-mode requests for
    /// degenerate trees; genuinely branching trees need an override
    /// (scripted/mock backends provide one).
    fn verify_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        tree: &DraftTree,
        gamma: usize,
    ) -> Result<Tensor> {
        verify_tree_linearized(self, st, last, tree, gamma)
    }

    /// Batched single-token decode across independent lanes.  The default
    /// computes each lane from its own per-sequence state in lane order --
    /// exactly the sequential semantics, so lane order cannot leak between
    /// requests.  Backends with a batched executable override this to pack
    /// along a batch axis (`models::TargetModel`).  Per-lane `Result`s
    /// isolate one faulty lane from the rest of the batch.
    fn decode_batch(&self, lanes: &mut [(&mut SeqState, i32)]) -> Vec<Result<Vec<f32>>> {
        lanes.iter_mut().map(|(st, tok)| self.decode(st, *tok)).collect()
    }

    /// Batched (gamma+1)-window verification across independent lanes
    /// (see `decode_batch` for the lane-isolation contract).
    fn verify_batch(&self, lanes: &mut [(&mut SeqState, &[i32])]) -> Vec<Result<Tensor>> {
        lanes.iter_mut().map(|(st, toks)| self.verify(st, *toks)).collect()
    }

    /// Batched flattened-tree verification across independent lanes.
    fn verify_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &DraftTree)],
        gamma: usize,
    ) -> Vec<Result<Tensor>> {
        lanes
            .iter_mut()
            .map(|(st, last, tree)| self.verify_tree(st, *last, *tree, gamma))
            .collect()
    }
}

/// Chain-fallback tree verification: pad the linearized tree to the fixed
/// `gamma + 1` verify window and slice the rows back down.  Trailing pad
/// tokens only condition rows we never read, so the result is exact.
pub(crate) fn verify_tree_linearized<T: TargetBackend + ?Sized>(
    target: &T,
    st: &mut SeqState,
    last: i32,
    tree: &DraftTree,
    gamma: usize,
) -> Result<Tensor> {
    let Some(chain) = tree.as_chain() else {
        return Err(anyhow::anyhow!(
            "this target backend only supports chain-shaped tree verification \
             (branching trees need a tree-attention verify entry point)"
        ));
    };
    if chain.len() > gamma {
        return Err(anyhow::anyhow!(
            "tree depth {} exceeds the verify window gamma={gamma}",
            chain.len()
        ));
    }
    let mut v = Vec::with_capacity(gamma + 1);
    v.push(last);
    v.extend_from_slice(&chain);
    let pad = *v.last().unwrap();
    v.resize(gamma + 1, pad);
    let full = target.verify(st, &v)?;
    let rows = tree.len() + 1;
    let w = full.dims[1];
    Tensor::new(full.data[..rows * w].to_vec(), vec![rows, w])
}

/// Backends are used through shared references (the decode loop only needs
/// `&self`; per-sequence mutability lives in `SeqState`), so a `&T` is a
/// backend too -- which lets `DecodeSession` either own its backends (the
/// serving engine) or borrow them (the blocking `generate*` wrappers).
impl<T: TargetBackend + ?Sized> TargetBackend for &T {
    fn prefill(&self, image: &[f32], prompt: &[i32], len: usize) -> Result<(Vec<f32>, SeqState)> {
        (**self).prefill(image, prompt, len)
    }

    fn encode_image(&self, image: &[f32]) -> Result<VisionEncoding> {
        (**self).encode_image(image)
    }

    fn prefill_encoded(
        &self,
        enc: &VisionEncoding,
        prompt: &[i32],
        len: usize,
    ) -> Result<(Vec<f32>, SeqState)> {
        (**self).prefill_encoded(enc, prompt, len)
    }

    fn verify(&self, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        (**self).verify(st, tokens)
    }

    fn decode(&self, st: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        (**self).decode(st, token)
    }

    fn verify_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        tree: &DraftTree,
        gamma: usize,
    ) -> Result<Tensor> {
        (**self).verify_tree(st, last, tree, gamma)
    }

    fn decode_batch(&self, lanes: &mut [(&mut SeqState, i32)]) -> Vec<Result<Vec<f32>>> {
        (**self).decode_batch(lanes)
    }

    fn verify_batch(&self, lanes: &mut [(&mut SeqState, &[i32])]) -> Vec<Result<Tensor>> {
        (**self).verify_batch(lanes)
    }

    fn verify_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &DraftTree)],
        gamma: usize,
    ) -> Vec<Result<Tensor>> {
        (**self).verify_tree_batch(lanes, gamma)
    }
}

/// Drafter operations the decoder needs.
pub trait DraftBackend {
    fn prefill(
        &self,
        image: Option<&[f32]>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
    ) -> Result<SeqState>;

    /// Prefill from a shared vision encoding (the target's stage-1 output
    /// is reused by the drafter so one cached encode serves both models).
    ///
    /// `vision_ratio` compresses the vision token sequence *for the drafter
    /// only* (1 = full resolution; 4/16 = pooled).  The target always sees
    /// full resolution, so acceptance -- and therefore the emitted token
    /// stream -- is unchanged; only the drafter's prefill cost and its
    /// agreement rate move.  The default pools raw pixels blockwise.
    fn prefill_encoded(
        &self,
        enc: Option<&VisionEncoding>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
        vision_ratio: u32,
    ) -> Result<SeqState> {
        match enc {
            None => self.prefill(None, prompt, len, text_only),
            Some(e) => match e.pooled_pixels(vision_ratio) {
                Some(px) => self.prefill(Some(px.as_slice()), prompt, len, text_only),
                None => Err(anyhow!(
                    "this draft backend cannot prefill from a non-raw vision encoding"
                )),
            },
        }
    }
    /// Fused gamma-token draft starting from `last` written at `st.pos`.
    /// Advances `st.pos` past `last` only.
    fn draft(&self, st: &mut SeqState, last: i32, temperature: f32, seed: u32)
        -> Result<DraftOutput>;

    /// Draft a token tree from `last`.  The default degenerates to the
    /// chain produced by `draft` truncated to the configured depth (fused
    /// PJRT drafters have no tree entry point); scripted/mock drafters
    /// override this with genuine top-k branching.
    fn draft_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        cfg: &TreeConfig,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftTree> {
        draft_tree_via_chain(self, st, last, cfg, temperature, seed)
    }

    /// Batched fused drafting across independent lanes, each with its own
    /// (last, temperature, seed) -- per-lane sampling state, so lane order
    /// cannot leak between requests.  Default loops; backends with a
    /// batched executable pack along a batch axis (`models::DraftModel`).
    #[allow(clippy::type_complexity)]
    fn draft_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, f32, u32)],
    ) -> Vec<Result<DraftOutput>> {
        lanes
            .iter_mut()
            .map(|(st, last, t, seed)| self.draft(st, *last, *t, *seed))
            .collect()
    }

    /// Batched tree drafting across independent lanes (per-lane tree
    /// shape; see `draft_batch` for the lane-isolation contract).
    #[allow(clippy::type_complexity)]
    fn draft_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &TreeConfig, f32, u32)],
    ) -> Vec<Result<DraftTree>> {
        lanes
            .iter_mut()
            .map(|(st, last, cfg, t, seed)| self.draft_tree(st, *last, *cfg, *t, *seed))
            .collect()
    }
}

/// Chain-fallback tree drafting shared by the trait default and the PJRT
/// `DraftModel` path.
pub(crate) fn draft_tree_via_chain<D: DraftBackend + ?Sized>(
    drafter: &D,
    st: &mut SeqState,
    last: i32,
    cfg: &TreeConfig,
    temperature: f32,
    seed: u32,
) -> Result<DraftTree> {
    let out = drafter.draft(st, last, temperature, seed)?;
    let depth = cfg.depth().min(out.tokens.len()).min(cfg.max_nodes);
    if depth == out.tokens.len() {
        return Ok(DraftTree::chain(out.tokens, out.qlogits));
    }
    let w = out.qlogits.dims[1];
    Ok(DraftTree::chain(
        out.tokens[..depth].to_vec(),
        Tensor::new(out.qlogits.data[..depth * w].to_vec(), vec![depth, w])?,
    ))
}

impl<D: DraftBackend + ?Sized> DraftBackend for &D {
    fn prefill(
        &self,
        image: Option<&[f32]>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
    ) -> Result<SeqState> {
        (**self).prefill(image, prompt, len, text_only)
    }

    fn prefill_encoded(
        &self,
        enc: Option<&VisionEncoding>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
        vision_ratio: u32,
    ) -> Result<SeqState> {
        (**self).prefill_encoded(enc, prompt, len, text_only, vision_ratio)
    }

    fn draft(
        &self,
        st: &mut SeqState,
        last: i32,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftOutput> {
        (**self).draft(st, last, temperature, seed)
    }

    fn draft_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        cfg: &TreeConfig,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftTree> {
        (**self).draft_tree(st, last, cfg, temperature, seed)
    }

    #[allow(clippy::type_complexity)]
    fn draft_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, f32, u32)],
    ) -> Vec<Result<DraftOutput>> {
        (**self).draft_batch(lanes)
    }

    #[allow(clippy::type_complexity)]
    fn draft_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &TreeConfig, f32, u32)],
    ) -> Vec<Result<DraftTree>> {
        (**self).draft_tree_batch(lanes)
    }
}

impl TargetBackend for TargetModel {
    fn prefill(&self, image: &[f32], prompt: &[i32], len: usize) -> Result<(Vec<f32>, SeqState)> {
        self.prefill_mm(image, prompt, len)
    }

    fn encode_image(&self, image: &[f32]) -> Result<VisionEncoding> {
        TargetModel::encode_image(self, image)
    }

    fn prefill_encoded(
        &self,
        enc: &VisionEncoding,
        prompt: &[i32],
        len: usize,
    ) -> Result<(Vec<f32>, SeqState)> {
        TargetModel::prefill_encoded(self, enc, prompt, len)
    }

    fn verify(&self, st: &mut SeqState, tokens: &[i32]) -> Result<Tensor> {
        TargetModel::verify(self, st, tokens)
    }

    fn decode(&self, st: &mut SeqState, token: i32) -> Result<Vec<f32>> {
        TargetModel::decode(self, st, token)
    }

    fn verify_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        tree: &DraftTree,
        gamma: usize,
    ) -> Result<Tensor> {
        TargetModel::verify_tree(self, st, last, tree, gamma)
    }

    fn decode_batch(&self, lanes: &mut [(&mut SeqState, i32)]) -> Vec<Result<Vec<f32>>> {
        TargetModel::decode_batch(self, lanes)
    }

    fn verify_batch(&self, lanes: &mut [(&mut SeqState, &[i32])]) -> Vec<Result<Tensor>> {
        TargetModel::verify_batch(self, lanes)
    }

    fn verify_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &DraftTree)],
        gamma: usize,
    ) -> Vec<Result<Tensor>> {
        TargetModel::verify_tree_batch(self, lanes, gamma)
    }
}

impl DraftBackend for DraftModel {
    fn prefill(
        &self,
        image: Option<&[f32]>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
    ) -> Result<SeqState> {
        DraftModel::prefill(self, image, prompt, len, text_only)
    }

    fn prefill_encoded(
        &self,
        enc: Option<&VisionEncoding>,
        prompt: &[i32],
        len: usize,
        text_only: bool,
        vision_ratio: u32,
    ) -> Result<SeqState> {
        DraftModel::prefill_encoded(self, enc, prompt, len, text_only, vision_ratio)
    }

    fn draft(
        &self,
        st: &mut SeqState,
        last: i32,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftOutput> {
        DraftModel::draft(self, st, last, temperature, seed)
    }

    fn draft_tree(
        &self,
        st: &mut SeqState,
        last: i32,
        cfg: &TreeConfig,
        temperature: f32,
        seed: u32,
    ) -> Result<DraftTree> {
        DraftModel::draft_tree(self, st, last, cfg, temperature, seed)
    }

    #[allow(clippy::type_complexity)]
    fn draft_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, f32, u32)],
    ) -> Vec<Result<DraftOutput>> {
        DraftModel::draft_batch(self, lanes)
    }

    #[allow(clippy::type_complexity)]
    fn draft_tree_batch(
        &self,
        lanes: &mut [(&mut SeqState, i32, &TreeConfig, f32, u32)],
    ) -> Vec<Result<DraftTree>> {
        DraftModel::draft_tree_batch(self, lanes)
    }
}

/// Decoding-invariant parameters (from the artifact manifest, or synthetic
/// for tests).
#[derive(Debug, Clone)]
pub struct SpecParams {
    pub gamma: usize,
    pub eos_id: i32,
    pub gen_max: usize,
    /// Default tree shape for `DecodeMode::Tree` requests (overridable per
    /// request via `GenConfig::tree`).
    pub tree: TreeConfig,
}

impl SpecParams {
    pub fn from_manifest(m: &Manifest) -> SpecParams {
        SpecParams {
            gamma: m.gamma,
            eos_id: m.eos_id,
            gen_max: m.gen_max,
            tree: TreeConfig::for_depth(m.gamma),
        }
    }
}

// ---------------------------------------------------------------------------
// Generation config + stats
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct GenConfig {
    pub temperature: f32,
    pub top_p: f32,
    pub max_new: usize,
    pub seed: u64,
    /// Per-request tree-shape override for tree-mode decoding; `None` uses
    /// the engine default from `SpecParams::tree`.
    pub tree: Option<TreeConfig>,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { temperature: 0.0, top_p: 1.0, max_new: 48, seed: 0, tree: None }
    }
}

/// Per-request generation record (everything the eval harness needs).
///
/// Per-iteration quantities are folded into streaming summaries
/// (sum/count/max) instead of per-iteration Vecs, so a long-running
/// session's record stays O(1) regardless of how many speculative
/// iterations it executes.
#[derive(Debug, Clone, Default)]
pub struct GenStats {
    pub tokens: Vec<i32>,
    /// number of verify (target forward) calls == SD iterations
    pub verify_calls: usize,
    pub draft_calls: usize,
    /// draft tokens accepted, summed over iterations
    pub accepted_draft: usize,
    /// number of iterations that emitted tokens (speculative windows or
    /// target-only decode steps)
    pub iters: usize,
    /// tokens emitted summed over iterations (accepted + the
    /// target-sampled one per iteration)
    pub emitted_sum: usize,
    /// most tokens emitted by any single iteration
    pub emitted_max: usize,
    pub prefill_micros: u64,
    /// drafter share of `prefill_micros` (the drafter's own prefill
    /// forward pass; shrinks with `draft_vision_ratio` compression)
    pub draft_prefill_micros: u64,
    pub decode_micros: u64,
    pub finished_by_eos: bool,
    /// iteration index at which an adaptive controller abandoned
    /// speculation (None = stayed speculative throughout)
    pub fallback_at: Option<usize>,
    /// number of tree-mode iterations (0 for chain/target-only decoding)
    pub tree_iters: usize,
    /// accepted root-to-leaf path length summed over tree iterations
    pub path_depth_sum: usize,
    /// deepest accepted root-to-leaf path of any tree iteration
    pub path_depth_max: usize,
    /// total candidate nodes drafted across tree-mode iterations
    pub tree_nodes_drafted: usize,
    /// true when prefill was served from the prefix cache (forked KV
    /// snapshots instead of model forward passes)
    pub prefill_cache_hit: bool,
    /// image-encode share of `prefill_micros` (0 on prefix-cache hits and
    /// for requests whose vision encoding was already cached)
    pub encode_micros: u64,
}

impl GenStats {
    /// Record one iteration's emitted-token count.
    pub(crate) fn record_emitted(&mut self, emitted: usize) {
        self.iters += 1;
        self.emitted_sum += emitted;
        self.emitted_max = self.emitted_max.max(emitted);
    }

    /// Record one tree iteration's accepted root-to-leaf path length.
    pub(crate) fn record_path_depth(&mut self, depth: usize) {
        self.tree_iters += 1;
        self.path_depth_sum += depth;
        self.path_depth_max = self.path_depth_max.max(depth);
    }

    /// Mean accepted length tau: tokens emitted per target forward pass
    /// (accepted drafts + the correction/bonus token), the paper's metric.
    pub fn mal(&self) -> f64 {
        if self.verify_calls == 0 {
            return 0.0;
        }
        self.emitted_sum as f64 / self.verify_calls as f64
    }

    pub fn total_micros(&self) -> u64 {
        self.prefill_micros + self.decode_micros
    }

    /// Mean accepted root-to-leaf path length over tree iterations.
    pub fn mean_path_depth(&self) -> f64 {
        if self.tree_iters == 0 {
            return 0.0;
        }
        self.path_depth_sum as f64 / self.tree_iters as f64
    }

    /// Equality modulo wall-clock timing (`*_micros`) and cache provenance
    /// (`prefill_cache_hit`) -- the relation the cold-vs-warm prefill
    /// losslessness property asserts: every semantic field of the
    /// generation record must be bit-identical.
    pub fn same_generation(&self, other: &GenStats) -> bool {
        self.tokens == other.tokens
            && self.verify_calls == other.verify_calls
            && self.draft_calls == other.draft_calls
            && self.accepted_draft == other.accepted_draft
            && self.iters == other.iters
            && self.emitted_sum == other.emitted_sum
            && self.emitted_max == other.emitted_max
            && self.finished_by_eos == other.finished_by_eos
            && self.fallback_at == other.fallback_at
            && self.tree_iters == other.tree_iters
            && self.path_depth_sum == other.path_depth_sum
            && self.path_depth_max == other.path_depth_max
            && self.tree_nodes_drafted == other.tree_nodes_drafted
    }

    /// Fraction of drafted tree nodes that ended up on an accepted path
    /// (branch utilization; 0.0 when no tree iterations ran).
    pub fn branch_utilization(&self) -> f64 {
        if self.tree_nodes_drafted == 0 {
            return 0.0;
        }
        self.path_depth_sum as f64 / self.tree_nodes_drafted as f64
    }
}

// ---------------------------------------------------------------------------
// The decoder
// ---------------------------------------------------------------------------

pub struct SpecDecoder<T: TargetBackend = TargetModel, D: DraftBackend = DraftModel> {
    pub target: T,
    pub drafter: D,
    pub params: SpecParams,
    /// Table-3 mode: run a multimodal drafter with visual tokens discarded.
    pub text_only_draft: bool,
}

impl SpecDecoder<TargetModel, DraftModel> {
    /// Production constructor: parameters come from the artifact manifest.
    pub fn new(target: TargetModel, drafter: DraftModel) -> Self {
        let params = SpecParams::from_manifest(&target.set.manifest);
        SpecDecoder { target, drafter, params, text_only_draft: false }
    }
}

impl<T: TargetBackend, D: DraftBackend> SpecDecoder<T, D> {
    /// Test/extension constructor with explicit backends + params.
    pub fn with_params(target: T, drafter: D, params: SpecParams) -> Self {
        SpecDecoder { target, drafter, params, text_only_draft: false }
    }

    /// Generate with speculative decoding.  `prompt` is padded to p_max;
    /// `len` is the true prompt length (incl. <bos>/<sep>).
    pub fn generate(
        &self,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        DecodeSession::new(
            &self.target,
            Some(&self.drafter),
            self.params.clone(),
            cfg.clone(),
            Some(SpecMode::Chain),
            None,
            self.text_only_draft,
        )
        .run_to_completion(image, prompt, len)
    }

    /// Generate with token-tree speculation: each iteration drafts a
    /// candidate tree, verifies every node in one target call, and accepts
    /// the longest root-to-leaf path losslessly
    /// (`acceptance::accept_tree_*`).  Position bookkeeping matches the
    /// chain path: both caches advance past `last` plus the accepted path;
    /// rejected branches are stale tail that the backends position-mask.
    pub fn generate_tree(
        &self,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        DecodeSession::new(
            &self.target,
            Some(&self.drafter),
            self.params.clone(),
            cfg.clone(),
            Some(SpecMode::Tree),
            None,
            self.text_only_draft,
        )
        .run_to_completion(image, prompt, len)
    }
}

/// Non-speculative target-only decoding (the 1.00x reference for every
/// speedup number in the paper's tables).
pub fn generate_baseline<T: TargetBackend>(
    target: &T,
    params: &SpecParams,
    image: &[f32],
    prompt: &[i32],
    len: usize,
    cfg: &GenConfig,
) -> Result<GenStats> {
    DecodeSession::<&T, NoDraft>::new(
        target,
        None,
        params.clone(),
        cfg.clone(),
        None,
        None,
        false,
    )
    .run_to_completion(image, prompt, len)
}

impl SpecDecoder<TargetModel, DraftModel> {
    /// Back-compat wrapper used by the engine/eval harness.
    pub fn generate_baseline(
        target: &TargetModel,
        image: &[f32],
        prompt: &[i32],
        len: usize,
        cfg: &GenConfig,
    ) -> Result<GenStats> {
        let params = SpecParams::from_manifest(&target.set.manifest);
        generate_baseline(target, &params, image, prompt, len, cfg)
    }
}

/// Sample one token from raw logits under (temperature, top_p).
pub(crate) fn sample_token(
    logits: &[f32],
    cfg: &GenConfig,
    probs: &mut Vec<f32>,
    rng: &mut Rng,
) -> i32 {
    if cfg.temperature <= 0.0 {
        return sampler::argmax(logits) as i32;
    }
    sampler::softmax_t(logits, cfg.temperature, probs);
    let mut perm = Vec::new();
    sampler::top_p_filter(probs, cfg.top_p, &mut perm);
    sampler::sample(probs, rng) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spec::testing::{params, MockDraft, MockTarget};

    fn greedy() -> GenConfig {
        GenConfig::default()
    }

    #[test]
    fn perfect_drafter_emits_full_windows() {
        // drafter script == target script: every window fully accepted
        let script = vec![5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 2]; // ends with EOS(2)
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(script.clone()),
            params(),
        );
        let stats = dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, script);
        assert!(stats.finished_by_eos);
        // 13 tokens: 1 free from prefill, then windows of up to 6
        assert_eq!(stats.verify_calls, 2);
        assert_eq!(stats.iters, 2);
        assert_eq!(stats.emitted_sum, 12);
        assert_eq!(stats.emitted_max, 6);
        assert!((stats.mal() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn hopeless_drafter_still_lossless_one_token_per_iter() {
        let script = vec![5, 6, 7, 8, 9, 2];
        let wrong = vec![50, 51, 52, 53, 54, 55, 56, 57, 58, 59, 60, 61];
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(wrong),
            params(),
        );
        let stats = dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, script, "losslessness must hold even for garbage drafts");
        assert_eq!(stats.accepted_draft, 0);
        // every iteration emits exactly the correction token
        assert_eq!(stats.emitted_max, 1);
        assert_eq!(stats.emitted_sum, stats.iters);
        assert!((stats.mal() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn partial_agreement_counts_prefix_only() {
        // drafter agrees on the first 2 tokens of each window then diverges
        let script = vec![5, 6, 7, 8, 9, 10, 11, 2];
        let mut dscript = script.clone();
        dscript[2] = 99; // first divergence at stream index 2
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(dscript),
            params(),
        );
        let stats = dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, script);
        // isolate the first window with a tight budget: drafts for idx
        // 1..=5 = [6,7->99 mismatch...], so it emits 1 draft + correction
        let mut cfg = greedy();
        cfg.max_new = 3; // prefill token + first window's 2
        let first = dec.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(first.tokens, script[..3].to_vec());
        assert_eq!(first.iters, 1);
        assert_eq!(first.emitted_sum, 2);
        assert_eq!(first.accepted_draft, 1);
    }

    #[test]
    fn eos_inside_accepted_window_truncates() {
        let script = vec![5, 6, 2, 40, 41, 42, 43, 44]; // EOS at index 2
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(script.clone()),
            params(),
        );
        let stats = dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, vec![5, 6, 2]);
        assert!(stats.finished_by_eos);
        assert_eq!(stats.verify_calls, 1);
    }

    #[test]
    fn eos_as_first_token_short_circuits() {
        let script = vec![2, 9, 9];
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(script),
            params(),
        );
        let stats = dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, vec![2]);
        assert_eq!(stats.verify_calls, 0);
        assert_eq!(stats.draft_calls, 0);
    }

    #[test]
    fn max_new_budget_is_respected() {
        let script: Vec<i32> = (10..60).collect(); // no EOS
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(script.clone()),
            params(),
        );
        let mut cfg = greedy();
        cfg.max_new = 9;
        let stats = dec.generate(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens.len(), 9);
        assert_eq!(stats.tokens, script[..9].to_vec());
        assert!(!stats.finished_by_eos);
    }

    #[test]
    fn baseline_matches_script_and_counts_forwards() {
        let script = vec![5, 6, 7, 2];
        let target = MockTarget::new(script.clone());
        let stats =
            generate_baseline(&target, &params(), &[], &[0; 8], 3, &greedy()).unwrap();
        assert_eq!(stats.tokens, script);
        assert_eq!(stats.verify_calls, 3); // one decode per non-prefill token
        assert!(stats.finished_by_eos);
    }

    #[test]
    fn spec_equals_baseline_for_any_drafter_script() {
        // property: greedy SD output == greedy target output, for random
        // drafter scripts (the losslessness theorem at the decoder level)
        crate::util::prop::propcheck("decoder losslessness", 50, |rng| {
            let n = 3 + rng.range(20);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2); // EOS
            let dscript: Vec<i32> = (0..n + 8)
                .map(|_| {
                    if rng.range(2) == 0 {
                        4 + rng.range(90) as i32
                    } else {
                        2
                    }
                })
                .collect();
            let dec = SpecDecoder::with_params(
                MockTarget::new(script.clone()),
                MockDraft::new(dscript),
                params(),
            );
            let spec = dec.generate(&[], &[0; 8], 3, &GenConfig::default()).unwrap();
            let base = generate_baseline(
                &MockTarget::new(script.clone()),
                &params(),
                &[],
                &[0; 8],
                3,
                &GenConfig::default(),
            )
            .unwrap();
            if spec.tokens != base.tokens {
                return Err(format!("spec {:?} != base {:?}", spec.tokens, base.tokens));
            }
            Ok(())
        });
    }

    // ------------------------------------------------------------ tree mode

    use crate::spec::testing::{MockTreeDraft, MOCK_GAMMA};

    fn wide(depth: usize) -> TreeConfig {
        TreeConfig { branch: vec![3; depth], max_nodes: 32 }
    }

    #[test]
    fn tree_prefix_agreement_accepts_longest_path() {
        // target wants 10,11,12,...; branch A diverges at depth 2, branch B
        // tracks the target all the way -> the accepted path must follow B.
        let script: Vec<i32> = (10..40).collect();
        let mut a = script.clone();
        for i in (2..a.len()).step_by(3) {
            a[i] = 90;
        }
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![a, script.clone()]),
            params(),
        );
        let mut cfg = greedy();
        cfg.tree = Some(wide(5));
        cfg.max_new = 19; // prefill + 3 full iterations of depth 5 + bonus
        let stats = dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens, script[..19].to_vec());
        // every iteration accepts the full 5-deep path + bonus
        // (max == 5 and sum == 5 * count pins all depths at exactly 5)
        assert_eq!(stats.path_depth_max, 5);
        assert_eq!(stats.path_depth_sum, 5 * stats.tree_iters);
        assert!((stats.mal() - 6.0).abs() < 1e-9);
        assert!(stats.tree_nodes_drafted > 5 * stats.verify_calls, "trees must branch");
        assert!(stats.branch_utilization() < 1.0);
    }

    #[test]
    fn tree_zero_agreement_emits_one_token_per_iter() {
        let script = vec![5, 6, 7, 8, 9, 2];
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![vec![50, 51, 52], vec![60, 61, 62]]),
            params(),
        );
        let mut cfg = greedy();
        cfg.tree = Some(wide(5));
        let stats = dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens, script, "losslessness with hopeless branches");
        assert_eq!(stats.path_depth_max, 0);
        assert!(stats.tree_iters > 0);
        assert_eq!(stats.emitted_max, 1);
        assert_eq!(stats.emitted_sum, stats.iters);
        assert!((stats.mal() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tree_eos_inside_accepted_branch_truncates() {
        let script = vec![5, 6, 2, 40, 41, 42, 43, 44]; // EOS at index 2
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![script.clone(), vec![5, 6, 77, 78, 79, 80, 81, 82]]),
            params(),
        );
        let mut cfg = greedy();
        cfg.tree = Some(wide(5));
        let stats = dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens, vec![5, 6, 2]);
        assert!(stats.finished_by_eos);
        assert_eq!(stats.verify_calls, 1);
    }

    #[test]
    fn tree_gen_max_truncates_mid_tree() {
        let script: Vec<i32> = (10..60).collect(); // no EOS
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![script.clone(), vec![90; 8]]),
            params(),
        );
        let mut cfg = greedy();
        cfg.tree = Some(wide(5));
        cfg.max_new = 9; // hits the budget inside the second iteration's path
        let stats = dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(stats.tokens.len(), 9);
        assert_eq!(stats.tokens, script[..9].to_vec());
        assert!(!stats.finished_by_eos);
    }

    #[test]
    fn tree_mal_beats_chain_on_recovering_branches() {
        // chain drafter: the target stream with scattered corruptions --
        // every corrupted position cuts a chain window short.  The tree
        // drafter carries the same corrupted line PLUS a clean line, so the
        // walk always has a branch tracking the target: tree MAL > chain
        // MAL on the same workload, both exactly lossless.
        let script: Vec<i32> = (10..58).collect();
        let mut corrupted = script.clone();
        for i in (2..corrupted.len()).step_by(6) {
            corrupted[i] = 90 + (i % 7) as i32;
        }
        let chain_dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(corrupted.clone()),
            params(),
        );
        let chain = chain_dec.generate(&[], &[0; 8], 3, &greedy()).unwrap();
        let tree_dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![corrupted, script.clone()]),
            params(),
        );
        let mut cfg = greedy();
        cfg.tree = Some(wide(5));
        let tree = tree_dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
        assert_eq!(chain.tokens, tree.tokens, "both lossless");
        assert_eq!(tree.tokens, script, "48-token budget covers the whole script");
        assert!(
            tree.mal() > chain.mal(),
            "tree MAL {:.2} must beat chain MAL {:.2} here",
            tree.mal(),
            chain.mal()
        );
    }

    #[test]
    fn tree_chain_shaped_config_matches_chain_decoder() {
        // with a single-branch drafter and branch factors of 1, tree mode
        // must reproduce chain mode exactly, iteration for iteration
        let script: Vec<i32> = (10..40).collect();
        let mut dscript = script.clone();
        dscript[4] = 99;
        dscript[11] = 99;
        let chain = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(dscript.clone()),
            params(),
        )
        .generate(&[], &[0; 8], 3, &greedy())
        .unwrap();
        let mut cfg = greedy();
        cfg.tree = Some(TreeConfig::chain(MOCK_GAMMA));
        let tree = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockTreeDraft::new(vec![dscript]),
            params(),
        )
        .generate_tree(&[], &[0; 8], 3, &cfg)
        .unwrap();
        assert_eq!(chain.tokens, tree.tokens);
        assert_eq!(chain.iters, tree.iters);
        assert_eq!(chain.emitted_sum, tree.emitted_sum);
        assert_eq!(chain.emitted_max, tree.emitted_max);
        assert_eq!(chain.verify_calls, tree.verify_calls);
    }

    #[test]
    fn prop_tree_spec_equals_baseline_for_any_scripts() {
        // the tree-level losslessness theorem at the decoder level: greedy
        // tree speculation == greedy target decoding for random branch sets
        crate::util::prop::propcheck("tree decoder losslessness", 50, |rng| {
            let n = 3 + rng.range(20);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2); // EOS
            let n_branches = 1 + rng.range(3);
            let scripts: Vec<Vec<i32>> = (0..n_branches)
                .map(|_| {
                    (0..n + 8)
                        .map(|i| {
                            if rng.range(3) == 0 {
                                // often agree with the target stream
                                *script.get(i).unwrap_or(&2)
                            } else if rng.range(2) == 0 {
                                4 + rng.range(90) as i32
                            } else {
                                2
                            }
                        })
                        .collect()
                })
                .collect();
            let dec = SpecDecoder::with_params(
                MockTarget::new(script.clone()),
                MockTreeDraft::new(scripts),
                params(),
            );
            let cfg = GenConfig {
                tree: Some(TreeConfig { branch: vec![3, 2, 2, 1, 1], max_nodes: 16 }),
                ..GenConfig::default()
            };
            let spec = dec.generate_tree(&[], &[0; 8], 3, &cfg).unwrap();
            let base = generate_baseline(
                &MockTarget::new(script.clone()),
                &params(),
                &[],
                &[0; 8],
                3,
                &GenConfig::default(),
            )
            .unwrap();
            if spec.tokens != base.tokens {
                return Err(format!("tree spec {:?} != base {:?}", spec.tokens, base.tokens));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_temperature_sampling_matches_target_only_for_fixed_seeds() {
        // The mocks' sharp one-hot logits make T>0 sampling deterministic,
        // so exact losslessness is testable seed by seed: chain and tree
        // speculative output must equal target-only sampling output.
        crate::util::prop::propcheck("T=1 spec == target-only per seed", 40, |rng| {
            let n = 3 + rng.range(16);
            let mut script: Vec<i32> = (0..n).map(|_| 4 + rng.range(90) as i32).collect();
            script.push(2);
            let dscript: Vec<i32> = (0..n + 8)
                .map(|i| {
                    if rng.range(2) == 0 {
                        *script.get(i).unwrap_or(&2)
                    } else {
                        4 + rng.range(90) as i32
                    }
                })
                .collect();
            let cfg = GenConfig {
                temperature: 1.0,
                seed: rng.next_u64(),
                ..GenConfig::default()
            };
            let base = generate_baseline(
                &MockTarget::new(script.clone()),
                &params(),
                &[],
                &[0; 8],
                3,
                &cfg,
            )
            .unwrap();
            let chain = SpecDecoder::with_params(
                MockTarget::new(script.clone()),
                MockDraft::new(dscript.clone()),
                params(),
            )
            .generate(&[], &[0; 8], 3, &cfg)
            .unwrap();
            if chain.tokens != base.tokens {
                return Err(format!("T=1 chain {:?} != base {:?}", chain.tokens, base.tokens));
            }
            let mut tcfg = cfg.clone();
            tcfg.tree = Some(TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 });
            let tree = SpecDecoder::with_params(
                MockTarget::new(script.clone()),
                MockTreeDraft::new(vec![dscript, script.clone()]),
                params(),
            )
            .generate_tree(&[], &[0; 8], 3, &tcfg)
            .unwrap();
            if tree.tokens != base.tokens {
                return Err(format!("T=1 tree {:?} != base {:?}", tree.tokens, base.tokens));
            }
            Ok(())
        });
    }

    #[test]
    fn batch_defaults_match_per_lane_calls_and_stay_independent() {
        // the trait-default batch entry points must equal per-lane calls,
        // and a lane's result must not depend on its batch position
        let t = MockTarget::new((10..40).collect());
        let mk = |pos: i32| SeqState::new(xla::Literal::scalar(0.0f32), pos, None);
        // forward order
        let (mut a, mut b) = (mk(0), mk(7));
        let mut lanes = vec![(&mut a, 10), (&mut b, 17)];
        let fwd: Vec<Vec<f32>> =
            t.decode_batch(&mut lanes).into_iter().map(|r| r.unwrap()).collect();
        // reverse order over fresh states
        let (mut a2, mut b2) = (mk(0), mk(7));
        let mut lanes = vec![(&mut b2, 17), (&mut a2, 10)];
        let rev: Vec<Vec<f32>> =
            t.decode_batch(&mut lanes).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(fwd[0], rev[1], "lane order must not leak into results");
        assert_eq!(fwd[1], rev[0]);
        assert_eq!(a.pos, 1, "decode advances each lane's own position");
        assert_eq!(b.pos, 8);
        // per-lane reference
        let mut r = mk(0);
        let single = t.decode(&mut r, 10).unwrap();
        assert_eq!(fwd[0], single);

        // verify_batch: windows per lane, positions untouched
        let (mut a, mut b) = (mk(0), mk(3));
        let (wa, wb) = (vec![10; MOCK_GAMMA + 1], vec![13; MOCK_GAMMA + 1]);
        let mut lanes: Vec<(&mut SeqState, &[i32])> = vec![(&mut a, &wa), (&mut b, &wb)];
        let out: Vec<_> = t.verify_batch(&mut lanes).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(a.pos, 0, "verify must not advance positions");
        let mut r = mk(3);
        assert_eq!(out[1].data, t.verify(&mut r, &wb).unwrap().data);
    }

    #[test]
    fn mal_accounting_sums_to_emitted_tokens() {
        let script: Vec<i32> = (10..40).collect();
        let mut dscript = script.clone();
        dscript[4] = 99;
        dscript[11] = 99;
        let dec = SpecDecoder::with_params(
            MockTarget::new(script.clone()),
            MockDraft::new(dscript),
            params(),
        );
        let mut cfg = greedy();
        // 24 = prefill token + 4 full-ish windows; chosen so the budget is
        // reached exactly at an iteration boundary (mid-window truncation
        // legitimately drops the iteration's target token)
        cfg.max_new = 24;
        let stats = dec.generate(&[], &[0; 8], 3, &cfg).unwrap();
        let emitted = stats.emitted_sum;
        // +1 for the prefill free token
        assert_eq!(emitted + 1, stats.tokens.len());
        assert_eq!(
            stats.accepted_draft + stats.verify_calls,
            emitted,
            "each full iteration emits accepted drafts + exactly one target token"
        );
    }
}
