//! Speculative decoding core (the paper's Section 2.1 algorithm + the
//! MASSV serving integration): sampling primitives, acceptance rules, and
//! the per-request decode engine.

pub mod acceptance;
pub mod adaptive;
pub mod calibrate;
pub mod decoder;
pub mod sampler;
pub mod session;
pub mod testing;
pub mod tree;

pub use acceptance::{
    accept_greedy, accept_stochastic, accept_tree_greedy, accept_tree_stochastic, Decision,
    Scratch, TreeDecision,
};
pub use adaptive::{AdaptiveConfig, AdaptiveDecoder, SpecMode};
pub use calibrate::{Calibrator, CalibratorConfig, ClassSnapshot, IterObs};
pub use decoder::{
    generate_baseline, DraftBackend, GenConfig, GenStats, SpecDecoder, SpecParams, TargetBackend,
};
pub use session::{DecodeSession, LaneKind, NoDraft, StepOutcome};
pub use tree::{DraftTree, TreeBuilder, TreeConfig};
