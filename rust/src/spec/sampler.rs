//! Token sampling primitives: temperature softmax, top-p (nucleus)
//! filtering, categorical sampling, and the residual distribution of
//! speculative decoding (Section 2.1).
//!
//! All functions write into caller-provided buffers where it matters --
//! the decoder hot loop runs allocation-free after warmup (section Perf).

use crate::util::rng::Rng;

/// argmax with first-winner tie-breaking (matches jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

/// probs = softmax(logits / temperature); T <= 0 degenerates to a one-hot
/// at the argmax (greedy).  Numerically stable (max-subtracted).
pub fn softmax_t(logits: &[f32], temperature: f32, probs: &mut Vec<f32>) {
    probs.clear();
    probs.resize(logits.len(), 0.0);
    if temperature <= 0.0 {
        probs[argmax(logits)] = 1.0;
        return;
    }
    let inv_t = 1.0 / temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for (p, &l) in probs.iter_mut().zip(logits) {
        let e = ((l - mx) * inv_t).exp();
        *p = e;
        sum += e;
    }
    let inv = 1.0 / sum;
    for p in probs.iter_mut() {
        *p *= inv;
    }
}

/// In-place nucleus filter: keep the smallest prefix of probability mass
/// >= top_p (by descending probability), zero the rest, renormalize.
/// `top_p >= 1.0` is a no-op.  `scratch` holds the sort permutation.
pub fn top_p_filter(probs: &mut [f32], top_p: f32, scratch: &mut Vec<u32>) {
    if top_p >= 1.0 {
        return;
    }
    scratch.clear();
    scratch.extend(0..probs.len() as u32);
    scratch.sort_unstable_by(|&a, &b| {
        probs[b as usize]
            .partial_cmp(&probs[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut acc = 0.0f32;
    let mut cut = probs.len();
    for (rank, &i) in scratch.iter().enumerate() {
        acc += probs[i as usize];
        if acc >= top_p {
            cut = rank + 1;
            break;
        }
    }
    let mut kept = 0.0f32;
    for &i in &scratch[..cut] {
        kept += probs[i as usize];
    }
    for &i in &scratch[cut..] {
        probs[i as usize] = 0.0;
    }
    if kept > 0.0 {
        let inv = 1.0 / kept;
        for p in probs.iter_mut() {
            *p *= inv;
        }
    }
}

/// Draw an index from a (normalized) categorical distribution.
pub fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    let u = rng.f64() as f32;
    let mut acc = 0.0f32;
    let mut last_nonzero = 0;
    for (i, &p) in probs.iter().enumerate() {
        if p > 0.0 {
            last_nonzero = i;
        }
        acc += p;
        if u < acc {
            return i;
        }
    }
    last_nonzero // float round-off fallback
}

/// Indices of the `k` largest values, descending, ties broken by lower
/// index (deterministic).  Used for top-k branching when a draft tree fans
/// a node out over the drafter's most confident continuations.
pub fn top_k_indices(xs: &[f32], k: usize, out: &mut Vec<u32>) {
    out.clear();
    out.extend(0..xs.len() as u32);
    out.sort_unstable_by(|&a, &b| {
        xs[b as usize]
            .partial_cmp(&xs[a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    out.truncate(k);
}

/// Residual distribution norm(max(p - q, 0)) (Section 2.1).  Returns false
/// (and leaves `out` = p) in the degenerate q >= p everywhere case, which
/// can only arise from float round-off when p == q.
pub fn residual(p: &[f32], q: &[f32], out: &mut Vec<f32>) -> bool {
    out.clear();
    out.resize(p.len(), 0.0);
    let mut sum = 0.0f32;
    for i in 0..p.len() {
        let d = (p[i] - q[i]).max(0.0);
        out[i] = d;
        sum += d;
    }
    if sum <= 1e-12 {
        out.copy_from_slice(p);
        return false;
    }
    let inv = 1.0 / sum;
    for v in out.iter_mut() {
        *v *= inv;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{propcheck, random_distribution, small_size};

    #[test]
    fn argmax_first_winner() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn softmax_temperature_zero_is_one_hot() {
        let mut p = Vec::new();
        softmax_t(&[0.1, 2.0, -1.0], 0.0, &mut p);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_normalizes_and_orders() {
        let mut p = Vec::new();
        softmax_t(&[1.0, 2.0, 3.0], 1.0, &mut p);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_low_temperature_sharpens() {
        let (mut a, mut b) = (Vec::new(), Vec::new());
        softmax_t(&[1.0, 2.0], 1.0, &mut a);
        softmax_t(&[1.0, 2.0], 0.25, &mut b);
        assert!(b[1] > a[1]);
    }

    #[test]
    fn softmax_handles_extreme_logits() {
        let mut p = Vec::new();
        softmax_t(&[1e30, -1e30, 0.0], 1.0, &mut p);
        assert!((p[0] - 1.0).abs() < 1e-6);
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn top_p_one_is_noop() {
        let mut probs = vec![0.5, 0.3, 0.2];
        let orig = probs.clone();
        top_p_filter(&mut probs, 1.0, &mut Vec::new());
        assert_eq!(probs, orig);
    }

    #[test]
    fn top_p_keeps_nucleus() {
        let mut probs = vec![0.5, 0.3, 0.15, 0.05];
        top_p_filter(&mut probs, 0.7, &mut Vec::new());
        // 0.5 + 0.3 = 0.8 >= 0.7 -> keep first two, renormalized
        assert!((probs[0] - 0.625).abs() < 1e-5);
        assert!((probs[1] - 0.375).abs() < 1e-5);
        assert_eq!(probs[2], 0.0);
        assert_eq!(probs[3], 0.0);
    }

    #[test]
    fn prop_top_p_normalized_and_subset() {
        propcheck("top_p filtered distribution valid", 300, |rng| {
            let n = small_size(rng, 64);
            let mut p = random_distribution(rng, n);
            let orig = p.clone();
            let tp = 0.05 + 0.9 * rng.f32();
            top_p_filter(&mut p, tp, &mut Vec::new());
            let s: f32 = p.iter().sum();
            if (s - 1.0).abs() > 1e-3 {
                return Err(format!("sum {s}"));
            }
            for i in 0..n {
                if p[i] > 0.0 && orig[i] == 0.0 {
                    return Err("mass created from nothing".into());
                }
            }
            // the most probable token always survives
            if p[argmax(&orig)] <= 0.0 {
                return Err("mode filtered out".into());
            }
            Ok(())
        });
    }

    #[test]
    fn top_k_indices_descending_with_index_ties() {
        let mut out = Vec::new();
        top_k_indices(&[0.1, 5.0, 0.2, 3.0, 5.0], 3, &mut out);
        assert_eq!(out, vec![1, 4, 3]); // 5.0@1 before 5.0@4 (tie by index)
        top_k_indices(&[1.0, 2.0], 10, &mut out);
        assert_eq!(out, vec![1, 0]); // k larger than input
    }

    #[test]
    fn prop_top_k_contains_argmax_first() {
        propcheck("top_k head is argmax", 200, |rng| {
            let n = small_size(rng, 64);
            let p = random_distribution(rng, n);
            let mut out = Vec::new();
            top_k_indices(&p, 1 + rng.range(n), &mut out);
            if out[0] as usize != argmax(&p) {
                return Err(format!("head {} vs argmax {}", out[0], argmax(&p)));
            }
            // descending order
            for w in out.windows(2) {
                if p[w[0] as usize] < p[w[1] as usize] {
                    return Err("not descending".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::seeded(11);
        let probs = vec![0.2, 0.5, 0.3];
        let n = 200_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[sample(&probs, &mut rng)] += 1;
        }
        for i in 0..3 {
            let f = counts[i] as f64 / n as f64;
            assert!((f - probs[i] as f64).abs() < 0.01, "bucket {i}: {f}");
        }
    }

    #[test]
    fn residual_basic() {
        let p = vec![0.6, 0.3, 0.1];
        let q = vec![0.2, 0.5, 0.3];
        let mut r = Vec::new();
        assert!(residual(&p, &q, &mut r));
        assert!((r[0] - 1.0).abs() < 1e-6); // only index 0 has p > q
        assert_eq!(r[1], 0.0);
        assert_eq!(r[2], 0.0);
    }

    #[test]
    fn residual_degenerate_p_equals_q() {
        let p = vec![0.5, 0.5];
        let mut r = Vec::new();
        assert!(!residual(&p, &p.clone(), &mut r));
        assert_eq!(r, p);
    }

    #[test]
    fn prop_residual_is_distribution() {
        propcheck("residual normalized", 300, |rng| {
            let n = small_size(rng, 48);
            let p = random_distribution(rng, n);
            let q = random_distribution(rng, n);
            let mut r = Vec::new();
            residual(&p, &q, &mut r);
            let s: f32 = r.iter().sum();
            if (s - 1.0).abs() > 1e-3 {
                return Err(format!("sum {s}"));
            }
            if r.iter().any(|&v| v < 0.0) {
                return Err("negative mass".into());
            }
            Ok(())
        });
    }
}
