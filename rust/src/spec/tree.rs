//! Token-tree speculation structures (Spec-LLaVA / SpecInfer-style).
//!
//! A `DraftTree` holds the drafter's candidate continuations of the current
//! context as a rooted forest in topological order: node `i` proposes one
//! token conditioned on the root-to-parent path, `parents[i]` is `None` for
//! children of the verified context (the token right after `last`), and
//! `qlogits.row(i)` is the drafter distribution node `i`'s token was drawn
//! from.  The whole tree is verified in ONE target call
//! (`TargetBackend::verify_tree`) which returns a logits row per node plus
//! one for the root context, and `spec::acceptance::accept_tree_*` picks
//! the longest accepted root-to-leaf path losslessly.
//!
//! Chain speculation is the degenerate tree where every level has exactly
//! one child -- `DraftTree::chain` -- so the tree path strictly generalizes
//! the paper's Section 2.1 algorithm.

use anyhow::{anyhow, Result};

use crate::runtime::Tensor;
use crate::spec::sampler;

/// Per-request/per-engine tree-shape knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeConfig {
    /// `branch[d]` = maximum children per node at depth `d`; `branch.len()`
    /// is the tree depth (the analog of gamma for chain drafting).
    pub branch: Vec<usize>,
    /// Hard cap on drafted nodes per iteration (keeps the flattened verify
    /// call bounded).
    pub max_nodes: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        // Fan out near the root where divergence is most likely, stay
        // narrow deeper in -- the Spec-LLaVA shape.
        TreeConfig { branch: vec![2, 2, 1, 1, 1], max_nodes: 16 }
    }
}

impl TreeConfig {
    /// A pure chain of the given depth (tree mode degenerates to the
    /// classic algorithm).
    pub fn chain(depth: usize) -> TreeConfig {
        TreeConfig { branch: vec![1; depth], max_nodes: depth.max(1) }
    }

    /// Shape derived from the manifest's gamma: depth = gamma, fan-out 2 on
    /// the first two levels (where drafter/target divergence concentrates),
    /// narrow below.
    pub fn for_depth(depth: usize) -> TreeConfig {
        let d = depth.max(1);
        let mut branch = vec![1; d];
        branch[0] = 2;
        if d > 1 {
            branch[1] = 2;
        }
        TreeConfig { branch, max_nodes: (3 * d).max(8) }
    }

    pub fn depth(&self) -> usize {
        self.branch.len()
    }
}

/// A drafted token tree in topological (parent-before-child) order.
#[derive(Debug, Clone)]
pub struct DraftTree {
    pub tokens: Vec<i32>,
    /// `None` = child of the verified context (depth 0).
    pub parents: Vec<Option<usize>>,
    pub depths: Vec<usize>,
    /// `[n x V]`: row `i` is the drafter's raw logits at node `i`'s parent
    /// context (the distribution `tokens[i]` was sampled from).
    pub qlogits: Tensor,
}

impl DraftTree {
    pub fn new(
        tokens: Vec<i32>,
        parents: Vec<Option<usize>>,
        depths: Vec<usize>,
        qlogits: Tensor,
    ) -> Result<DraftTree> {
        let n = tokens.len();
        if parents.len() != n || depths.len() != n {
            return Err(anyhow!("tree arrays disagree on node count"));
        }
        if qlogits.dims.len() != 2 || qlogits.dims[0] != n {
            return Err(anyhow!("qlogits must be [{n} x V], got {:?}", qlogits.dims));
        }
        for (i, p) in parents.iter().enumerate() {
            match p {
                None => {
                    if depths[i] != 0 {
                        return Err(anyhow!("root child {i} must have depth 0"));
                    }
                }
                Some(p) => {
                    if *p >= i {
                        return Err(anyhow!("node {i} not in topological order"));
                    }
                    if depths[i] != depths[*p] + 1 {
                        return Err(anyhow!("node {i} depth inconsistent with parent"));
                    }
                }
            }
        }
        Ok(DraftTree { tokens, parents, depths, qlogits })
    }

    /// The degenerate single-path tree (classic chain speculation).
    pub fn chain(tokens: Vec<i32>, qlogits: Tensor) -> DraftTree {
        let n = tokens.len();
        let parents = (0..n).map(|i| i.checked_sub(1)).collect();
        let depths = (0..n).collect();
        DraftTree { tokens, parents, depths, qlogits }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn max_depth(&self) -> usize {
        self.depths.iter().copied().max().map(|d| d + 1).unwrap_or(0)
    }

    /// Children of `parent` (`None` = the root context), in node order.
    /// Trees are small (<= max_nodes), so a linear scan is the right call.
    pub fn children_of(&self, parent: Option<usize>) -> impl Iterator<Item = usize> + '_ {
        self.parents
            .iter()
            .enumerate()
            .filter(move |(_, p)| **p == parent)
            .map(|(i, _)| i)
    }

    /// `Some(tokens root..leaf)` when the tree is a pure chain (node `i`'s
    /// parent is `i-1`); used by backends that can only verify linear
    /// windows.
    pub fn as_chain(&self) -> Option<Vec<i32>> {
        for (i, p) in self.parents.iter().enumerate() {
            if *p != i.checked_sub(1) {
                return None;
            }
        }
        Some(self.tokens.clone())
    }

    /// Number of distinct root-to-leaf paths (branch utilization metrics).
    pub fn leaf_count(&self) -> usize {
        (0..self.len())
            .filter(|&i| self.children_of(Some(i)).next().is_none())
            .count()
    }
}

/// Incremental prefix-tree builder: insert candidate continuation paths
/// (token + the q-logits row it was sampled from per level); shared
/// prefixes are deduplicated, per-level fan-out is budgeted by
/// `TreeConfig::branch` with survivors chosen by drafter confidence
/// (`sampler::top_k_indices` over the candidate tokens' q mass).
pub struct TreeBuilder {
    vocab: usize,
    tokens: Vec<i32>,
    parents: Vec<Option<usize>>,
    depths: Vec<usize>,
    rows: Vec<Vec<f32>>,
}

impl TreeBuilder {
    pub fn new(vocab: usize) -> TreeBuilder {
        TreeBuilder {
            vocab,
            tokens: Vec::new(),
            parents: Vec::new(),
            depths: Vec::new(),
            rows: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    fn find_child(&self, parent: Option<usize>, token: i32) -> Option<usize> {
        (0..self.tokens.len())
            .find(|&i| self.parents[i] == parent && self.tokens[i] == token)
    }

    fn child_count(&self, parent: Option<usize>) -> usize {
        self.parents.iter().filter(|p| **p == parent).count()
    }

    /// Insert one root-to-leaf candidate path.  `path[d]` = (token, q-logits
    /// row) at depth `d`.  Stops at the first level where the config budget
    /// or `max_nodes` is exhausted and the token is not already present.
    pub fn add_path(&mut self, path: &[(i32, Vec<f32>)], cfg: &TreeConfig) {
        let mut cur: Option<usize> = None;
        for (d, (tok, row)) in path.iter().enumerate() {
            if d >= cfg.branch.len() {
                break;
            }
            if let Some(existing) = self.find_child(cur, *tok) {
                cur = Some(existing);
                continue;
            }
            if self.child_count(cur) >= cfg.branch[d] || self.tokens.len() >= cfg.max_nodes {
                break;
            }
            debug_assert_eq!(row.len(), self.vocab);
            self.tokens.push(*tok);
            self.parents.push(cur);
            self.depths.push(d);
            self.rows.push(row.clone());
            cur = Some(self.tokens.len() - 1);
        }
    }

    /// Fan a node out over the `k` most confident tokens of a drafter
    /// distribution (top-k branching).  The first (most confident) inserted
    /// child index is returned so callers can keep extending the mainline.
    ///
    /// GREEDY DRAFTING ONLY: the children are chosen deterministically, so
    /// they are NOT i.i.d. samples from `qrow` and the stochastic
    /// acceptance rule's losslessness proof does not cover them (see the
    /// q-row contract on `accept_tree_stochastic`).  Greedy (T = 0)
    /// acceptance is lossless for any tree, which is where this belongs;
    /// a T > 0 drafter must populate siblings by sampling from its own
    /// distribution instead (or use point-mass rows, as the scripted
    /// backend does).
    pub fn add_topk_children(
        &mut self,
        parent: Option<usize>,
        qrow: &[f32],
        k: usize,
        cfg: &TreeConfig,
    ) -> Option<usize> {
        let depth = parent.map(|p| self.depths[p] + 1).unwrap_or(0);
        if depth >= cfg.branch.len() {
            return None;
        }
        let budget = cfg.branch[depth].min(k);
        let mut idx = Vec::new();
        sampler::top_k_indices(qrow, budget, &mut idx);
        let mut first = None;
        for &t in &idx {
            if self.find_child(parent, t as i32).is_some()
                || self.child_count(parent) >= cfg.branch[depth]
                || self.tokens.len() >= cfg.max_nodes
            {
                continue;
            }
            self.tokens.push(t as i32);
            self.parents.push(parent);
            self.depths.push(depth);
            self.rows.push(qrow.to_vec());
            if first.is_none() {
                first = Some(self.tokens.len() - 1);
            }
        }
        first
    }

    pub fn build(self) -> Result<DraftTree> {
        let n = self.tokens.len();
        let qlogits = Tensor::new(
            self.rows.into_iter().flatten().collect(),
            vec![n, self.vocab],
        )?;
        DraftTree::new(self.tokens, self.parents, self.depths, qlogits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_hot(tok: i32, v: usize) -> Vec<f32> {
        let mut row = vec![0.0; v];
        row[tok as usize] = 50.0;
        row
    }

    #[test]
    fn chain_tree_shape() {
        let q = Tensor::new(vec![0.0; 3 * 4], vec![3, 4]).unwrap();
        let t = DraftTree::chain(vec![1, 2, 3], q);
        assert_eq!(t.parents, vec![None, Some(0), Some(1)]);
        assert_eq!(t.depths, vec![0, 1, 2]);
        assert_eq!(t.as_chain(), Some(vec![1, 2, 3]));
        assert_eq!(t.max_depth(), 3);
        assert_eq!(t.leaf_count(), 1);
    }

    #[test]
    fn builder_dedups_shared_prefixes() {
        let v = 8;
        let cfg = TreeConfig { branch: vec![2, 2, 2], max_nodes: 16 };
        let mut b = TreeBuilder::new(v);
        let path = |toks: &[i32]| -> Vec<(i32, Vec<f32>)> {
            toks.iter().map(|&t| (t, one_hot(t, v))).collect()
        };
        b.add_path(&path(&[1, 2, 3]), &cfg);
        b.add_path(&path(&[1, 2, 4]), &cfg); // shares [1, 2]
        b.add_path(&path(&[5, 6, 7]), &cfg);
        let t = b.build().unwrap();
        // nodes: 1,2,3 then 4 (child of 2), then 5,6,7
        assert_eq!(t.len(), 7);
        assert_eq!(t.as_chain(), None);
        assert_eq!(t.children_of(None).count(), 2); // 1 and 5
        let node2 = t.tokens.iter().position(|&x| x == 2).unwrap();
        assert_eq!(t.children_of(Some(node2)).count(), 2); // 3 and 4
        assert_eq!(t.leaf_count(), 3);
    }

    #[test]
    fn builder_respects_budgets() {
        let v = 8;
        let cfg = TreeConfig { branch: vec![1, 1], max_nodes: 16 };
        let mut b = TreeBuilder::new(v);
        let path = |toks: &[i32]| -> Vec<(i32, Vec<f32>)> {
            toks.iter().map(|&t| (t, one_hot(t, v))).collect()
        };
        b.add_path(&path(&[1, 2, 3]), &cfg); // depth capped at 2
        b.add_path(&path(&[4, 5]), &cfg); // root budget exhausted
        let t = b.build().unwrap();
        assert_eq!(t.tokens, vec![1, 2]);

        let cfg = TreeConfig { branch: vec![4, 4], max_nodes: 3 };
        let mut b = TreeBuilder::new(v);
        b.add_path(&path(&[1, 2]), &cfg);
        b.add_path(&path(&[3, 4]), &cfg); // node 4 exceeds max_nodes
        let t = b.build().unwrap();
        assert_eq!(t.tokens, vec![1, 2, 3]);
    }

    #[test]
    fn topk_fanout_orders_by_confidence() {
        let v = 6;
        let cfg = TreeConfig { branch: vec![2], max_nodes: 8 };
        let mut b = TreeBuilder::new(v);
        let qrow = vec![0.1, 5.0, 0.2, 3.0, 0.0, 0.0];
        let first = b.add_topk_children(None, &qrow, 3, &cfg);
        let t = b.build().unwrap();
        assert_eq!(t.tokens, vec![1, 3]); // top-2 by logit, budget 2
        assert_eq!(first, Some(0));
    }

    #[test]
    fn invalid_trees_rejected() {
        let q = Tensor::new(vec![0.0; 2 * 4], vec![2, 4]).unwrap();
        // non-topological parent
        assert!(DraftTree::new(vec![1, 2], vec![Some(1), None], vec![1, 0], q.clone()).is_err());
        // depth inconsistent
        assert!(DraftTree::new(vec![1, 2], vec![None, Some(0)], vec![0, 2], q).is_err());
    }
}
