//! Word-level tokenizer shared with the Python authoring side.
//!
//! The vocabulary is authored once in `python/compile/shapeworld.py` and
//! exported to `artifacts/vocab.json`; this module loads the same tables so
//! the serving path never imports Python (the three-layer contract).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::parse;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    tokens: Vec<String>,
    ids: HashMap<String, u32>,
    pub pad_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub sep_id: u32,
}

impl Tokenizer {
    pub fn from_json(text: &str) -> Result<Tokenizer> {
        let v = parse(text)?;
        let tokens: Vec<String> = v
            .req("tokens")?
            .as_arr()?
            .iter()
            .map(|t| Ok(t.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let ids = tokens
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Ok(Tokenizer {
            pad_id: v.req("pad_id")?.as_usize()? as u32,
            bos_id: v.req("bos_id")?.as_usize()? as u32,
            eos_id: v.req("eos_id")?.as_usize()? as u32,
            sep_id: v.req("sep_id")?.as_usize()? as u32,
            tokens,
            ids,
        })
    }

    pub fn load(artifacts_dir: &str) -> Result<Tokenizer> {
        Tokenizer::from_json(&crate::util::read_file(&format!(
            "{artifacts_dir}/vocab.json"
        ))?)
    }

    pub fn vocab_size(&self) -> usize {
        self.tokens.len()
    }

    /// Encode a whitespace-separated word sequence.  Errors on OOV -- the
    /// grammar is closed, so OOV at serving time is a caller bug.
    pub fn encode(&self, text: &str) -> Result<Vec<u32>> {
        text.split_whitespace()
            .map(|w| {
                self.ids
                    .get(w)
                    .copied()
                    .ok_or_else(|| anyhow!("OOV word {w:?}"))
            })
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.tokens.get(i as usize).map(|s| s.as_str()).unwrap_or("<?>"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn token(&self, id: u32) -> Option<&str> {
        self.tokens.get(id as usize).map(|s| s.as_str())
    }

    /// `[<bos>] words [<sep>]` padded to `p_max`; returns (ids, len).
    /// This is the canonical prompt framing used at training time
    /// (python/compile/train.py::assemble_sequence) -- they must agree.
    pub fn encode_prompt(&self, text: &str, p_max: usize) -> Result<(Vec<i32>, usize)> {
        let body = self.encode(text)?;
        let len = body.len() + 2;
        if len > p_max {
            return Err(anyhow!("prompt too long: {len} > {p_max}"));
        }
        let mut out = vec![self.pad_id as i32; p_max];
        out[0] = self.bos_id as i32;
        for (i, id) in body.iter().enumerate() {
            out[1 + i] = *id as i32;
        }
        out[1 + body.len()] = self.sep_id as i32;
        Ok((out, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        Tokenizer::from_json(
            r#"{"tokens":["<pad>","<bos>","<eos>","<sep>","<img>","the","red","circle","."],
                "pad_id":0,"bos_id":1,"eos_id":2,"sep_id":3,"img_id":4}"#,
        )
        .unwrap()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = toy();
        let ids = t.encode("the red circle .").unwrap();
        assert_eq!(ids, vec![5, 6, 7, 8]);
        assert_eq!(t.decode(&ids), "the red circle .");
    }

    #[test]
    fn oov_is_error() {
        assert!(toy().encode("the blue circle").is_err());
    }

    #[test]
    fn prompt_framing() {
        let t = toy();
        let (ids, len) = t.encode_prompt("the red circle", 8).unwrap();
        assert_eq!(len, 5);
        assert_eq!(ids, vec![1, 5, 6, 7, 3, 0, 0, 0]);
    }

    #[test]
    fn prompt_too_long() {
        let t = toy();
        assert!(t.encode_prompt("the red circle .", 4).is_err());
    }

    #[test]
    fn special_ids() {
        let t = toy();
        assert_eq!(t.pad_id, 0);
        assert_eq!(t.eos_id, 2);
        assert_eq!(t.token(7), Some("circle"));
        assert_eq!(t.vocab_size(), 9);
    }
}
