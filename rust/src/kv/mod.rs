//! Paged KV block pool with copy-on-write sharing.
//!
//! Replaces whole-sequence KV deep copies with block-granular structural
//! sharing (the vLLM paged-attention memory model, here over host-side
//! `xla::Literal`s): a `KvPool` owns fixed-size blocks of 32-bit words,
//! sequences hold block *tables* (`PagedKv`), and a fork is a refcount
//! bump per block instead of a full KV clone.  The first divergent write
//! to a shared block copies just that block (CoW); unchanged prefix
//! blocks stay shared for the life of both sequences -- which is exactly
//! the prefix-cache and tree-branch fork pattern (MASSV doubles every
//! sequence's KV footprint with its drafter, so sharing has to be
//! structural, not copy-based).
//!
//! Bit-exactness: block content is the literal's words verbatim (`f32`
//! stored via `to_bits`), so materialize -> mutate -> write -> materialize
//! round-trips are bit-identical and the decode path cannot observe
//! whether paging is on.  That is the headline invariant the PR 4
//! batched-vs-sequential oracle enforces end-to-end.
//!
//! Pressure: allocation never fails (over-commit); `over_budget()`
//! reports when resident bytes exceed the configured budget and the
//! engine responds by *preempting* -- swapping out the lowest-priority
//! backlogged session's blocks (`PagedKv::swap_out`, a compacted host
//! copy) instead of rejecting at admission.  Swap-in re-pages the copy;
//! the round-trip is bit-exact, so a preempted request resumes with
//! identical output (see `docs/paged_kv.md`).
//!
//! `KvBacking` is the `SeqState.kv` slot: `Owned` (the pre-paging deep
//! literal, still the default for pool-less callers) or `Paged`.  Both
//! expose the same materialize/replace surface, so the model layer is
//! agnostic.

use std::fmt;
use std::sync::{Arc, Mutex};

use crate::metrics::Metrics;

/// Default block size in 32-bit words (4 KiB per block).
pub const DEFAULT_BLOCK_WORDS: usize = 1024;

#[derive(Debug, Clone)]
pub struct KvPoolConfig {
    /// Words per block.  Smaller blocks share more aggressively across
    /// divergent forks; larger blocks cut table overhead.
    pub block_words: usize,
    /// Resident-byte budget the engine's preemption policy enforces
    /// (allocation itself never fails -- see `KvPool::over_budget`).
    pub budget_bytes: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig { block_words: DEFAULT_BLOCK_WORDS, budget_bytes: 64 << 20 }
    }
}

// ------------------------------------------------------- literal <-> words

#[derive(Debug, Clone, PartialEq)]
enum Dtype {
    F32,
    I32,
    U32,
}

/// Structure of a flattened literal, kept alongside the block table so the
/// words can be re-materialized into an identical `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
enum LitShape {
    Array { dtype: Dtype, dims: Vec<i64>, len: usize },
    Tuple(Vec<LitShape>),
}

/// Append the literal's elements to `words` as raw 32-bit patterns
/// (`f32::to_bits`: the round-trip is bit-exact, NaNs and -0.0 included)
/// and return the shape descriptor that re-materializes them.
fn flatten(lit: &xla::Literal, words: &mut Vec<u32>) -> LitShape {
    match lit {
        xla::Literal::Array { data, dims } => {
            let (dtype, len) = match data {
                xla::LiteralData::F32(v) => {
                    words.extend(v.iter().map(|x| x.to_bits()));
                    (Dtype::F32, v.len())
                }
                xla::LiteralData::I32(v) => {
                    words.extend(v.iter().map(|x| *x as u32));
                    (Dtype::I32, v.len())
                }
                xla::LiteralData::U32(v) => {
                    words.extend_from_slice(v);
                    (Dtype::U32, v.len())
                }
            };
            LitShape::Array { dtype, dims: dims.clone(), len }
        }
        xla::Literal::Tuple(parts) => {
            LitShape::Tuple(parts.iter().map(|p| flatten(p, words)).collect())
        }
    }
}

fn unflatten(shape: &LitShape, words: &[u32], cursor: &mut usize) -> xla::Literal {
    match shape {
        LitShape::Array { dtype, dims, len } => {
            let slice = &words[*cursor..*cursor + *len];
            *cursor += *len;
            let data = match dtype {
                Dtype::F32 => {
                    xla::LiteralData::F32(slice.iter().map(|w| f32::from_bits(*w)).collect())
                }
                Dtype::I32 => xla::LiteralData::I32(slice.iter().map(|w| *w as i32).collect()),
                Dtype::U32 => xla::LiteralData::U32(slice.to_vec()),
            };
            xla::Literal::Array { data, dims: dims.clone() }
        }
        LitShape::Tuple(shapes) => {
            xla::Literal::Tuple(shapes.iter().map(|s| unflatten(s, words, cursor)).collect())
        }
    }
}

// ------------------------------------------------------------------- pool

/// A pool slot.  `Free` slots are recycled through the free list; `Used`
/// slots carry their word payload (the last block of a sequence may be
/// partial) and a refcount shared by every table pointing at them.
enum BlockSlot {
    Free,
    Used { data: Vec<u32>, refs: u32 },
}

struct PoolInner {
    blocks: Vec<BlockSlot>,
    free: Vec<u32>,
    used_blocks: usize,
    used_words: usize,
}

impl PoolInner {
    fn alloc(&mut self, chunk: &[u32]) -> u32 {
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.blocks.push(BlockSlot::Free);
                (self.blocks.len() - 1) as u32
            }
        };
        self.used_blocks += 1;
        self.used_words += chunk.len();
        self.blocks[id as usize] = BlockSlot::Used { data: chunk.to_vec(), refs: 1 };
        id
    }

    fn incref(&mut self, id: u32) {
        match &mut self.blocks[id as usize] {
            BlockSlot::Used { refs, .. } => *refs += 1,
            // a live table can only reference Used slots: Free here means a
            // refcounting bug, never a recoverable condition
            BlockSlot::Free => unreachable!("kv pool: incref on a free block"),
        }
    }

    fn decref(&mut self, id: u32) {
        let freed = match &mut self.blocks[id as usize] {
            BlockSlot::Used { refs, data } => {
                *refs -= 1;
                if *refs == 0 {
                    Some(data.len())
                } else {
                    None
                }
            }
            BlockSlot::Free => unreachable!("kv pool: decref on a free block"),
        };
        if let Some(words) = freed {
            self.used_blocks -= 1;
            self.used_words -= words;
            self.blocks[id as usize] = BlockSlot::Free;
            self.free.push(id);
        }
    }

    fn refs(&self, id: u32) -> u32 {
        match &self.blocks[id as usize] {
            BlockSlot::Used { refs, .. } => *refs,
            BlockSlot::Free => unreachable!("kv pool: refs of a free block"),
        }
    }

    fn read(&self, id: u32) -> &[u32] {
        match &self.blocks[id as usize] {
            BlockSlot::Used { data, .. } => data,
            BlockSlot::Free => unreachable!("kv pool: read of a free block"),
        }
    }

    /// Overwrite an exclusively-held block's payload (caller checked
    /// `refs == 1`; shared blocks must go through CoW instead).
    fn write_block(&mut self, id: u32, chunk: &[u32]) {
        let old = match &mut self.blocks[id as usize] {
            BlockSlot::Used { data, .. } => {
                let old = data.len();
                data.clear();
                data.extend_from_slice(chunk);
                old
            }
            BlockSlot::Free => unreachable!("kv pool: write to a free block"),
        };
        self.used_words += chunk.len();
        self.used_words -= old;
    }
}

/// The shared block pool.  One per engine; every `PagedKv` holds an `Arc`
/// back to it, so drop order never dangles a table.
pub struct KvPool {
    cfg: KvPoolConfig,
    inner: Mutex<PoolInner>,
    metrics: Option<Arc<Metrics>>,
}

impl KvPool {
    pub fn new(cfg: KvPoolConfig) -> Arc<KvPool> {
        KvPool::with_metrics(cfg, None)
    }

    pub fn with_metrics(mut cfg: KvPoolConfig, metrics: Option<Arc<Metrics>>) -> Arc<KvPool> {
        cfg.block_words = cfg.block_words.max(1);
        Arc::new(KvPool {
            cfg,
            inner: Mutex::new(PoolInner {
                blocks: Vec::new(),
                free: Vec::new(),
                used_blocks: 0,
                used_words: 0,
            }),
            metrics,
        })
    }

    pub fn block_words(&self) -> usize {
        self.cfg.block_words
    }

    pub fn budget_bytes(&self) -> usize {
        self.cfg.budget_bytes
    }

    /// Resident (pooled) bytes across all live blocks.
    pub fn bytes_used(&self) -> usize {
        self.inner.lock().unwrap().used_words * 4
    }

    pub fn blocks_used(&self) -> usize {
        self.inner.lock().unwrap().used_blocks
    }

    /// Pressure signal for the engine's preemption policy: allocation
    /// itself never fails (over-commit), preemption brings it back down.
    pub fn over_budget(&self) -> bool {
        self.bytes_used() > self.cfg.budget_bytes
    }

    /// Page a literal into the pool, returning the owning table handle.
    pub fn store(self: &Arc<Self>, lit: &xla::Literal) -> PagedKv {
        let mut words = Vec::new();
        let shape = flatten(lit, &mut words);
        let mut inner = self.inner.lock().unwrap();
        let table: Vec<u32> =
            words.chunks(self.cfg.block_words).map(|c| inner.alloc(c)).collect();
        self.sync_gauges(&inner);
        drop(inner);
        PagedKv { pool: self.clone(), shape, len_words: words.len(), table, swapped: None }
    }

    fn sync_gauges(&self, inner: &PoolInner) {
        if let Some(m) = &self.metrics {
            m.kv_pool_bytes.set((inner.used_words * 4) as i64);
            m.kv_pool_blocks.set(inner.used_blocks as i64);
        }
    }

    fn count(&self, f: impl FnOnce(&Metrics)) {
        if let Some(m) = &self.metrics {
            f(m);
        }
    }
}

// ------------------------------------------------------------ block tables

/// One sequence's view of its KV: a table of pool block ids (resident) or
/// a compacted host copy (swapped out under preemption).  `Clone` is the
/// O(table) fork -- a refcount bump per block, no payload copy -- and
/// `write` is chunk-wise copy-on-write, so forked sequences share every
/// block they have not diverged on.
pub struct PagedKv {
    pool: Arc<KvPool>,
    shape: LitShape,
    len_words: usize,
    table: Vec<u32>,
    swapped: Option<Vec<u32>>,
}

impl PagedKv {
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    pub fn is_swapped(&self) -> bool {
        self.swapped.is_some()
    }

    /// Blocks currently resident in the pool (0 while swapped out).
    pub fn blocks(&self) -> usize {
        self.table.len()
    }

    fn gather(&self) -> Vec<u32> {
        if let Some(w) = &self.swapped {
            return w.clone();
        }
        let inner = self.pool.inner.lock().unwrap();
        let mut words = Vec::with_capacity(self.len_words);
        for &id in &self.table {
            words.extend_from_slice(inner.read(id));
        }
        words
    }

    /// Materialize the full literal (works resident or swapped).
    pub fn to_literal(&self) -> xla::Literal {
        let words = self.gather();
        let mut cursor = 0;
        let lit = unflatten(&self.shape, &words, &mut cursor);
        debug_assert_eq!(cursor, words.len(), "kv shape/word-count mismatch");
        lit
    }

    /// Replace the content with `lit`, chunk-wise: unchanged blocks are
    /// kept (shared blocks *stay* shared), exclusively-held blocks are
    /// overwritten in place, and a changed shared block is copied first --
    /// the copy-on-write that makes forks safe.  Handles growth and
    /// shrink (the PJRT executables return whole replacement KVs).
    pub fn write(&mut self, lit: &xla::Literal) {
        let mut words = Vec::new();
        self.shape = flatten(lit, &mut words);
        self.len_words = words.len();
        if self.swapped.is_some() {
            self.swapped = Some(words);
            return;
        }
        let bw = self.pool.cfg.block_words;
        let nblocks = words.len().div_ceil(bw);
        let mut cow = 0u64;
        let mut inner = self.pool.inner.lock().unwrap();
        while self.table.len() > nblocks {
            let id = self.table.pop().unwrap();
            inner.decref(id);
        }
        for i in 0..nblocks {
            let chunk = &words[i * bw..((i + 1) * bw).min(words.len())];
            if i >= self.table.len() {
                let id = inner.alloc(chunk);
                self.table.push(id);
                continue;
            }
            let id = self.table[i];
            let (same, shared) = (inner.read(id) == chunk, inner.refs(id) > 1);
            if same {
                continue;
            }
            if shared {
                inner.decref(id);
                self.table[i] = inner.alloc(chunk);
                cow += 1;
            } else {
                inner.write_block(id, chunk);
            }
        }
        self.pool.sync_gauges(&inner);
        drop(inner);
        if cow > 0 {
            self.pool.count(|m| m.kv_cow_copies.add(cow));
        }
    }

    /// Preemption: compact the words to a host copy and release every
    /// pool block (shared blocks just drop one reference -- the other
    /// holders keep them resident).  Idempotent.
    pub fn swap_out(&mut self) {
        if self.swapped.is_some() {
            return;
        }
        let words = self.gather();
        {
            let mut inner = self.pool.inner.lock().unwrap();
            for &id in &self.table {
                inner.decref(id);
            }
            self.pool.sync_gauges(&inner);
        }
        self.table.clear();
        self.swapped = Some(words);
        self.pool.count(|m| m.kv_swap_outs.inc());
    }

    /// Resume: re-page the swapped copy into fresh blocks.  The word
    /// round-trip is verbatim, so the materialized literal is
    /// bit-identical to the pre-swap state.  Idempotent.
    pub fn swap_in(&mut self) {
        let Some(words) = self.swapped.take() else { return };
        let bw = self.pool.cfg.block_words;
        let mut inner = self.pool.inner.lock().unwrap();
        self.table = words.chunks(bw).map(|c| inner.alloc(c)).collect();
        self.pool.sync_gauges(&inner);
        drop(inner);
        self.pool.count(|m| m.kv_swap_ins.inc());
    }

    /// Host bytes attributable to this handle alone: the block table plus
    /// any swapped-out copy.  Resident block *content* is charged to the
    /// pool gauge (`kv_pool_bytes`) once, shared across all forks -- the
    /// block-based byte charging the cache budget sees.
    pub fn bytes(&self) -> usize {
        self.table.len() * 4 + self.swapped.as_ref().map_or(0, |w| w.len() * 4)
    }
}

impl Clone for PagedKv {
    fn clone(&self) -> PagedKv {
        if !self.table.is_empty() {
            let mut inner = self.pool.inner.lock().unwrap();
            for &id in &self.table {
                inner.incref(id);
            }
        }
        self.pool.count(|m| m.kv_forks.inc());
        PagedKv {
            pool: self.pool.clone(),
            shape: self.shape.clone(),
            len_words: self.len_words,
            table: self.table.clone(),
            swapped: self.swapped.clone(),
        }
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        if self.table.is_empty() {
            return;
        }
        let mut inner = self.pool.inner.lock().unwrap();
        for &id in &self.table {
            inner.decref(id);
        }
        self.pool.sync_gauges(&inner);
    }
}

impl fmt::Debug for PagedKv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PagedKv")
            .field("blocks", &self.table.len())
            .field("len_words", &self.len_words)
            .field("swapped", &self.swapped.is_some())
            .finish()
    }
}

// -------------------------------------------------------------- kv backing

/// The `SeqState.kv` slot: an owned deep literal (pool-less callers, the
/// pre-paging behavior) or a paged block table.  Both forms expose the
/// same materialize/replace surface, so the model layer never branches on
/// which one it holds.
#[derive(Debug, Clone)]
pub enum KvBacking {
    Owned(xla::Literal),
    Paged(PagedKv),
}

impl KvBacking {
    /// Materialize the full literal (what the executable call consumes).
    pub fn literal(&self) -> xla::Literal {
        match self {
            KvBacking::Owned(l) => l.clone(),
            KvBacking::Paged(p) => p.to_literal(),
        }
    }

    /// Replace the content (what the executable call returned).  Paged
    /// backings write chunk-wise with CoW; owned backings just swap the
    /// value.
    pub fn set(&mut self, lit: xla::Literal) {
        match self {
            KvBacking::Owned(slot) => *slot = lit,
            KvBacking::Paged(p) => p.write(&lit),
        }
    }

    /// Size accounting for the cache byte budget.  Owned literals are
    /// charged in full; paged tables charge only their handle (block
    /// content lives on the pool gauge).
    pub fn bytes(&self) -> usize {
        match self {
            KvBacking::Owned(l) => crate::models::literal_bytes(l),
            KvBacking::Paged(p) => p.bytes(),
        }
    }

    /// Move an owned literal into the pool (no-op if already paged).
    pub fn paginate(&mut self, pool: &Arc<KvPool>) {
        if let KvBacking::Owned(l) = self {
            *self = KvBacking::Paged(pool.store(l));
        }
    }

    pub fn is_paged(&self) -> bool {
        matches!(self, KvBacking::Paged(_))
    }

    pub fn swap_out(&mut self) {
        if let KvBacking::Paged(p) = self {
            p.swap_out();
        }
    }

    pub fn swap_in(&mut self) {
        if let KvBacking::Paged(p) = self {
            p.swap_in();
        }
    }

    pub fn is_swapped(&self) -> bool {
        matches!(self, KvBacking::Paged(p) if p.is_swapped())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool_with(block_words: usize, budget: usize) -> (Arc<KvPool>, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        let p = KvPool::with_metrics(
            KvPoolConfig { block_words, budget_bytes: budget },
            Some(m.clone()),
        );
        (p, m)
    }

    /// A nested literal covering every dtype plus awkward f32 bit patterns
    /// (NaN, -0.0, subnormal): the round-trip must be *bit* exact.
    fn gnarly_literal(n: usize) -> xla::Literal {
        let f: Vec<f32> = (0..n)
            .map(|i| match i % 5 {
                0 => f32::NAN,
                1 => -0.0,
                2 => f32::MIN_POSITIVE / 2.0, // subnormal
                3 => -(i as f32) * 0.37,
                _ => (i as f32).sqrt(),
            })
            .collect();
        let i: Vec<i32> = (0..n).map(|x| -(x as i32) * 3).collect();
        let u: Vec<u32> = (0..n).map(|x| (x as u32).wrapping_mul(0x9e3779b9)).collect();
        xla::Literal::Tuple(vec![
            xla::Literal::vec1(&f),
            xla::Literal::Tuple(vec![xla::Literal::vec1(&i), xla::Literal::vec1(&u)]),
            xla::Literal::scalar(7.25f32),
        ])
    }

    fn bits_of(l: &xla::Literal) -> Vec<u32> {
        let mut w = Vec::new();
        flatten(l, &mut w);
        w
    }

    #[test]
    fn store_roundtrips_bit_exact() {
        let (pool, _) = pool_with(8, 1 << 20);
        let lit = gnarly_literal(100);
        let paged = pool.store(&lit);
        assert_eq!(bits_of(&paged.to_literal()), bits_of(&lit));
        // shape survives too (dims, tuple nesting)
        assert_eq!(paged.to_literal().element_count(), lit.element_count());
    }

    #[test]
    fn fork_is_refcount_only_and_cow_isolates() {
        let (pool, m) = pool_with(16, 1 << 20);
        let base = xla::Literal::vec1(&(0..64).map(|i| i as f32).collect::<Vec<_>>());
        let mut a = pool.store(&base);
        let before = pool.bytes_used();
        assert_eq!(before, 64 * 4);
        let b = a.clone();
        assert_eq!(pool.bytes_used(), before, "fork must not copy payload");
        assert_eq!(m.kv_forks.get(), 1);

        // diverge one word in block 2 of the original
        let mut v: Vec<f32> = (0..64).map(|i| i as f32).collect();
        v[35] = 999.0;
        a.write(&xla::Literal::vec1(&v));
        // exactly one block (16 words) was copied
        assert_eq!(pool.bytes_used(), before + 16 * 4);
        assert_eq!(m.kv_cow_copies.get(), 1);
        // the fork still sees the pre-divergence content, bit-exact
        assert_eq!(bits_of(&b.to_literal()), bits_of(&base));
        assert_eq!(a.to_literal().to_vec::<f32>().unwrap()[35], 999.0);
    }

    #[test]
    fn unshared_write_is_in_place() {
        let (pool, m) = pool_with(16, 1 << 20);
        let mut a = pool.store(&xla::Literal::vec1(&vec![1.0f32; 64]));
        let before = pool.bytes_used();
        a.write(&xla::Literal::vec1(&vec![2.0f32; 64]));
        assert_eq!(pool.bytes_used(), before, "exclusive blocks are overwritten in place");
        assert_eq!(m.kv_cow_copies.get(), 0);
        assert_eq!(a.to_literal().to_vec::<f32>().unwrap(), vec![2.0f32; 64]);
    }

    #[test]
    fn growth_and_shrink_keep_accounting_exact() {
        let (pool, _) = pool_with(16, 1 << 20);
        let mut a = pool.store(&xla::Literal::vec1(&vec![1.0f32; 24]));
        assert_eq!(pool.bytes_used(), 24 * 4);
        assert_eq!(a.blocks(), 2); // 16 + 8 (partial tail)
        a.write(&xla::Literal::vec1(&vec![1.0f32; 50]));
        assert_eq!(pool.bytes_used(), 50 * 4);
        assert_eq!(a.blocks(), 4);
        a.write(&xla::Literal::vec1(&vec![1.0f32; 10]));
        assert_eq!(pool.bytes_used(), 10 * 4);
        assert_eq!(a.blocks(), 1);
        assert_eq!(a.to_literal().to_vec::<f32>().unwrap(), vec![1.0f32; 10]);
    }

    #[test]
    fn drop_releases_blocks_and_free_list_recycles() {
        let (pool, _) = pool_with(8, 1 << 20);
        let a = pool.store(&gnarly_literal(40));
        let b = a.clone();
        let blocks = pool.blocks_used();
        assert!(blocks > 0);
        drop(a);
        assert_eq!(pool.blocks_used(), blocks, "shared blocks survive one holder");
        drop(b);
        assert_eq!(pool.blocks_used(), 0);
        assert_eq!(pool.bytes_used(), 0);
        // a fresh store reuses recycled slots rather than growing the arena
        let c = pool.store(&gnarly_literal(40));
        assert_eq!(pool.blocks_used(), blocks);
        drop(c);
    }

    #[test]
    fn swap_roundtrip_is_bit_exact_and_releases_residency() {
        let (pool, m) = pool_with(8, 1 << 20);
        let lit = gnarly_literal(60);
        let mut a = pool.store(&lit);
        let shared = a.clone(); // shared blocks must survive a's swap-out
        let resident = pool.bytes_used();
        a.swap_out();
        a.swap_out(); // idempotent
        assert!(a.is_swapped());
        assert_eq!(a.blocks(), 0);
        assert_eq!(
            pool.bytes_used(),
            resident,
            "shared blocks keep their other holder resident"
        );
        drop(shared);
        assert_eq!(pool.bytes_used(), 0, "swap-out releases all residency");
        // materializes identically while swapped...
        assert_eq!(bits_of(&a.to_literal()), bits_of(&lit));
        a.swap_in();
        a.swap_in(); // idempotent
        assert!(!a.is_swapped());
        // ...and after resuming
        assert_eq!(bits_of(&a.to_literal()), bits_of(&lit));
        assert_eq!(m.kv_swap_outs.get(), 1);
        assert_eq!(m.kv_swap_ins.get(), 1);
    }

    #[test]
    fn over_budget_signals_pressure() {
        let (pool, _) = pool_with(8, 100);
        assert!(!pool.over_budget());
        let a = pool.store(&xla::Literal::vec1(&vec![0.0f32; 64]));
        assert!(pool.over_budget(), "256 bytes resident > 100 byte budget");
        drop(a);
        assert!(!pool.over_budget());
    }

    #[test]
    fn gauges_mirror_pool_state() {
        let (pool, m) = pool_with(8, 1 << 20);
        let a = pool.store(&xla::Literal::vec1(&vec![0.0f32; 20]));
        assert_eq!(m.kv_pool_bytes.get(), pool.bytes_used() as i64);
        assert_eq!(m.kv_pool_blocks.get(), pool.blocks_used() as i64);
        drop(a);
        assert_eq!(m.kv_pool_bytes.get(), 0);
        assert_eq!(m.kv_pool_blocks.get(), 0);
    }

    #[test]
    fn backing_paginate_and_set_match_owned_semantics() {
        let (pool, _) = pool_with(8, 1 << 20);
        let lit = gnarly_literal(30);
        let mut owned = KvBacking::Owned(lit.clone());
        let mut paged = KvBacking::Owned(lit.clone());
        paged.paginate(&pool);
        paged.paginate(&pool); // idempotent
        assert!(paged.is_paged() && !owned.is_paged());
        assert_eq!(bits_of(&owned.literal()), bits_of(&paged.literal()));
        let next = gnarly_literal(33);
        owned.set(next.clone());
        paged.set(next.clone());
        assert_eq!(bits_of(&owned.literal()), bits_of(&paged.literal()));
        assert_eq!(bits_of(&paged.literal()), bits_of(&next));
        // paged handle charges only its table; the content sits on the pool
        assert!(paged.bytes() < owned.bytes());
        // owned backings ignore swap requests (nothing to page out)
        owned.swap_out();
        assert!(!owned.is_swapped());
        paged.swap_out();
        assert!(paged.is_swapped());
        paged.swap_in();
        assert_eq!(bits_of(&paged.literal()), bits_of(&next));
    }

    #[test]
    fn empty_literal_pages_cleanly() {
        let (pool, _) = pool_with(8, 1 << 20);
        let lit = xla::Literal::vec1(&[] as &[f32]);
        let mut a = pool.store(&lit);
        assert_eq!(a.blocks(), 0);
        assert_eq!(bits_of(&a.to_literal()), bits_of(&lit));
        a.swap_out();
        a.swap_in();
        assert_eq!(a.to_literal().to_vec::<f32>().unwrap(), Vec::<f32>::new());
    }
}
