//! Model routing: resolve a request to a (target, drafter) pair.
//!
//! Policy (vLLM-router-style, adapted to the MASSV deployment shape):
//!   * the request may pin a target; otherwise the engine default is used
//!   * speculative requests pick the drafter aligned with the target's
//!     *family* (the paper's generalization result: one drafter serves all
//!     same-family targets, including larger ones it was never tuned on)
//!   * unknown variants or missing drafters fall back to TargetOnly rather
//!     than failing the request (availability over speculation).

use crate::coordinator::request::{DecodeMode, Request};
use crate::manifest::Manifest;

#[derive(Debug, Clone, PartialEq)]
pub struct Route {
    pub target: String,
    /// None -> plain target decoding
    pub drafter: Option<(String, String)>, // (name, variant)
    pub text_only_draft: bool,
}

pub struct Router {
    pub default_target: String,
}

impl Router {
    pub fn new(default_target: impl Into<String>) -> Router {
        Router { default_target: default_target.into() }
    }

    pub fn route(&self, req: &Request, manifest: &Manifest) -> Result<Route, String> {
        let target = if req.target.is_empty() {
            self.default_target.clone()
        } else {
            req.target.clone()
        };
        if manifest.target(&target).is_err() {
            return Err(format!("unknown target model {target:?}"));
        }
        match req.mode.drafting() {
            None => Ok(Route { target, drafter: None, text_only_draft: false }),
            Some((variant, text_only_draft)) => {
                match manifest.drafter_for_target(&target, variant) {
                    Ok(d) => Ok(Route {
                        target,
                        drafter: Some((d.name.clone(), variant.to_string())),
                        text_only_draft,
                    }),
                    Err(_) => {
                        log::warn!(
                            "no {variant:?} drafter for target {target:?}; \
                             falling back to target-only decoding"
                        );
                        Ok(Route { target, drafter: None, text_only_draft: false })
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::manifest::Manifest;

    const TOY: &str = r#"{
      "schema": 1, "gamma": 5, "t_max": 128, "p_max": 32, "n_visual": 16,
      "gen_max": 48, "vocab_size": 120, "pad_id": 0, "bos_id": 1,
      "eos_id": 2, "sep_id": 3, "use_kernel": true,
      "targets": [
        {"name": "qwensim-L", "kind": "target", "family": "qwensim",
         "paper_analog": "x", "d_model": 96, "n_layers": 3, "n_heads": 4,
         "d_head": 24, "vocab": 120, "window": null,
         "kv_shape": [3,2,4,128,24], "entries": {}},
        {"name": "qwensim-XL", "kind": "target", "family": "qwensim",
         "paper_analog": "x", "d_model": 128, "n_layers": 4, "n_heads": 4,
         "d_head": 32, "vocab": 120, "window": null,
         "kv_shape": [4,2,4,128,32], "entries": {}}
      ],
      "drafters": [
        {"name": "qwensim-S", "kind": "draft", "family": "qwensim",
         "paper_analog": "x", "d_model": 48, "n_layers": 2, "n_heads": 4,
         "d_head": 12, "vocab": 120, "window": null,
         "kv_shape": [2,2,4,128,12], "entries": {},
         "variant": "massv", "aligned_target": "qwensim-L", "multimodal": true}
      ]
    }"#;

    fn req(mode: DecodeMode, target: &str) -> Request {
        let mut r = Request::simple(1, "hi", vec![0.0; 768]);
        r.mode = mode;
        r.target = target.to_string();
        r
    }

    #[test]
    fn routes_to_default_target() {
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        let r = router
            .route(
                &req(
                    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive: false },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(r.target, "qwensim-L");
        assert_eq!(r.drafter, Some(("qwensim-S".into(), "massv".into())));
    }

    #[test]
    fn family_generalization_xl_uses_same_drafter() {
        // the paper's section 4.2 experiment: the drafter aligned to the L
        // target serves the XL target of the same family
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        let r = router
            .route(
                &req(
                    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive: false },
                    "qwensim-XL",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(r.target, "qwensim-XL");
        assert_eq!(r.drafter, Some(("qwensim-S".into(), "massv".into())));
    }

    #[test]
    fn tree_mode_routes_like_speculative() {
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        let r = router
            .route(
                &req(
                    DecodeMode::Tree {
                        variant: "massv".into(),
                        text_only_draft: false,
                        adaptive: false,
                    },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(r.target, "qwensim-L");
        assert_eq!(r.drafter, Some(("qwensim-S".into(), "massv".into())));
    }

    #[test]
    fn missing_variant_falls_back_to_target_only() {
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        let r = router
            .route(
                &req(
                    DecodeMode::Speculative { variant: "baseline".into(), text_only_draft: false, adaptive: false },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(r.drafter, None);
    }

    #[test]
    fn unknown_target_is_an_error() {
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        assert!(router.route(&req(DecodeMode::TargetOnly, "nope"), &m).is_err());
    }

    /// TOY with the drafter list emptied: every drafting mode must fall
    /// back to target-only (availability over speculation), never error.
    fn no_drafters_manifest() -> Manifest {
        let stripped = TOY.replace(
            r#""drafters": [
        {"name": "qwensim-S", "kind": "draft", "family": "qwensim",
         "paper_analog": "x", "d_model": 48, "n_layers": 2, "n_heads": 4,
         "d_head": 12, "vocab": 120, "window": null,
         "kv_shape": [2,2,4,128,12], "entries": {},
         "variant": "massv", "aligned_target": "qwensim-L", "multimodal": true}
      ]"#,
            r#""drafters": []"#,
        );
        assert!(stripped.contains(r#""drafters": []"#), "strip must apply");
        Manifest::from_json(&stripped).unwrap()
    }

    #[test]
    fn missing_drafter_falls_back_for_chain_and_tree() {
        let m = no_drafters_manifest();
        let router = Router::new("qwensim-L");
        let chain = router
            .route(
                &req(
                    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive: false },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(chain.drafter, None, "chain mode must degrade, not fail");
        let tree = router
            .route(
                &req(
                    DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive: false },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(tree.drafter, None, "tree mode must degrade, not fail");
    }

    #[test]
    fn fallback_clears_text_only_draft() {
        // text_only_draft modifies *drafting*; with no drafter resolved the
        // flag must not leak into the route (a stale true would change the
        // prefix-cache key and session construction for a plain decode)
        let m = no_drafters_manifest();
        let router = Router::new("qwensim-L");
        let r = router
            .route(
                &req(
                    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: true, adaptive: false },
                    "",
                ),
                &m,
            )
            .unwrap();
        assert_eq!(r.drafter, None);
        assert!(!r.text_only_draft, "fallback must reset text_only_draft");
    }

    #[test]
    fn unknown_target_errors_before_drafter_fallback() {
        // target validation must win over the drafter fallback: a typo'd
        // target under a drafting mode is a clean error, not a silent
        // target-only decode on some other model
        let m = Manifest::from_json(TOY).unwrap();
        let router = Router::new("qwensim-L");
        for mode in [
            DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive: false },
            DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive: false },
        ] {
            let err = router.route(&req(mode, "nope"), &m).unwrap_err();
            assert!(err.contains("nope"), "error must name the bad target: {err}");
        }
    }
}
