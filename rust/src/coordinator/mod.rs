//! Layer-3 coordinator: the paper's serving-system integration.
//!
//! MASSV's contribution is a drafting *method*; deploying it requires a
//! serving coordinator (the paper's Figure-2 "deployment configuration").
//! This module provides the vLLM-router-shaped stack: request types + FSM,
//! two-class admission-controlled scheduler, family-aware model router,
//! and a worker-pool engine that multiplexes resumable decode sessions at
//! iteration granularity (continuous batching) over shared compiled
//! executables, with streaming delivery, cancellation, and deadlines.

pub mod engine;
pub mod front;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod stream;

pub use engine::{Engine, EngineConfig, SchedPolicy, Update};
pub use front::EngineFront;
pub use request::{DecodeMode, Priority, Request, Response};
pub use router::{Route, Router};
pub use scheduler::{Scheduler, Submit, DEFAULT_TENANT};
pub use stream::{update_channel, UpdateReceiver, UpdateSender};
