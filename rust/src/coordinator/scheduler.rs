//! Weighted-fair, two-class, admission-controlled scheduler.
//!
//! Work is queued per **tenant**, and tenants are served credit-based
//! round-robin: each refill round grants every tenant `weight` credits
//! (default 1, `set_weight`), and a dispatch consumes one credit, so over
//! any window tenants with queued work split dispatches in proportion to
//! their weights -- a flooding tenant cannot starve a light one.  Within
//! a tenant the original two-class policy is unchanged: interactive
//! requests are served ahead of batch requests, but batch never starves
//! -- after `AGING_LIMIT` consecutive interactive dispatches with batch
//! work waiting, one batch job is forced through.  The single-tenant case
//! (every caller using `submit`/`requeue`, which route to the default
//! tenant) degenerates to exactly the old two-class behavior.
//!
//! Admission is bounded (`capacity`, across all tenants); when the queue
//! is full the submitter gets an immediate `Rejected` -- backpressure
//! instead of unbounded memory.
//!
//! Under continuous batching the queue holds *steps*, not requests: a
//! worker pops one item, runs one decode iteration, and `requeue`s the
//! resumed session.  Requeued sessions sit in the queue between steps, so
//! `capacity` becomes a bound on requests *in the system* (waiting
//! admissions + runnable in-flight sessions), vLLM `max_num_seqs`-style --
//! NOT just on waiting requests as under run-to-completion.  Size it as
//! "max concurrent requests", not "max backlog".  `requeue` itself never
//! rejects (an in-flight session was already admitted) and ignores
//! `closed`, so draining a shut-down engine still finishes every in-flight
//! request.  Because requeued sessions re-enter the *back* of their class
//! queue, the two-class aging policy applies per step: sessions of one
//! class round-robin, and interactive steps preempt batch steps up to the
//! aging limit.
//!
//! `pop_batch` extends the single pop for cross-request batching: the
//! first item is chosen exactly as `pop` would (weighted-fair + aging),
//! then up to `max - 1` queued items with the same caller-supplied key are
//! ganged into the same dispatch -- the engine keys steps by lane
//! compatibility (`coordinator::engine`) and leaves admissions keyless so
//! they always dispatch alone.  A gang counts as one dispatch for aging
//! and consumes one credit: lanes riding along are free work on a pass
//! that runs anyway, whichever tenant they belong to.
//!
//! Invariants (property-tested below):
//!   * FIFO within a (tenant, class)
//!   * no starvation of either class or any tenant
//!   * admissions are rejected whenever depth >= capacity; only requeues
//!     may push depth past it
//!   * every submitted job is either dispatched exactly once or rejected
//!     (gangs included: `pop_batch` never duplicates or drops an item)

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};

use crate::coordinator::request::Priority;

const AGING_LIMIT: usize = 4;

/// Tenant name used by the tenant-less `submit`/`requeue` wrappers and as
/// the wire-level default when a request names no tenant.
pub const DEFAULT_TENANT: &str = "default";

#[derive(Debug)]
struct TenantQ<T> {
    name: String,
    weight: u32,
    credit: u32,
    interactive: VecDeque<T>,
    batch: VecDeque<T>,
    consecutive_interactive: usize,
}

impl<T> TenantQ<T> {
    fn len(&self) -> usize {
        self.interactive.len() + self.batch.len()
    }

    /// The original two-class aging pick, scoped to this tenant.
    fn pick(&mut self) -> Option<T> {
        let force_batch = self.consecutive_interactive >= AGING_LIMIT && !self.batch.is_empty();
        if !force_batch {
            if let Some(it) = self.interactive.pop_front() {
                self.consecutive_interactive += 1;
                return Some(it);
            }
        }
        if let Some(it) = self.batch.pop_front() {
            self.consecutive_interactive = 0;
            return Some(it);
        }
        // batch empty: retry interactive (force_batch may have skipped it)
        if let Some(it) = self.interactive.pop_front() {
            self.consecutive_interactive += 1;
            return Some(it);
        }
        None
    }
}

#[derive(Debug)]
struct State<T> {
    tenants: Vec<TenantQ<T>>,
    cursor: usize,
    weights: HashMap<String, u32>,
    closed: bool,
}

impl<T> State<T> {
    fn total(&self) -> usize {
        self.tenants.iter().map(|t| t.len()).sum()
    }

    fn tenant_mut(&mut self, name: &str) -> &mut TenantQ<T> {
        if let Some(i) = self.tenants.iter().position(|t| t.name == name) {
            return &mut self.tenants[i];
        }
        let weight = self.weights.get(name).copied().unwrap_or(1);
        self.tenants.push(TenantQ {
            name: name.to_string(),
            weight,
            credit: 0,
            interactive: VecDeque::new(),
            batch: VecDeque::new(),
            consecutive_interactive: 0,
        });
        self.tenants.last_mut().unwrap()
    }

    /// Weighted-fair pick: serve the first tenant at/after the cursor
    /// that has both queued work and credit; when every tenant with work
    /// is out of credit, refill all credits from the weights and retry.
    /// Emptied tenant queues are pruned (their configured weight persists
    /// in the weights map).
    fn pick(&mut self) -> Option<T> {
        if self.total() == 0 {
            return None;
        }
        loop {
            let n = self.tenants.len();
            let found = (0..n)
                .map(|off| (self.cursor + off) % n)
                .find(|&i| self.tenants[i].len() > 0 && self.tenants[i].credit > 0);
            match found {
                Some(i) => {
                    self.cursor = i;
                    let t = &mut self.tenants[i];
                    t.credit -= 1;
                    let item = t.pick();
                    if self.tenants[i].len() == 0 {
                        self.tenants.remove(i);
                        if self.cursor >= self.tenants.len() {
                            self.cursor = 0;
                        }
                    }
                    return item;
                }
                None => {
                    for t in &mut self.tenants {
                        t.credit = t.weight.max(1);
                    }
                }
            }
        }
    }
}

pub struct Scheduler<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    pub capacity: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Submit {
    Accepted,
    Rejected,
}

impl<T> Scheduler<T> {
    pub fn new(capacity: usize) -> Self {
        Scheduler {
            state: Mutex::new(State {
                tenants: Vec::new(),
                cursor: 0,
                weights: HashMap::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().total()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Set a tenant's fair-share weight (credits granted per refill
    /// round).  Applies to queued work immediately and persists across
    /// the tenant's queue emptying.  Weight 0 is clamped to 1 at refill.
    pub fn set_weight(&self, tenant: &str, weight: u32) {
        let mut s = self.state.lock().unwrap();
        s.weights.insert(tenant.to_string(), weight);
        if let Some(t) = s.tenants.iter_mut().find(|t| t.name == tenant) {
            t.weight = weight;
        }
    }

    /// Non-blocking submit with admission control (default tenant).
    pub fn submit(&self, item: T, class: Priority) -> Submit {
        self.submit_for(DEFAULT_TENANT, item, class)
    }

    /// Non-blocking submit with admission control, under a tenant queue.
    /// Capacity is a global bound across tenants.
    pub fn submit_for(&self, tenant: &str, item: T, class: Priority) -> Submit {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.total() >= self.capacity {
            return Submit::Rejected;
        }
        let t = s.tenant_mut(tenant);
        match class {
            Priority::Interactive => t.interactive.push_back(item),
            Priority::Batch => t.batch.push_back(item),
        }
        drop(s);
        self.cv.notify_one();
        Submit::Accepted
    }

    /// Requeue an in-flight item (one that was popped and needs another
    /// turn) on the default tenant.  See `requeue_for`.
    pub fn requeue(&self, item: T, class: Priority) {
        self.requeue_for(DEFAULT_TENANT, item, class)
    }

    /// Requeue an in-flight item under its tenant.  Never rejects: the
    /// item was already admitted, and requeueing must succeed after
    /// `close` so the drain path can finish running sessions.  (In-flight
    /// items still count toward the depth `submit` checks -- see the
    /// module docs on capacity semantics.)
    pub fn requeue_for(&self, tenant: &str, item: T, class: Priority) {
        let mut s = self.state.lock().unwrap();
        let t = s.tenant_mut(tenant);
        match class {
            Priority::Interactive => t.interactive.push_back(item),
            Priority::Batch => t.batch.push_back(item),
        }
        drop(s);
        self.cv.notify_one();
    }

    /// Blocking pop; returns None once closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.pick() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Blocking batched pop: dispatch the first item exactly as `pop`
    /// would (weighted-fair + two-class aging decide it), then gang up to
    /// `max - 1` more items whose `key` equals the first's -- scanning
    /// each tenant's interactive then batch queue, front-to-back, so FIFO
    /// order is preserved among the ganged items and untouched for
    /// everything skipped.  Items whose key is `None` are never ganged
    /// and never stolen (the engine's admissions).  The whole gang counts
    /// as ONE dispatch for the aging rule and the tenant credits -- lanes
    /// riding along are free work on a pass that runs anyway.  Returns
    /// None once closed AND drained.
    pub fn pop_batch<K: PartialEq>(
        &self,
        max: usize,
        key: impl Fn(&T) -> Option<K>,
    ) -> Option<Vec<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(first) = s.pick() {
                let k = key(&first);
                let mut gang = Vec::with_capacity(max.max(1));
                gang.push(first);
                if let Some(k) = k {
                    for t in &mut s.tenants {
                        for q in [&mut t.interactive, &mut t.batch] {
                            let mut i = 0;
                            while i < q.len() && gang.len() < max {
                                if key(&q[i]).is_some_and(|ki| ki == k) {
                                    if let Some(item) = q.remove(i) {
                                        gang.push(item);
                                    }
                                } else {
                                    i += 1;
                                }
                            }
                        }
                    }
                    s.tenants.retain(|t| t.len() > 0);
                    if s.cursor >= s.tenants.len() {
                        s.cursor = 0;
                    }
                }
                return Some(gang);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Non-blocking pop (for tests and the drain path).
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().pick()
    }

    /// Visit every queued item in *reverse* dispatch priority -- batch
    /// queues back-to-front first, then interactive queues back-to-front
    /// -- under the queue lock, without dequeuing anything.  `f` returns
    /// `false` to stop early.  This is the engine's preemption-victim
    /// order: the item the scheduler would dispatch LAST is the first one
    /// asked to give up its KV blocks under pool pressure.
    pub fn visit_backlog_mut(&self, mut f: impl FnMut(&mut T) -> bool) {
        let mut s = self.state.lock().unwrap();
        let batches = s.tenants.iter_mut().rev().flat_map(|t| t.batch.iter_mut().rev());
        for item in batches {
            if !f(item) {
                return;
            }
        }
        let interactives =
            s.tenants.iter_mut().rev().flat_map(|t| t.interactive.iter_mut().rev());
        for item in interactives {
            if !f(item) {
                return;
            }
        }
    }

    /// Close the queue; waiting poppers drain the backlog then get None.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::propcheck;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn fifo_within_class() {
        let s = Scheduler::new(16);
        for i in 0..5 {
            assert_eq!(s.submit(i, Priority::Interactive), Submit::Accepted);
        }
        for i in 0..5 {
            assert_eq!(s.try_pop(), Some(i));
        }
    }

    #[test]
    fn interactive_preempts_batch_but_batch_progresses() {
        let s = Scheduler::new(64);
        for i in 0..3 {
            s.submit(100 + i, Priority::Batch);
        }
        for i in 0..10 {
            s.submit(i, Priority::Interactive);
        }
        let mut order = Vec::new();
        while let Some(x) = s.try_pop() {
            order.push(x);
        }
        // first AGING_LIMIT are interactive, then one batch is forced
        assert!(order[..AGING_LIMIT].iter().all(|&x| x < 100));
        assert_eq!(order[AGING_LIMIT], 100);
        // everything dispatched exactly once
        assert_eq!(order.len(), 13);
    }

    #[test]
    fn admission_rejects_when_full() {
        let s = Scheduler::new(2);
        assert_eq!(s.submit(1, Priority::Batch), Submit::Accepted);
        assert_eq!(s.submit(2, Priority::Interactive), Submit::Accepted);
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Rejected);
        s.try_pop();
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Accepted);
    }

    #[test]
    fn close_drains_then_none() {
        let s = Scheduler::new(8);
        s.submit(1, Priority::Batch);
        s.close();
        assert_eq!(s.submit(2, Priority::Batch), Submit::Rejected);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn blocking_pop_wakes_on_submit() {
        let s = Arc::new(Scheduler::new(8));
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop());
        std::thread::sleep(std::time::Duration::from_millis(30));
        s.submit(42, Priority::Interactive);
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn requeue_bypasses_capacity_and_close() {
        let s = Scheduler::new(1);
        assert_eq!(s.submit(1, Priority::Interactive), Submit::Accepted);
        assert_eq!(s.submit(2, Priority::Interactive), Submit::Rejected);
        // a popped item can always come back, even at capacity
        let x = s.try_pop().unwrap();
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Accepted);
        s.requeue(x, Priority::Interactive); // depth now 2 > capacity 1
        assert_eq!(s.len(), 2);
        // ...and even after close (drain must finish in-flight sessions)
        s.close();
        let y = s.try_pop().unwrap();
        s.requeue(y, Priority::Batch);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), Some(y));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn requeue_round_robins_within_class() {
        // two "sessions" alternating steps: pop A, requeue A, pop B, ...
        let s = Scheduler::new(8);
        s.submit("a", Priority::Interactive);
        s.submit("b", Priority::Interactive);
        let mut order = Vec::new();
        for _ in 0..6 {
            let x = s.try_pop().unwrap();
            order.push(x);
            s.requeue(x, Priority::Interactive);
        }
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b"]);
    }

    #[test]
    fn visit_backlog_walks_lowest_priority_first() {
        let s = Scheduler::new(16);
        s.submit(1, Priority::Interactive);
        s.submit(2, Priority::Interactive);
        s.submit(100, Priority::Batch);
        s.submit(101, Priority::Batch);
        // Victim order: back of batch, front of batch, back of interactive,
        // front of interactive -- the exact reverse of dispatch order.
        let mut seen = Vec::new();
        s.visit_backlog_mut(|x| {
            seen.push(*x);
            true
        });
        assert_eq!(seen, vec![101, 100, 2, 1]);
        // Early stop and in-place mutation both work; nothing is dequeued.
        s.visit_backlog_mut(|x| {
            *x += 1000;
            false
        });
        assert_eq!(s.len(), 4);
        let mut drained = Vec::new();
        while let Some(x) = s.try_pop() {
            drained.push(x);
        }
        assert_eq!(drained, vec![1, 2, 100, 1101]);
    }

    /// Key items by sign: positive values gang together, negative values
    /// gang together, zero is an "admission" (never ganged, never stolen).
    fn sign_key(x: &i64) -> Option<i64> {
        match x.cmp(&0) {
            std::cmp::Ordering::Greater => Some(1),
            std::cmp::Ordering::Less => Some(-1),
            std::cmp::Ordering::Equal => None,
        }
    }

    #[test]
    fn pop_batch_gangs_compatible_items_across_classes() {
        let s = Scheduler::new(64);
        s.submit(1i64, Priority::Interactive);
        s.submit(-5, Priority::Interactive);
        s.submit(2, Priority::Interactive);
        s.submit(3, Priority::Batch);
        let gang = s.pop_batch(8, sign_key).unwrap();
        // first item decides the key; compatible items join from both
        // queues in FIFO order, incompatible ones keep their place
        assert_eq!(gang, vec![1, 2, 3]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![-5]);
        assert!(s.is_empty());
    }

    #[test]
    fn pop_batch_respects_max_and_keyless_items() {
        let s = Scheduler::new(64);
        for i in 1..=5i64 {
            s.submit(i, Priority::Interactive);
        }
        let gang = s.pop_batch(3, sign_key).unwrap();
        assert_eq!(gang, vec![1, 2, 3], "gang is capped at max");
        assert_eq!(s.len(), 2);

        // a keyless (admission) head is dispatched alone, and keyless
        // items are never stolen into someone else's gang
        let s = Scheduler::new(64);
        s.submit(0i64, Priority::Interactive);
        s.submit(7, Priority::Interactive);
        s.submit(0, Priority::Interactive);
        s.submit(8, Priority::Interactive);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![0]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![7, 8]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![0]);
        assert!(s.is_empty());
    }

    #[test]
    fn pop_batch_drains_then_none_after_close() {
        let s = Scheduler::new(8);
        s.submit(4i64, Priority::Batch);
        s.submit(5, Priority::Batch);
        s.close();
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![4, 5]);
        assert_eq!(s.pop_batch(8, sign_key), None);
    }

    #[test]
    fn weighted_tenants_split_dispatches_by_weight() {
        let s = Scheduler::new(64);
        s.set_weight("gold", 3);
        s.set_weight("free", 1);
        for i in 0..12 {
            s.submit_for("gold", i, Priority::Interactive);
            s.submit_for("free", 100 + i, Priority::Interactive);
        }
        // over any full refill rounds, dispatches split 3:1
        let first8: Vec<i64> = (0..8).map(|_| s.try_pop().unwrap()).collect();
        let gold = first8.iter().filter(|&&x| x < 100).count();
        assert_eq!(gold, 6, "weight-3 tenant gets 3 of every 4 dispatches: {first8:?}");
        // FIFO preserved within each tenant
        let golds: Vec<i64> = first8.iter().copied().filter(|&x| x < 100).collect();
        assert_eq!(golds, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn flooding_tenant_cannot_starve_light_tenant() {
        let s = Scheduler::new(4096);
        let mut flood_id = 0i64;
        for _ in 0..64 {
            s.submit_for("flood", flood_id, Priority::Interactive);
            flood_id += 1;
        }
        // a light tenant arriving behind a deep flood backlog is served
        // within one refill round, not after the flood drains
        s.submit_for("user", 1_000_000, Priority::Interactive);
        let mut pops_until_user = 0;
        loop {
            let x = s.try_pop().unwrap();
            if x == 1_000_000 {
                break;
            }
            pops_until_user += 1;
            // keep the flood queue topped up while waiting
            s.submit_for("flood", flood_id, Priority::Interactive);
            flood_id += 1;
        }
        assert!(
            pops_until_user <= 2,
            "light tenant waited {pops_until_user} dispatches behind the flood"
        );
    }

    #[test]
    fn tenant_weight_survives_queue_drain() {
        let s = Scheduler::new(64);
        s.set_weight("gold", 3);
        s.submit_for("gold", 1, Priority::Interactive);
        assert_eq!(s.try_pop(), Some(1)); // queue empties, tenant pruned
        for i in 0..6 {
            s.submit_for("gold", 10 + i, Priority::Interactive);
            s.submit_for("free", 100 + i, Priority::Interactive);
        }
        let first4: Vec<i64> = (0..4).map(|_| s.try_pop().unwrap()).collect();
        assert_eq!(first4.iter().filter(|&&x| x < 100).count(), 3);
    }

    #[test]
    fn prop_pop_batch_dispatches_exactly_once() {
        propcheck("pop_batch exactly-once dispatch", 40, |rng: &mut Rng| {
            let cap = 4 + rng.range(40);
            let s = Scheduler::new(cap);
            let mut submitted: Vec<i64> = Vec::new();
            let mut popped: Vec<i64> = Vec::new();
            let mut next = 1i64;
            for _ in 0..(10 + rng.range(150)) {
                if rng.range(2) == 0 {
                    // value sign picks the gang key; ~1/5 are "admissions"
                    let v = match rng.range(5) {
                        0 => 0,
                        n if n < 3 => next,
                        _ => -next,
                    };
                    next += 1;
                    let class = if rng.range(2) == 0 {
                        Priority::Interactive
                    } else {
                        Priority::Batch
                    };
                    let tenant = ["default", "a", "b"][rng.range(3)];
                    if s.submit_for(tenant, v, class) == Submit::Accepted {
                        submitted.push(v);
                    }
                } else if !s.is_empty() {
                    let max = 1 + rng.range(6);
                    let gang = s.pop_batch(max, sign_key).unwrap();
                    if gang.len() > 1 {
                        let k = sign_key(&gang[0]);
                        assert!(k.is_some(), "keyless items must dispatch alone");
                        assert!(
                            gang.iter().all(|x| sign_key(x) == k),
                            "gang mixes keys: {gang:?}"
                        );
                        assert!(gang.len() <= max);
                    }
                    popped.extend(gang);
                }
            }
            while !s.is_empty() {
                popped.extend(s.pop_batch(4, sign_key).unwrap());
            }
            let mut a = submitted.clone();
            let mut b = popped.clone();
            a.sort();
            b.sort();
            if a != b {
                return Err(format!("submitted {a:?} != dispatched {b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_scheduler_invariants() {
        propcheck("scheduler invariants", 60, |rng: &mut Rng| {
            let cap = 1 + rng.range(20);
            let s = Scheduler::new(cap);
            let n_ops = 5 + rng.range(200);
            let mut submitted: Vec<u64> = Vec::new();
            let mut rejected = 0usize;
            let mut popped: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..n_ops {
                if rng.range(2) == 0 {
                    let class = if rng.range(2) == 0 {
                        Priority::Interactive
                    } else {
                        Priority::Batch
                    };
                    let tenant = ["default", "t1", "t2"][rng.range(3)];
                    let id = next_id;
                    next_id += 1;
                    match s.submit_for(tenant, id, class) {
                        Submit::Accepted => submitted.push(id),
                        Submit::Rejected => rejected += 1,
                    }
                    if s.len() > cap {
                        return Err(format!("depth {} > cap {cap}", s.len()));
                    }
                } else if let Some(x) = s.try_pop() {
                    popped.push(x);
                }
            }
            while let Some(x) = s.try_pop() {
                popped.push(x);
            }
            // exactly-once dispatch
            let mut a = submitted.clone();
            let mut b = popped.clone();
            a.sort();
            b.sort();
            if a != b {
                return Err(format!("submitted {a:?} != popped {b:?} (rej {rejected})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_no_starvation_under_interactive_flood() {
        // continuously refill interactive; batch items must still drain
        let s = Scheduler::new(1024);
        for i in 0..5u64 {
            s.submit(1_000_000 + i, Priority::Batch);
        }
        let mut batch_seen = 0;
        let mut id = 0u64;
        for _ in 0..2000 {
            // keep the interactive queue non-empty
            while s.len() < 8 {
                s.submit(id, Priority::Interactive);
                id += 1;
            }
            if let Some(x) = s.try_pop() {
                if x >= 1_000_000 {
                    batch_seen += 1;
                }
            }
        }
        assert_eq!(batch_seen, 5, "batch starved");
    }
}
