//! Two-class admission-controlled scheduler.
//!
//! Interactive requests are served ahead of batch requests, but batch never
//! starves: after `AGING_LIMIT` consecutive interactive dispatches with
//! batch work waiting, one batch job is forced through.  Admission is
//! bounded (`capacity`); when the queue is full the submitter gets an
//! immediate `Rejected` -- backpressure instead of unbounded memory.
//!
//! Under continuous batching the queue holds *steps*, not requests: a
//! worker pops one item, runs one decode iteration, and `requeue`s the
//! resumed session.  Requeued sessions sit in the queue between steps, so
//! `capacity` becomes a bound on requests *in the system* (waiting
//! admissions + runnable in-flight sessions), vLLM `max_num_seqs`-style --
//! NOT just on waiting requests as under run-to-completion.  Size it as
//! "max concurrent requests", not "max backlog".  `requeue` itself never
//! rejects (an in-flight session was already admitted) and ignores
//! `closed`, so draining a shut-down engine still finishes every in-flight
//! request.  Because requeued sessions re-enter the *back* of their class
//! queue, the two-class aging policy applies per step: sessions of one
//! class round-robin, and interactive steps preempt batch steps up to the
//! aging limit.
//!
//! `pop_batch` extends the single pop for cross-request batching: the
//! first item is chosen exactly as `pop` would (aging policy included),
//! then up to `max - 1` queued items with the same caller-supplied key are
//! ganged into the same dispatch -- the engine keys steps by lane
//! compatibility (`coordinator::engine`) and leaves admissions keyless so
//! they always dispatch alone.  A gang counts as one dispatch for aging.
//!
//! Invariants (property-tested below):
//!   * FIFO within a class
//!   * no starvation of either class
//!   * admissions are rejected whenever depth >= capacity; only requeues
//!     may push depth past it
//!   * every submitted job is either dispatched exactly once or rejected
//!     (gangs included: `pop_batch` never duplicates or drops an item)

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::coordinator::request::Priority;

const AGING_LIMIT: usize = 4;

#[derive(Debug)]
struct State<T> {
    interactive: VecDeque<T>,
    batch: VecDeque<T>,
    consecutive_interactive: usize,
    closed: bool,
}

pub struct Scheduler<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    pub capacity: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum Submit {
    Accepted,
    Rejected,
}

impl<T> Scheduler<T> {
    pub fn new(capacity: usize) -> Self {
        Scheduler {
            state: Mutex::new(State {
                interactive: VecDeque::new(),
                batch: VecDeque::new(),
                consecutive_interactive: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        let s = self.state.lock().unwrap();
        s.interactive.len() + s.batch.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking submit with admission control.
    pub fn submit(&self, item: T, class: Priority) -> Submit {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.interactive.len() + s.batch.len() >= self.capacity {
            return Submit::Rejected;
        }
        match class {
            Priority::Interactive => s.interactive.push_back(item),
            Priority::Batch => s.batch.push_back(item),
        }
        drop(s);
        self.cv.notify_one();
        Submit::Accepted
    }

    /// Requeue an in-flight item (one that was popped and needs another
    /// turn).  Never rejects: the item was already admitted, and requeueing
    /// must succeed after `close` so the drain path can finish running
    /// sessions.  (In-flight items still count toward the depth `submit`
    /// checks -- see the module docs on capacity semantics.)
    pub fn requeue(&self, item: T, class: Priority) {
        let mut s = self.state.lock().unwrap();
        match class {
            Priority::Interactive => s.interactive.push_back(item),
            Priority::Batch => s.batch.push_back(item),
        }
        drop(s);
        self.cv.notify_one();
    }

    /// Blocking pop; returns None once closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = Self::pick(&mut s) {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Blocking batched pop: dispatch the first item exactly as `pop`
    /// would (the two-class aging policy decides it), then gang up to
    /// `max - 1` more items whose `key` equals the first's -- scanning
    /// interactive then batch, front-to-back, so FIFO order is preserved
    /// among the ganged items and untouched for everything skipped.
    /// Items whose key is `None` are never ganged and never stolen (the
    /// engine's admissions).  The whole gang counts as ONE dispatch for
    /// the aging rule -- lanes riding along are free work on a pass that
    /// runs anyway.  Returns None once closed AND drained.
    pub fn pop_batch<K: PartialEq>(
        &self,
        max: usize,
        key: impl Fn(&T) -> Option<K>,
    ) -> Option<Vec<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(first) = Self::pick(&mut s) {
                let k = key(&first);
                let mut gang = Vec::with_capacity(max.max(1));
                gang.push(first);
                if let Some(k) = k {
                    let State { interactive, batch, .. } = &mut *s;
                    for q in [interactive, batch] {
                        let mut i = 0;
                        while i < q.len() && gang.len() < max {
                            if key(&q[i]).is_some_and(|ki| ki == k) {
                                if let Some(item) = q.remove(i) {
                                    gang.push(item);
                                }
                            } else {
                                i += 1;
                            }
                        }
                    }
                }
                return Some(gang);
            }
            if s.closed {
                return None;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// Non-blocking pop (for tests and the drain path).
    pub fn try_pop(&self) -> Option<T> {
        Self::pick(&mut self.state.lock().unwrap())
    }

    /// Visit every queued item in *reverse* dispatch priority -- the back
    /// of the batch queue first, then the back of the interactive queue --
    /// under the queue lock, without dequeuing anything.  `f` returns
    /// `false` to stop early.  This is the engine's preemption-victim
    /// order: the item the scheduler would dispatch LAST is the first one
    /// asked to give up its KV blocks under pool pressure.
    pub fn visit_backlog_mut(&self, mut f: impl FnMut(&mut T) -> bool) {
        let mut s = self.state.lock().unwrap();
        let State { interactive, batch, .. } = &mut *s;
        for item in batch.iter_mut().rev().chain(interactive.iter_mut().rev()) {
            if !f(item) {
                return;
            }
        }
    }

    fn pick(s: &mut State<T>) -> Option<T> {
        let force_batch = s.consecutive_interactive >= AGING_LIMIT && !s.batch.is_empty();
        if !force_batch {
            if let Some(it) = s.interactive.pop_front() {
                s.consecutive_interactive += 1;
                return Some(it);
            }
        }
        if let Some(it) = s.batch.pop_front() {
            s.consecutive_interactive = 0;
            return Some(it);
        }
        // batch empty: retry interactive (force_batch may have skipped it)
        if let Some(it) = s.interactive.pop_front() {
            s.consecutive_interactive += 1;
            return Some(it);
        }
        None
    }

    /// Close the queue; waiting poppers drain the backlog then get None.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::propcheck;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn fifo_within_class() {
        let s = Scheduler::new(16);
        for i in 0..5 {
            assert_eq!(s.submit(i, Priority::Interactive), Submit::Accepted);
        }
        for i in 0..5 {
            assert_eq!(s.try_pop(), Some(i));
        }
    }

    #[test]
    fn interactive_preempts_batch_but_batch_progresses() {
        let s = Scheduler::new(64);
        for i in 0..3 {
            s.submit(100 + i, Priority::Batch);
        }
        for i in 0..10 {
            s.submit(i, Priority::Interactive);
        }
        let mut order = Vec::new();
        while let Some(x) = s.try_pop() {
            order.push(x);
        }
        // first AGING_LIMIT are interactive, then one batch is forced
        assert!(order[..AGING_LIMIT].iter().all(|&x| x < 100));
        assert_eq!(order[AGING_LIMIT], 100);
        // everything dispatched exactly once
        assert_eq!(order.len(), 13);
    }

    #[test]
    fn admission_rejects_when_full() {
        let s = Scheduler::new(2);
        assert_eq!(s.submit(1, Priority::Batch), Submit::Accepted);
        assert_eq!(s.submit(2, Priority::Interactive), Submit::Accepted);
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Rejected);
        s.try_pop();
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Accepted);
    }

    #[test]
    fn close_drains_then_none() {
        let s = Scheduler::new(8);
        s.submit(1, Priority::Batch);
        s.close();
        assert_eq!(s.submit(2, Priority::Batch), Submit::Rejected);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn blocking_pop_wakes_on_submit() {
        let s = Arc::new(Scheduler::new(8));
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.pop());
        std::thread::sleep(std::time::Duration::from_millis(30));
        s.submit(42, Priority::Interactive);
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn requeue_bypasses_capacity_and_close() {
        let s = Scheduler::new(1);
        assert_eq!(s.submit(1, Priority::Interactive), Submit::Accepted);
        assert_eq!(s.submit(2, Priority::Interactive), Submit::Rejected);
        // a popped item can always come back, even at capacity
        let x = s.try_pop().unwrap();
        assert_eq!(s.submit(3, Priority::Interactive), Submit::Accepted);
        s.requeue(x, Priority::Interactive); // depth now 2 > capacity 1
        assert_eq!(s.len(), 2);
        // ...and even after close (drain must finish in-flight sessions)
        s.close();
        let y = s.try_pop().unwrap();
        s.requeue(y, Priority::Batch);
        assert_eq!(s.pop(), Some(1));
        assert_eq!(s.pop(), Some(y));
        assert_eq!(s.pop(), None);
    }

    #[test]
    fn requeue_round_robins_within_class() {
        // two "sessions" alternating steps: pop A, requeue A, pop B, ...
        let s = Scheduler::new(8);
        s.submit("a", Priority::Interactive);
        s.submit("b", Priority::Interactive);
        let mut order = Vec::new();
        for _ in 0..6 {
            let x = s.try_pop().unwrap();
            order.push(x);
            s.requeue(x, Priority::Interactive);
        }
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b"]);
    }

    #[test]
    fn visit_backlog_walks_lowest_priority_first() {
        let s = Scheduler::new(16);
        s.submit(1, Priority::Interactive);
        s.submit(2, Priority::Interactive);
        s.submit(100, Priority::Batch);
        s.submit(101, Priority::Batch);
        // Victim order: back of batch, front of batch, back of interactive,
        // front of interactive -- the exact reverse of dispatch order.
        let mut seen = Vec::new();
        s.visit_backlog_mut(|x| {
            seen.push(*x);
            true
        });
        assert_eq!(seen, vec![101, 100, 2, 1]);
        // Early stop and in-place mutation both work; nothing is dequeued.
        s.visit_backlog_mut(|x| {
            *x += 1000;
            false
        });
        assert_eq!(s.len(), 4);
        let mut drained = Vec::new();
        while let Some(x) = s.try_pop() {
            drained.push(x);
        }
        assert_eq!(drained, vec![1, 2, 100, 1101]);
    }

    /// Key items by sign: positive values gang together, negative values
    /// gang together, zero is an "admission" (never ganged, never stolen).
    fn sign_key(x: &i64) -> Option<i64> {
        match x.cmp(&0) {
            std::cmp::Ordering::Greater => Some(1),
            std::cmp::Ordering::Less => Some(-1),
            std::cmp::Ordering::Equal => None,
        }
    }

    #[test]
    fn pop_batch_gangs_compatible_items_across_classes() {
        let s = Scheduler::new(64);
        s.submit(1i64, Priority::Interactive);
        s.submit(-5, Priority::Interactive);
        s.submit(2, Priority::Interactive);
        s.submit(3, Priority::Batch);
        let gang = s.pop_batch(8, sign_key).unwrap();
        // first item decides the key; compatible items join from both
        // queues in FIFO order, incompatible ones keep their place
        assert_eq!(gang, vec![1, 2, 3]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![-5]);
        assert!(s.is_empty());
    }

    #[test]
    fn pop_batch_respects_max_and_keyless_items() {
        let s = Scheduler::new(64);
        for i in 1..=5i64 {
            s.submit(i, Priority::Interactive);
        }
        let gang = s.pop_batch(3, sign_key).unwrap();
        assert_eq!(gang, vec![1, 2, 3], "gang is capped at max");
        assert_eq!(s.len(), 2);

        // a keyless (admission) head is dispatched alone, and keyless
        // items are never stolen into someone else's gang
        let s = Scheduler::new(64);
        s.submit(0i64, Priority::Interactive);
        s.submit(7, Priority::Interactive);
        s.submit(0, Priority::Interactive);
        s.submit(8, Priority::Interactive);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![0]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![7, 8]);
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![0]);
        assert!(s.is_empty());
    }

    #[test]
    fn pop_batch_drains_then_none_after_close() {
        let s = Scheduler::new(8);
        s.submit(4i64, Priority::Batch);
        s.submit(5, Priority::Batch);
        s.close();
        assert_eq!(s.pop_batch(8, sign_key).unwrap(), vec![4, 5]);
        assert_eq!(s.pop_batch(8, sign_key), None);
    }

    #[test]
    fn prop_pop_batch_dispatches_exactly_once() {
        propcheck("pop_batch exactly-once dispatch", 40, |rng: &mut Rng| {
            let cap = 4 + rng.range(40);
            let s = Scheduler::new(cap);
            let mut submitted: Vec<i64> = Vec::new();
            let mut popped: Vec<i64> = Vec::new();
            let mut next = 1i64;
            for _ in 0..(10 + rng.range(150)) {
                if rng.range(2) == 0 {
                    // value sign picks the gang key; ~1/5 are "admissions"
                    let v = match rng.range(5) {
                        0 => 0,
                        n if n < 3 => next,
                        _ => -next,
                    };
                    next += 1;
                    let class = if rng.range(2) == 0 {
                        Priority::Interactive
                    } else {
                        Priority::Batch
                    };
                    if s.submit(v, class) == Submit::Accepted {
                        submitted.push(v);
                    }
                } else if !s.is_empty() {
                    let max = 1 + rng.range(6);
                    let gang = s.pop_batch(max, sign_key).unwrap();
                    if gang.len() > 1 {
                        let k = sign_key(&gang[0]);
                        assert!(k.is_some(), "keyless items must dispatch alone");
                        assert!(
                            gang.iter().all(|x| sign_key(x) == k),
                            "gang mixes keys: {gang:?}"
                        );
                        assert!(gang.len() <= max);
                    }
                    popped.extend(gang);
                }
            }
            while !s.is_empty() {
                popped.extend(s.pop_batch(4, sign_key).unwrap());
            }
            let mut a = submitted.clone();
            let mut b = popped.clone();
            a.sort();
            b.sort();
            if a != b {
                return Err(format!("submitted {a:?} != dispatched {b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_scheduler_invariants() {
        propcheck("scheduler invariants", 60, |rng: &mut Rng| {
            let cap = 1 + rng.range(20);
            let s = Scheduler::new(cap);
            let n_ops = 5 + rng.range(200);
            let mut submitted: Vec<u64> = Vec::new();
            let mut rejected = 0usize;
            let mut popped: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..n_ops {
                if rng.range(2) == 0 {
                    let class = if rng.range(2) == 0 {
                        Priority::Interactive
                    } else {
                        Priority::Batch
                    };
                    let id = next_id;
                    next_id += 1;
                    match s.submit(id, class) {
                        Submit::Accepted => submitted.push(id),
                        Submit::Rejected => rejected += 1,
                    }
                    if s.len() > cap {
                        return Err(format!("depth {} > cap {cap}", s.len()));
                    }
                } else if let Some(x) = s.try_pop() {
                    popped.push(x);
                }
            }
            while let Some(x) = s.try_pop() {
                popped.push(x);
            }
            // exactly-once dispatch
            let mut a = submitted.clone();
            let mut b = popped.clone();
            a.sort();
            b.sort();
            if a != b {
                return Err(format!("submitted {a:?} != popped {b:?} (rej {rejected})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_no_starvation_under_interactive_flood() {
        // continuously refill interactive; batch items must still drain
        let s = Scheduler::new(1024);
        for i in 0..5u64 {
            s.submit(1_000_000 + i, Priority::Batch);
        }
        let mut batch_seen = 0;
        let mut id = 0u64;
        for _ in 0..2000 {
            // keep the interactive queue non-empty
            while s.len() < 8 {
                s.submit(id, Priority::Interactive);
                id += 1;
            }
            if let Some(x) = s.try_pop() {
                if x >= 1_000_000 {
                    batch_seen += 1;
                }
            }
        }
        assert_eq!(batch_seen, 5, "batch starved");
    }
}
