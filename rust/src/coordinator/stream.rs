//! Bounded streaming update channel with chunk coalescing.
//!
//! `Engine::submit_streaming` used to hand back an unbounded
//! `mpsc::Receiver<Update>`: a stalled consumer accumulated one
//! `Update::Chunk` per decode step for the whole generation, so a single
//! slow client could hold O(max_new) frames alive.  This channel bounds
//! the buffer instead -- once `cap` chunk frames are queued, a new chunk
//! is *coalesced* into the newest queued frame rather than appended as a
//! frame of its own.  Chunks only ever concatenate, so the delivered
//! token sequence is bit-identical; only the framing granularity degrades
//! under consumer backpressure.  The sender never blocks (workers must
//! not stall on a slow client), and sending into a dropped receiver
//! returns an error so the engine's auto-cancel-on-disconnect path keeps
//! working.
//!
//! The receiver API mirrors `std::sync::mpsc` (`recv`, `recv_timeout`,
//! same error types) so call sites migrate without behavioral changes.

use std::collections::VecDeque;
use std::sync::mpsc::{RecvError, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::engine::Update;
use crate::coordinator::request::Response;

/// The receiver was dropped; the payload is returned to the caller.
#[derive(Debug)]
pub struct StreamClosed(pub Update);

struct StreamState {
    chunks: VecDeque<Vec<i32>>,
    done: Option<Response>,
    rx_alive: bool,
    senders: usize,
    /// High-water mark of queued chunk frames (bounded-memory assertions).
    peak_chunks: usize,
    /// Chunk sends folded into an already-queued frame.
    coalesced: u64,
}

struct Shared {
    state: Mutex<StreamState>,
    cv: Condvar,
    cap: usize,
}

/// Create a bounded update channel holding at most `cap` chunk frames
/// (clamped to >= 1) plus the terminal `Done` response.
pub fn update_channel(cap: usize) -> (UpdateSender, UpdateReceiver) {
    let shared = Arc::new(Shared {
        state: Mutex::new(StreamState {
            chunks: VecDeque::new(),
            done: None,
            rx_alive: true,
            senders: 1,
            peak_chunks: 0,
            coalesced: 0,
        }),
        cv: Condvar::new(),
        cap: cap.max(1),
    });
    (UpdateSender { shared: shared.clone() }, UpdateReceiver { shared })
}

pub struct UpdateSender {
    shared: Arc<Shared>,
}

impl UpdateSender {
    /// Non-blocking send.  A chunk that arrives while the buffer is full
    /// is appended onto the newest queued chunk (coalescing); `Done`
    /// always fits.  Errors iff the receiver is gone -- the engine uses
    /// that to auto-cancel sessions whose client disconnected.
    pub fn send(&self, update: Update) -> Result<(), StreamClosed> {
        let mut s = self.shared.state.lock().unwrap();
        if !s.rx_alive {
            return Err(StreamClosed(update));
        }
        match update {
            Update::Chunk(tokens) => {
                if s.chunks.len() >= self.shared.cap {
                    s.coalesced += 1;
                    // safe: cap >= 1 and len >= cap implies non-empty
                    s.chunks.back_mut().unwrap().extend(tokens);
                } else {
                    s.chunks.push_back(tokens);
                    s.peak_chunks = s.peak_chunks.max(s.chunks.len());
                }
            }
            Update::Done(resp) => s.done = Some(resp),
        }
        drop(s);
        self.shared.cv.notify_one();
        Ok(())
    }
}

impl Clone for UpdateSender {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        UpdateSender { shared: self.shared.clone() }
    }
}

impl Drop for UpdateSender {
    fn drop(&mut self) {
        let mut s = self.shared.state.lock().unwrap();
        s.senders -= 1;
        if s.senders == 0 {
            drop(s);
            self.shared.cv.notify_all();
        }
    }
}

pub struct UpdateReceiver {
    shared: Arc<Shared>,
}

impl UpdateReceiver {
    /// Blocking receive: chunks in order, then the final `Done`, then
    /// `Err(RecvError)` once every sender is gone and the buffer drained.
    pub fn recv(&self) -> Result<Update, RecvError> {
        let mut s = self.shared.state.lock().unwrap();
        loop {
            if let Some(u) = Self::take(&mut s) {
                return Ok(u);
            }
            if s.senders == 0 {
                return Err(RecvError);
            }
            s = self.shared.cv.wait(s).unwrap();
        }
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<Update, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut s = self.shared.state.lock().unwrap();
        loop {
            if let Some(u) = Self::take(&mut s) {
                return Ok(u);
            }
            if s.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, _) = self.shared.cv.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    fn take(s: &mut StreamState) -> Option<Update> {
        if let Some(c) = s.chunks.pop_front() {
            return Some(Update::Chunk(c));
        }
        s.done.take().map(Update::Done)
    }

    /// High-water mark of buffered chunk frames (test observability for
    /// the bounded-memory guarantee).
    pub fn peak_buffered(&self) -> usize {
        self.shared.state.lock().unwrap().peak_chunks
    }

    /// Number of chunk sends that were folded into an existing frame.
    pub fn coalesced(&self) -> u64 {
        self.shared.state.lock().unwrap().coalesced
    }
}

impl Drop for UpdateReceiver {
    fn drop(&mut self) {
        let mut s = self.shared.state.lock().unwrap();
        s.rx_alive = false;
        // free buffered work eagerly; senders see Err on their next send
        s.chunks.clear();
        s.done = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64) -> Response {
        Response::failure(id, "x".into())
    }

    #[test]
    fn delivers_chunks_then_done_then_disconnect() {
        let (tx, rx) = update_channel(8);
        tx.send(Update::Chunk(vec![1, 2])).unwrap();
        tx.send(Update::Chunk(vec![3])).unwrap();
        tx.send(Update::Done(resp(7))).unwrap();
        drop(tx);
        assert!(matches!(rx.recv(), Ok(Update::Chunk(c)) if c == vec![1, 2]));
        assert!(matches!(rx.recv(), Ok(Update::Chunk(c)) if c == vec![3]));
        assert!(matches!(rx.recv(), Ok(Update::Done(r)) if r.id == 7));
        assert!(rx.recv().is_err());
    }

    #[test]
    fn full_buffer_coalesces_without_reordering() {
        let (tx, rx) = update_channel(2);
        for t in 0..6 {
            tx.send(Update::Chunk(vec![t])).unwrap();
        }
        tx.send(Update::Done(resp(1))).unwrap();
        // exactly cap frames queued; later sends folded into the newest
        assert_eq!(rx.peak_buffered(), 2);
        assert_eq!(rx.coalesced(), 4);
        let mut tokens = Vec::new();
        let mut frames = 0;
        loop {
            match rx.recv().unwrap() {
                Update::Chunk(c) => {
                    tokens.extend(c);
                    frames += 1;
                }
                Update::Done(_) => break,
            }
        }
        assert_eq!(tokens, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(frames, 2);
    }

    #[test]
    fn send_after_receiver_drop_errors() {
        let (tx, rx) = update_channel(4);
        tx.send(Update::Chunk(vec![1])).unwrap();
        drop(rx);
        assert!(tx.send(Update::Chunk(vec![2])).is_err());
        assert!(tx.send(Update::Done(resp(1))).is_err());
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = update_channel(4);
        assert!(matches!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Timeout)
        ));
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            tx.send(Update::Chunk(vec![9])).unwrap();
        });
        assert!(matches!(
            rx.recv_timeout(Duration::from_secs(5)),
            Ok(Update::Chunk(c)) if c == vec![9]
        ));
        h.join().unwrap();
    }

    #[test]
    fn cloned_senders_keep_channel_open_until_all_drop() {
        let (tx, rx) = update_channel(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(Update::Chunk(vec![5])).unwrap();
        drop(tx2);
        assert!(matches!(rx.recv(), Ok(Update::Chunk(c)) if c == vec![5]));
        assert!(rx.recv().is_err());
    }
}
