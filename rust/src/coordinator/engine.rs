//! The serving engine: a worker pool over an iteration-level (continuous
//! batching) scheduler.
//!
//! Requests are decoded as resumable `spec::session::DecodeSession`s.  The
//! scheduler queue holds units of *work* -- admit-and-prefill a new request,
//! or run ONE speculative iteration of an in-flight session -- and workers
//! requeue a stepped session instead of parking on it, so a short
//! interactive request admitted mid-flight interleaves with long batch
//! decodes instead of waiting behind them.  The two-class aging policy in
//! `scheduler.rs` therefore applies per step, not per request.
//!
//! The session model buys three serving capabilities threaded end to end
//! here and through `server::protocol`:
//!
//!   * incremental token streaming (`Engine::submit_streaming` yields an
//!     `Update::Chunk` per decode step, then `Update::Done` with the final
//!     summary `Response`);
//!   * client cancellation (`Engine::cancel`) and per-request deadlines
//!     (`Request::deadline_ms`), both checked between steps -- the session
//!     is dropped cleanly and the client receives the partial output;
//!   * step-level metrics: active sessions, steps per request, time per
//!     output token, cancelled/deadline-exceeded counters.
//!
//! Admission is *cache-aware* (`crate::cache`, `docs/prefix_cache.md`):
//! the first dispatch resolves the request's image (inline pixels are
//! registered under their content hash; `image_id` references resolve to
//! previously sent pixels), then looks up the (target, drafter, image,
//! prompt) prefix.  A hit forks the cached post-prefill KV snapshots for
//! both models instead of running either prefill; a miss runs the cold
//! prefill under single-flight (concurrent same-image requests wait on one
//! image encode, same-prefix requests on one prefill) and fills the cache.
//! Warm output is bit-identical to cold output -- the snapshot is taken
//! before the free token is sampled, so per-request sampling config never
//! enters the cache key.
//!
//! Session KV lives in a shared *paged block pool* (`crate::kv`,
//! `docs/paged_kv.md`) when `EngineConfig::paged_kv` is on (the default):
//! prefix-cache hits and tree forks bump block refcounts instead of deep
//! copying KV literals, divergence copies only the touched block
//! (copy-on-write), and pool pressure is handled by *preemption* -- the
//! lowest-priority backlogged session is swapped out of the pool
//! (`Worker::maybe_preempt`) and restored bit-exactly when next popped --
//! instead of rejecting at admission.  Decoded output is bit-identical
//! with paging on or off.
//!
//! Steps are *ganged* across requests (cross-request batching,
//! `docs/serving.md`): a worker pops up to `EngineConfig::max_batch`
//! compatible steps in one dispatch (`Scheduler::pop_batch`; compatible =
//! same target-pass shape `spec::LaneKind` + same target + same drafter
//! identity) and drives them through ONE fused tick -- every lane's
//! `propose` half-step, then one batched drafter pass
//! (`DraftModel::draft_batch` / `draft_tree_batch`), then one batched
//! target pass (`decode_batch` / `verify_batch` / `verify_tree_batch`),
//! then per-lane `absorb_*`.  All sampling state is per-session, so
//! batched output is bit-identical to sequential stepping -- the
//! `spec::testing::run_batched_vs_sequential` oracle and
//! `rust/tests/batch_equivalence.rs` pin this.  Single-lane dispatches
//! (and `max_batch == 1`) take the pre-batching `step_once` path
//! unchanged; admissions are never ganged.
//!
//! PJRT CPU executables are batch-1 (DESIGN.md section 3) unless the
//! artifact exports `*_batch` entry points, so on stock artifacts the
//! fused tick's win is scheduler amortization (one pop/requeue round-trip
//! per tick instead of per step) while parallelism across sequences still
//! comes from the worker pool; what continuous batching changes is
//! *scheduling*: N workers multiplex M >= N sessions at iteration
//! granularity.  `SchedPolicy::RunToCompletion` restores the old
//! request-at-a-time behavior for A/B comparison (`benches/micro_engine.rs`;
//! `benches/micro_batch.rs` A/Bs ganged vs per-step dispatch).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{self, PrefixCache, PrefixKey, PrefixLookup};
use crate::coordinator::request::{DecodeMode, Request, Response};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{Scheduler, Submit};
use crate::coordinator::stream::{update_channel, UpdateReceiver, UpdateSender};
use crate::kv::{KvPool, KvPoolConfig};
use crate::metrics::Metrics;
use crate::models::{DraftModel, ModelSet, SeqState, TargetModel, VisionEncoding};
use crate::spec::{
    AdaptiveConfig, Calibrator, CalibratorConfig, DecodeSession, GenStats, LaneKind, SpecMode,
    SpecParams, StepOutcome,
};
use crate::tokenizer::Tokenizer;

/// How workers treat an in-flight session after each decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Iteration-level scheduling: after one step the session goes back to
    /// the queue, so admissions interleave with running decodes (default).
    Continuous,
    /// Legacy behavior: the popping worker drives the session to completion
    /// before taking more work (kept for A/B benchmarking).
    RunToCompletion,
}

#[derive(Clone)]
pub struct EngineConfig {
    pub default_target: String,
    pub workers: usize,
    pub queue_capacity: usize,
    pub policy: SchedPolicy,
    /// Byte budget for the multimodal prefix cache (pixels + vision
    /// encodings + post-prefill KV snapshots for both models).  `0`
    /// disables retention in practice (every insert is immediately
    /// evicted); admission still single-flights concurrent encodes.
    pub prefix_cache_bytes: usize,
    /// Upper bound on compatible sessions ganged into one fused batched
    /// tick (`Continuous` policy only).  `1` disables ganging -- pure
    /// per-step dispatch, the pre-batching behavior.  Admissions are
    /// never ganged.
    pub max_batch: usize,
    /// Back per-session KV with the shared paged block pool
    /// (`crate::kv`, `docs/paged_kv.md`): sequence forks -- prefix-cache
    /// hits, tree branches, snapshot exports -- become refcount bumps on
    /// shared blocks with copy-on-write isolation, instead of deep
    /// literal clones.  Output is bit-identical either way (pinned by
    /// `rust/tests/paged_equivalence.rs`); `false` restores the
    /// owned-literal behavior for A/B comparison.
    pub paged_kv: bool,
    /// Byte budget for the paged KV pool.  The pool over-commits --
    /// allocation never fails -- and workers respond to pressure by
    /// swapping out the lowest-priority backlogged sessions
    /// (`Worker::maybe_preempt`) until residency is back under budget.
    pub kv_pool_bytes: usize,
    /// Words (4 bytes each) per KV block.  Smaller blocks share more
    /// aggressively on fork; larger blocks keep tables shorter.
    pub kv_block_words: usize,
    /// Drafter-side vision token compression ratio applied to admissions
    /// that don't carry their own `Request::draft_vision_ratio` override.
    /// `0` defers to the manifest's `draft_vision_ratio` (itself 1 for
    /// older manifests).  The target always prefills at full resolution,
    /// so this knob is output-lossless (see `docs/drafting.md`).
    pub draft_vision_ratio: u32,
    /// Enable the cross-request acceptance calibrator
    /// (`spec::calibrate`): per-iteration accept/reject telemetry flows
    /// into per-class EWMAs, and warmed classes steer chain<->tree
    /// drafting at admission.  OFF by default: calibration carries state
    /// across requests, so a calibrated engine's drafting shape depends on
    /// traffic history -- the batched-vs-unbatched response-identity
    /// guarantee (`tests/batch_equivalence.rs`) only holds with it off.
    pub calibration: bool,
    /// Stream every acceptance observation to this JSONL file (one object
    /// per iteration -- the `python/compile/selfdistill.py` training-data
    /// export).  Only read when `calibration` is on.
    pub calib_jsonl: Option<std::path::PathBuf>,
    /// Maximum chunk frames buffered per streaming request (clamped to
    /// >= 1).  A consumer that stalls past this bound gets later chunks
    /// coalesced into the newest queued frame (`coordinator::stream`):
    /// the delivered token sequence is unchanged, memory stays bounded.
    pub stream_chunk_cap: usize,
    /// Fair-share weights for the weighted-fair scheduler, applied at
    /// startup (`Scheduler::set_weight`).  Tenants not listed here get
    /// weight 1.  A request's tenant comes from `Request::tenant`
    /// (HTTP `x-tenant` header / wire `tenant` field).
    pub tenant_weights: Vec<(String, u32)>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 4,
            queue_capacity: 256,
            policy: SchedPolicy::Continuous,
            prefix_cache_bytes: 64 << 20,
            max_batch: 8,
            paged_kv: true,
            kv_pool_bytes: 64 << 20,
            kv_block_words: crate::kv::DEFAULT_BLOCK_WORDS,
            draft_vision_ratio: 0,
            calibration: false,
            calib_jsonl: None,
            stream_chunk_cap: 64,
            tenant_weights: Vec::new(),
        }
    }
}

/// Incremental delivery for streaming submissions.
#[derive(Debug)]
pub enum Update {
    /// Tokens emitted by one decode step (prefill included).  Concatenating
    /// every chunk of a request yields exactly `Response::tokens`.
    Chunk(Vec<i32>),
    /// Terminal frame: the full summary response (complete token list,
    /// stats, finish_reason).
    Done(Response),
}

#[derive(Clone)]
enum Reply {
    /// Final `Response` only (`Engine::submit` / `Engine::run`).
    Oneshot(mpsc::Sender<Response>),
    /// Per-step chunks then the final response (`Engine::submit_streaming`).
    /// The sender is the bounded coalescing channel (`coordinator::stream`).
    Stream(UpdateSender),
}

struct Job {
    req: Request,
    enqueued: Instant,
    reply: Reply,
    cancel: Arc<AtomicBool>,
    /// Content address of the request's image: hashed from inline pixels
    /// at submission, or the client-supplied `image_id`.  `None` only for
    /// malformed requests (neither pixels nor id), which fail at admission.
    image_id: Option<u64>,
}

impl Job {
    fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }

    /// Deadline is measured from submission; `Some(0)` expires immediately.
    fn deadline_exceeded(&self) -> bool {
        self.req
            .deadline_ms
            .map(|ms| self.enqueued.elapsed().as_millis() as u64 >= ms)
            .unwrap_or(false)
    }
}

/// An admitted, prefilled, not-yet-finished request.
struct Active {
    job: Job,
    session: DecodeSession,
    /// when the first dispatch (prefill) began; latency_ms counts from here
    started: Instant,
    queue_ms: f64,
    /// tokens already delivered as stream chunks
    streamed: usize,
    /// scheduler dispatches consumed (prefill + steps)
    steps: usize,
    /// model handles retained for fused batched passes (clones of the
    /// session's own handles, so a ganged pass runs the same compiled
    /// executables a sequential step would)
    target: TargetModel,
    drafter: Option<DraftModel>,
    /// Pre-joined model identities, computed once at admission: the gang
    /// key is evaluated per scanned queue item under the scheduler lock,
    /// so it must not allocate.  `model_key` pins target + drafter +
    /// variant; `target_key` pins the target alone.
    model_key: Arc<str>,
    target_key: Arc<str>,
}

impl Active {
    /// Lane-compatibility key: sessions gang into one fused tick only when
    /// their next target pass has the same shape AND runs the same models
    /// (same batched executables, comparable windows).  Plain lanes only
    /// run the target decode, so they key on the target alone -- an
    /// adaptive session that fell back to plain decoding gangs with
    /// target-only sessions.  Cloning is a refcount bump; `Arc<str>`
    /// equality compares contents.
    fn batch_key(&self) -> (LaneKind, Arc<str>) {
        let kind = self.session.lane_kind();
        let key = match kind {
            LaneKind::Plain => self.target_key.clone(),
            LaneKind::Chain | LaneKind::Tree => self.model_key.clone(),
        };
        (kind, key)
    }
}

/// Build an `Active::model_key` from the resolved model handles.
fn model_key(target: &TargetModel, drafter: &Option<DraftModel>) -> Arc<str> {
    match drafter {
        Some(d) => format!("{}|{}|{}", target.name(), d.name(), d.variant()).into(),
        None => format!("{}|", target.name()).into(),
    }
}

enum Work {
    /// Route + prefill a fresh request (one dispatch).
    Admit(Job),
    /// Run one decode iteration of an in-flight session.
    Step(Box<Active>),
}

pub struct Engine {
    pub models: Arc<ModelSet>,
    pub tokenizer: Arc<Tokenizer>,
    pub metrics: Arc<Metrics>,
    pub cache: Arc<PrefixCache>,
    /// The shared paged KV block pool (`None` when `paged_kv` is off).
    pub kv_pool: Option<Arc<KvPool>>,
    /// The cross-request acceptance calibrator (`None` when
    /// `EngineConfig::calibration` is off).  Workers feed it per-iteration
    /// accept/reject observations; admissions consult it for per-class
    /// chain<->tree steering; `scrape` exports its per-class state.
    pub calibrator: Option<Arc<Calibrator>>,
    sched: Arc<Scheduler<Work>>,
    cancels: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    stream_chunk_cap: usize,
}

impl Engine {
    pub fn start(artifacts_dir: &str, cfg: EngineConfig) -> Result<Engine> {
        let models = ModelSet::load(artifacts_dir)?;
        let tokenizer = Arc::new(Tokenizer::load(artifacts_dir)?);
        let metrics = Arc::new(Metrics::new());
        let cache = PrefixCache::new(cfg.prefix_cache_bytes, metrics.clone());
        let sched = Arc::new(Scheduler::new(cfg.queue_capacity));
        let router = Arc::new(Router::new(cfg.default_target.clone()));
        let cancels = Arc::new(Mutex::new(HashMap::new()));

        metrics.batch_max_lanes.set(cfg.max_batch.max(1) as i64);
        for (tenant, weight) in &cfg.tenant_weights {
            sched.set_weight(tenant, *weight);
        }
        let calibrator = if cfg.calibration {
            let cal = Arc::new(Calibrator::new(
                CalibratorConfig::default(),
                models.manifest.gamma,
            ));
            if let Some(path) = &cfg.calib_jsonl {
                cal.log_jsonl_to(path)?;
            }
            Some(cal)
        } else {
            None
        };
        let kv_pool = cfg.paged_kv.then(|| {
            KvPool::with_metrics(
                KvPoolConfig {
                    block_words: cfg.kv_block_words,
                    budget_bytes: cfg.kv_pool_bytes,
                },
                Some(metrics.clone()),
            )
        });
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let w = Worker {
                models: models.clone(),
                tokenizer: tokenizer.clone(),
                metrics: metrics.clone(),
                cache: cache.clone(),
                kv_pool: kv_pool.clone(),
                calibrator: calibrator.clone(),
                sched: sched.clone(),
                router: router.clone(),
                cancels: cancels.clone(),
                policy: cfg.policy,
                max_batch: cfg.max_batch.max(1),
                workers: cfg.workers.max(1),
                draft_vision_ratio: cfg.draft_vision_ratio,
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("massv-worker-{wid}"))
                    .spawn(move || w.run())?,
            );
        }
        Ok(Engine {
            models,
            tokenizer,
            metrics,
            cache,
            kv_pool,
            calibrator,
            sched,
            cancels,
            workers,
            next_id: AtomicU64::new(1),
            stream_chunk_cap: cfg.stream_chunk_cap,
        })
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a request; the final response arrives on the returned channel.
    /// Backpressure: a full queue yields an immediate rejected Response.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.enqueue(req, Reply::Oneshot(tx));
        rx
    }

    /// Submit a request for streaming delivery: one `Update::Chunk` per
    /// decode step, then `Update::Done` with the summary response.  If the
    /// receiver is dropped mid-stream the session is cancelled.  The
    /// channel is bounded (`EngineConfig::stream_chunk_cap`): a consumer
    /// that stalls gets later chunks coalesced, never an unbounded queue.
    pub fn submit_streaming(&self, req: Request) -> UpdateReceiver {
        let (tx, rx) = update_channel(self.stream_chunk_cap);
        self.enqueue(req, Reply::Stream(tx));
        rx
    }

    fn enqueue(&self, req: Request, reply: Reply) {
        self.metrics.requests_received.inc();
        let id = req.id;
        let priority = req.priority;
        let tenant = req.tenant.clone();
        self.metrics.tenant(&tenant).received.inc();
        let cancel = Arc::new(AtomicBool::new(false));
        // content-address the image up front so every terminal response --
        // including rejections -- can report the reusable image_id
        let image_id = if req.image.is_empty() {
            req.image_id
        } else {
            Some(cache::image_hash(&req.image))
        };
        // register before submit so a cancel can never race a fast worker
        self.cancels.lock().unwrap().insert(id, cancel.clone());
        let t0 = Instant::now();
        let job = Job { req, enqueued: t0, reply: reply.clone(), cancel, image_id };
        match self.sched.submit_for(&tenant, Work::Admit(job), priority) {
            Submit::Accepted => {
                self.metrics.queue_depth.set(self.sched.len() as i64);
            }
            Submit::Rejected => {
                self.cancels.lock().unwrap().remove(&id);
                self.metrics.requests_rejected.inc();
                self.metrics.tenant(&tenant).rejected.inc();
                // rejections are terminal outcomes too: record their (tiny)
                // queue time and latency instead of dropping them from the
                // histograms
                let ms = t0.elapsed().as_secs_f64() * 1000.0;
                self.metrics.queue_ms.record(ms);
                self.metrics.latency_ms.record(ms);
                let mut resp = Response::failure(id, "queue full (backpressure)".into());
                resp.finish_reason = "rejected".into();
                resp.queue_ms = ms;
                resp.latency_ms = ms;
                resp.image_id = image_id.map(cache::format_image_id).unwrap_or_default();
                send_final(&reply, resp);
            }
        }
    }

    /// Submit and wait (convenience for examples/benches).
    pub fn run(&self, req: Request) -> Response {
        let id = req.id;
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Response::failure(id, "engine shut down".into()))
    }

    /// Cancel a queued or in-flight request.  Returns true if the request
    /// was still live; the client receives a partial-output response with
    /// `finish_reason = "cancelled"` once the worker observes the flag
    /// (before its next decode step).
    pub fn cancel(&self, id: u64) -> bool {
        match self.cancels.lock().unwrap().get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Current scheduler depth (queued admissions + runnable sessions).
    pub fn queue_len(&self) -> usize {
        self.sched.len()
    }

    /// Metrics snapshot with derived gauges refreshed under the scheduler
    /// lock (the inline queue_depth updates race with worker pops; scrape
    /// is authoritative).
    pub fn scrape(&self) -> HashMap<String, f64> {
        self.metrics.queue_depth.set(self.sched.len() as i64);
        let mut out = self.metrics.render();
        // merge per-class calibrator state so operators see the live
        // acceptance EWMAs and recommendations the serving loop acts on
        if let Some(cal) = &self.calibrator {
            for s in cal.snapshot() {
                out.insert(format!("calib_alpha{{class=\"{}\"}}", s.class), s.alpha);
                out.insert(
                    format!("calib_accepted_len{{class=\"{}\"}}", s.class),
                    s.accepted_len_ema,
                );
                out.insert(format!("calib_obs{{class=\"{}\"}}", s.class), s.obs as f64);
                out.insert(format!("calib_gamma{{class=\"{}\"}}", s.class), s.gamma as f64);
                out.insert(
                    format!("calib_tree{{class=\"{}\"}}", s.class),
                    if s.tree { 1.0 } else { 0.0 },
                );
            }
        }
        out
    }

    /// Graceful shutdown: drain the queue (in-flight sessions finish; their
    /// steps keep requeueing past close), then join workers.
    pub fn shutdown(mut self) {
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(cal) = &self.calibrator {
            cal.flush_jsonl();
        }
    }
}

/// Per-lane shares of a fused pass's wall time.  Plain integer division
/// would drop the remainder -- zeroing `decode_micros` (and therefore
/// tpot) entirely on microsecond-scale scripted passes -- so the first
/// `total % n` lanes carry one extra microsecond and the shares always
/// sum to `total_us`.
fn time_shares(total_us: u64, n: usize) -> impl Iterator<Item = u64> {
    let n64 = n.max(1) as u64;
    let (q, r) = (total_us / n64, total_us % n64);
    (0..n64).map(move |i| q + u64::from(i < r))
}

fn send_final(reply: &Reply, resp: Response) {
    match reply {
        Reply::Oneshot(tx) => {
            let _ = tx.send(resp);
        }
        Reply::Stream(tx) => {
            let _ = tx.send(Update::Done(resp));
        }
    }
}

/// Per-thread serving state: shared handles plus the scheduling policy.
struct Worker {
    models: Arc<ModelSet>,
    tokenizer: Arc<Tokenizer>,
    metrics: Arc<Metrics>,
    cache: Arc<PrefixCache>,
    /// Shared paged KV pool; `None` runs sessions on owned literals.
    kv_pool: Option<Arc<KvPool>>,
    /// Shared acceptance calibrator; `None` when calibration is off.
    calibrator: Option<Arc<Calibrator>>,
    sched: Arc<Scheduler<Work>>,
    router: Arc<Router>,
    cancels: Arc<Mutex<HashMap<u64, Arc<AtomicBool>>>>,
    policy: SchedPolicy,
    /// Ganging bound for fused batched ticks (>= 1).
    max_batch: usize,
    /// Pool size, for the fair-share gang bound (see `Worker::run`).
    workers: usize,
    /// Engine-level drafter vision compression default (0 = manifest).
    draft_vision_ratio: u32,
}

/// Everything `make_session` resolves for one admission.
struct SessionParts {
    session: DecodeSession,
    /// target handle retained for the (cacheable) image-encode stage and
    /// for fused batched passes
    target: TargetModel,
    /// drafter handle retained for fused batched passes (None = target-only)
    drafter: Option<DraftModel>,
    prompt_ids: Vec<i32>,
    len: usize,
    /// drafter identity + vision compression ratio for the prefix-cache
    /// key (None = target-only)
    drafter_key: Option<(String, String, bool, u32)>,
}

impl Worker {
    fn run(&self) {
        loop {
            // gang compatible steps into one dispatch under continuous
            // scheduling; pop_batch never mixes keys, so a dispatch is
            // either one admission or a homogeneous group of steps.  The
            // gang is additionally bounded by the backlog's fair share per
            // worker: when the backend has no `*_batch` entry points the
            // fused pass degenerates to a per-lane loop, and without this
            // bound one worker would drain steps the rest of the pool
            // could run in parallel, idling the other threads.
            let fair = self.sched.len().div_ceil(self.workers).max(1);
            let bound = self.max_batch.min(fair);
            let works = if self.policy == SchedPolicy::Continuous && bound > 1 {
                self.sched.pop_batch(bound, |w| match w {
                    Work::Step(active) => Some(active.batch_key()),
                    Work::Admit(_) => None,
                })
            } else {
                self.sched.pop().map(|w| vec![w])
            };
            let Some(works) = works else { break };
            self.metrics.queue_depth.set(self.sched.len() as i64);
            let mut steps = Vec::with_capacity(works.len());
            for work in works {
                match work {
                    Work::Admit(job) => self.admit(job),
                    Work::Step(active) => steps.push(active),
                }
            }
            if steps.len() <= 1 {
                if let Some(active) = steps.pop() {
                    if let Some(active) = self.step_once(active) {
                        self.requeue_step(active);
                    }
                }
            } else {
                self.step_batch(steps);
            }
            self.maybe_preempt();
        }
    }

    /// Relieve KV-pool pressure by swapping out backlogged sessions.  The
    /// pool over-commits (allocation never fails), so admission never
    /// rejects on memory; instead, whenever residency exceeds the byte
    /// budget, the queued session the scheduler would dispatch LAST --
    /// back of the batch class, then back of interactive
    /// (`Scheduler::visit_backlog_mut`) -- has its KV blocks compacted out
    /// of the pool.  The session stays queued; when it is next popped,
    /// `kv_swap_in` restores its blocks bit-exactly before the step runs,
    /// so preemption is invisible in the output (pinned by
    /// `rust/tests/paged_equivalence.rs`).  Sessions mid-dispatch on other
    /// workers are never touched: only items *in* the queue are visited,
    /// and the visit holds the queue lock.
    fn maybe_preempt(&self) {
        let Some(pool) = &self.kv_pool else { return };
        if !pool.over_budget() {
            return;
        }
        let mut swapped = 0u32;
        self.sched.visit_backlog_mut(|work| {
            if let Work::Step(active) = work {
                if !active.session.kv_swapped() {
                    active.session.kv_swap_out();
                    swapped += 1;
                }
            }
            pool.over_budget() // keep walking only while still over
        });
        if swapped > 0 {
            self.metrics.kv_preemptions.inc();
        }
    }

    /// First dispatch of a request: route, resolve the image, prefill
    /// (cache-aware), emit the free token.
    fn admit(&self, job: Job) {
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1000.0;
        let started = Instant::now();
        self.metrics.inflight.add(1);
        if job.cancelled() {
            self.finalize(job, queue_ms, started, 0, GenStats::default(), Some("cancelled"));
            return;
        }
        if job.deadline_exceeded() {
            self.finalize(job, queue_ms, started, 0, GenStats::default(), Some("deadline"));
            return;
        }
        let parts = match self.make_session(&job.req) {
            Ok(x) => x,
            Err(e) => {
                log::error!("request {} failed: {e:#}", job.req.id);
                self.finalize_failure(job, queue_ms, started, 1, GenStats::default(), format!("{e:#}"));
                return;
            }
        };
        let Some(image_id) = job.image_id else {
            let err = "request carries neither image pixels nor image_id".to_string();
            log::error!("request {} failed: {err}", job.req.id);
            self.finalize_failure(job, queue_ms, started, 1, GenStats::default(), err);
            return;
        };
        // keep the pixel store warm for image_id-only follow-ups (an LRU
        // touch when the content is already there)
        if !job.req.image.is_empty() {
            self.cache.put_image_hashed(image_id, &job.req.image);
        }
        let SessionParts { mut session, target, drafter, prompt_ids, len, drafter_key } = parts;
        let key = PrefixKey {
            target: target.name().to_string(),
            drafter: drafter_key,
            image: image_id,
            prompt: prompt_ids[..len].to_vec(),
        };
        match self.prefill_with_cache(&mut session, &target, &key, &job, &prompt_ids, len) {
            Err(e) => {
                log::error!("request {} failed in prefill: {e:#}", job.req.id);
                self.finalize_failure(job, queue_ms, started, 1, GenStats::default(), format!("{e:#}"));
            }
            Ok(StepOutcome::Finished(stats)) => {
                let model_key = model_key(&target, &drafter);
                let target_key: Arc<str> = target.name().into();
                let active = Active {
                    job,
                    session,
                    started,
                    queue_ms,
                    streamed: 0,
                    steps: 1,
                    target,
                    drafter,
                    model_key,
                    target_key,
                };
                self.flush_and_finalize(active, stats, None);
            }
            Ok(StepOutcome::Emitted(tokens)) => {
                // decode iterations start after this point: route their
                // accept/reject telemetry to the calibrator, keyed by the
                // request's workload class
                if let Some(cal) = &self.calibrator {
                    let reuse = session.stats().prefill_cache_hit;
                    session.set_telemetry(cal.clone(), &job.req.task, reuse);
                }
                let model_key = model_key(&target, &drafter);
                let target_key: Arc<str> = target.name().into();
                let mut active = Box::new(Active {
                    job,
                    session,
                    started,
                    queue_ms,
                    streamed: 0,
                    steps: 1,
                    target,
                    drafter,
                    model_key,
                    target_key,
                });
                self.send_chunk(&mut active, &tokens);
                match self.policy {
                    SchedPolicy::Continuous => self.requeue_step(active),
                    SchedPolicy::RunToCompletion => {
                        let mut cur = active;
                        while let Some(next) = self.step_once(cur) {
                            cur = next;
                        }
                    }
                }
            }
        }
    }

    /// One decode iteration of an in-flight session.  Returns the session
    /// if it should be scheduled again, None if it terminated.
    fn step_once(&self, mut active: Box<Active>) -> Option<Box<Active>> {
        if active.job.cancelled() {
            let stats = active.session.abort();
            self.flush_and_finalize(*active, stats, Some("cancelled"));
            return None;
        }
        if active.job.deadline_exceeded() {
            let stats = active.session.abort();
            self.flush_and_finalize(*active, stats, Some("deadline"));
            return None;
        }
        // a session preempted while queued resumes here, bit-exactly
        active.session.kv_swap_in();
        active.steps += 1;
        self.drive_step(active)
    }

    /// Run one fused `session.step()` and conclude it (the pre-batching
    /// single-lane path; liveness checks and step accounting already done).
    fn drive_step(&self, mut active: Box<Active>) -> Option<Box<Active>> {
        let outcome = active.session.step();
        self.conclude(active, outcome)
    }

    /// Put a still-running session back in the queue for its next turn
    /// (under its tenant, so fair-share applies per step, not just at
    /// admission).
    fn requeue_step(&self, active: Box<Active>) {
        let prio = active.job.req.priority;
        let tenant = active.job.req.tenant.clone();
        self.sched.requeue_for(&tenant, Work::Step(active), prio);
    }

    /// `conclude` plus the requeue of a still-running lane (the shared
    /// tail of every batched-absorb arm).
    fn conclude_and_requeue(&self, active: Box<Active>, outcome: Result<StepOutcome>) {
        if let Some(active) = self.conclude(active, outcome) {
            self.requeue_step(active);
        }
    }

    /// Shared step epilogue: deliver/emit/finalize one step outcome.
    /// Returns the session if it should be scheduled again.
    fn conclude(&self, mut active: Box<Active>, outcome: Result<StepOutcome>) -> Option<Box<Active>> {
        match outcome {
            Err(e) => {
                self.fail_step(active, e);
                None
            }
            Ok(StepOutcome::Emitted(tokens)) => {
                self.send_chunk(&mut active, &tokens);
                Some(active)
            }
            Ok(StepOutcome::Finished(stats)) => {
                self.flush_and_finalize(*active, stats, None);
                None
            }
        }
    }

    /// Terminal path for a step that errored (sequential or mid-batch):
    /// deliver the partial output -- flush the unstreamed tail so the
    /// chunk-concatenation invariant holds even for errors -- then run the
    /// full failure accounting (queue/tpot/latency samples included).
    fn fail_step(&self, mut active: Box<Active>, e: anyhow::Error) {
        log::error!("request {} failed mid-decode: {e:#}", active.job.req.id);
        let stats = active.session.abort();
        if active.streamed < stats.tokens.len() {
            self.send_tail(&active.job, &stats.tokens[active.streamed..]);
        }
        let Active { job, queue_ms, started, steps, .. } = *active;
        self.finalize_failure(job, queue_ms, started, steps, stats, format!("{e:#}"));
    }

    /// One fused batched tick over a gang of compatible lanes: liveness
    /// checks, then every lane's `propose`, then ONE batched drafter pass,
    /// then ONE batched target pass, then per-lane `absorb_*`.  Per-lane
    /// failures drop only that lane (with full metric accounting); the
    /// rest of the gang proceeds.  Sampling state is per-session, so this
    /// tick is bit-identical to stepping each lane sequentially.
    fn step_batch(&self, batch: Vec<Box<Active>>) {
        // phase 0: drop dead lanes before any model work
        let mut group: Vec<Box<Active>> = Vec::with_capacity(batch.len());
        for mut active in batch {
            if active.job.cancelled() {
                let stats = active.session.abort();
                self.flush_and_finalize(*active, stats, Some("cancelled"));
            } else if active.job.deadline_exceeded() {
                let stats = active.session.abort();
                self.flush_and_finalize(*active, stats, Some("deadline"));
            } else {
                active.session.kv_swap_in();
                active.steps += 1;
                group.push(active);
            }
        }
        if group.len() <= 1 {
            // single-lane ticks fall back to the existing per-step path
            if let Some(active) = group.pop() {
                if let Some(active) = self.drive_step(active) {
                    self.requeue_step(active);
                }
            }
            return;
        }
        let kind = group[0].session.lane_kind();
        self.metrics.batch_ticks.inc();
        self.metrics.batched_lane_steps.add(group.len() as u64);
        self.metrics.batch_occupancy_peak.max_with(group.len() as i64);

        // phase 1: stage every lane (draws per-lane draft seeds)
        let mut survivors: Vec<Box<Active>> = Vec::with_capacity(group.len());
        for mut active in group {
            match active.session.propose() {
                Ok(_) => survivors.push(active),
                Err(e) => self.fail_step(active, e),
            }
        }
        // phase 2: one fused drafter pass (chain/tree lanes only)
        let survivors = self.batched_draft(kind, survivors);
        // phase 3: one fused target pass, then per-lane absorb + epilogue
        self.batched_verify_and_absorb(kind, survivors);
    }

    /// Fused drafter pass for a staged gang; scatters outputs back into
    /// the sessions.  Returns the lanes still alive.
    fn batched_draft(&self, kind: LaneKind, mut lanes: Vec<Box<Active>>) -> Vec<Box<Active>> {
        if kind == LaneKind::Plain || lanes.is_empty() {
            return lanes;
        }
        let drafter = lanes[0]
            .drafter
            .clone()
            .expect("speculative lanes always carry a drafter handle");
        let t0 = Instant::now();
        match kind {
            LaneKind::Chain => {
                let results = {
                    let mut dl: Vec<(&mut SeqState, i32, f32, u32)> =
                        Vec::with_capacity(lanes.len());
                    for a in lanes.iter_mut() {
                        dl.push(
                            a.session
                                .chain_draft_parts()
                                .expect("staged chain lane must expose draft parts"),
                        );
                    }
                    drafter.draft_batch(&mut dl)
                };
                let shares = time_shares(t0.elapsed().as_micros() as u64, lanes.len());
                let mut alive = Vec::with_capacity(lanes.len());
                for ((mut a, res), share) in lanes.into_iter().zip(results).zip(shares) {
                    a.session.add_decode_micros(share);
                    match res.and_then(|out| a.session.supply_draft(out)) {
                        Ok(()) => alive.push(a),
                        Err(e) => self.fail_step(a, e),
                    }
                }
                alive
            }
            LaneKind::Tree => {
                let results = {
                    let mut dl: Vec<(
                        &mut SeqState,
                        i32,
                        &crate::spec::TreeConfig,
                        f32,
                        u32,
                    )> = Vec::with_capacity(lanes.len());
                    for a in lanes.iter_mut() {
                        dl.push(
                            a.session
                                .tree_draft_parts()
                                .expect("staged tree lane must expose draft parts"),
                        );
                    }
                    drafter.draft_tree_batch(&mut dl)
                };
                let shares = time_shares(t0.elapsed().as_micros() as u64, lanes.len());
                let mut alive = Vec::with_capacity(lanes.len());
                for ((mut a, res), share) in lanes.into_iter().zip(results).zip(shares) {
                    a.session.add_decode_micros(share);
                    match res.and_then(|tree| a.session.supply_draft_tree(tree)) {
                        Ok(()) => alive.push(a),
                        Err(e) => self.fail_step(a, e),
                    }
                }
                alive
            }
            LaneKind::Plain => unreachable!(),
        }
    }

    /// Fused target pass for a staged gang, then per-lane absorb and the
    /// shared epilogue (chunk delivery, requeue, finalize).
    fn batched_verify_and_absorb(&self, kind: LaneKind, mut lanes: Vec<Box<Active>>) {
        if lanes.is_empty() {
            return;
        }
        let target = lanes[0].target.clone();
        let t0 = Instant::now();
        match kind {
            LaneKind::Plain => {
                let results = {
                    let mut vl: Vec<(&mut SeqState, i32)> = Vec::with_capacity(lanes.len());
                    for a in lanes.iter_mut() {
                        vl.push(
                            a.session
                                .plain_verify_parts()
                                .expect("staged plain lane must expose verify parts"),
                        );
                    }
                    target.decode_batch(&mut vl)
                };
                let shares = time_shares(t0.elapsed().as_micros() as u64, lanes.len());
                for ((mut a, res), share) in lanes.into_iter().zip(results).zip(shares) {
                    a.session.add_decode_micros(share);
                    let outcome = res.and_then(|logits| a.session.absorb_decode(logits));
                    self.conclude_and_requeue(a, outcome);
                }
            }
            LaneKind::Chain => {
                let results = {
                    let mut vl: Vec<(&mut SeqState, &[i32])> = Vec::with_capacity(lanes.len());
                    for a in lanes.iter_mut() {
                        vl.push(
                            a.session
                                .chain_verify_parts()
                                .expect("staged chain lane must expose verify parts"),
                        );
                    }
                    target.verify_batch(&mut vl)
                };
                let shares = time_shares(t0.elapsed().as_micros() as u64, lanes.len());
                for ((mut a, res), share) in lanes.into_iter().zip(results).zip(shares) {
                    a.session.add_decode_micros(share);
                    let outcome = res.and_then(|plogits| a.session.absorb_verify(plogits));
                    self.conclude_and_requeue(a, outcome);
                }
            }
            LaneKind::Tree => {
                let gamma = lanes[0].session.gamma();
                let results = {
                    let mut vl: Vec<(&mut SeqState, i32, &crate::spec::DraftTree)> =
                        Vec::with_capacity(lanes.len());
                    for a in lanes.iter_mut() {
                        vl.push(
                            a.session
                                .tree_verify_parts()
                                .expect("staged tree lane must expose verify parts"),
                        );
                    }
                    target.verify_tree_batch(&mut vl, gamma)
                };
                let shares = time_shares(t0.elapsed().as_micros() as u64, lanes.len());
                for ((mut a, res), share) in lanes.into_iter().zip(results).zip(shares) {
                    a.session.add_decode_micros(share);
                    let outcome = res.and_then(|plogits| a.session.absorb_verify(plogits));
                    self.conclude_and_requeue(a, outcome);
                }
            }
        }
    }

    /// Resolve the route and build a decode session for one request.
    fn make_session(&self, req: &Request) -> Result<SessionParts> {
        let route = self
            .router
            .route(req, &self.models.manifest)
            .map_err(|e| anyhow::anyhow!(e))?;
        let target = self.models.target(&route.target)?;
        let (prompt_ids, len) =
            self.tokenizer.encode_prompt(&req.prompt, self.models.manifest.p_max)?;
        let params = SpecParams::from_manifest(&self.models.manifest);

        let (drafter, mut start, adaptive) = match (&req.mode, &route.drafter) {
            (DecodeMode::TargetOnly, _) | (_, None) => (None, None, None),
            (DecodeMode::Speculative { adaptive, .. }, Some((dname, variant))) => (
                Some(self.models.drafter(dname, variant)?),
                Some(SpecMode::Chain),
                if *adaptive { Some(AdaptiveConfig::default()) } else { None },
            ),
            (DecodeMode::Tree { adaptive, .. }, Some((dname, variant))) => (
                Some(self.models.drafter(dname, variant)?),
                Some(SpecMode::Tree),
                if *adaptive { Some(AdaptiveConfig::default()) } else { None },
            ),
        };
        // a warmed calibrator class overrides the request's starting
        // drafting mode (chain<->tree steering; lossless -- acceptance
        // depends only on target logits).  Target-only requests are never
        // upgraded: they asked for no drafter at all.
        if let (Some(cal), Some(_)) = (&self.calibrator, &start) {
            if let Some(mode) = cal.mode_for(&req.task) {
                start = Some(mode);
            }
        }
        // drafter vision compression: request override, then engine
        // config, then manifest default; clamp to >= 1
        let vision_ratio = req
            .draft_vision_ratio
            .filter(|r| *r > 0)
            .unwrap_or(if self.draft_vision_ratio > 0 {
                self.draft_vision_ratio
            } else {
                self.models.manifest.draft_vision_ratio
            })
            .max(1);
        // the prefix-cache key must pin everything that shapes the
        // post-prefill state: the drafter identity (incl. text-only
        // drafting, and the vision ratio its prefill KV was built over)
        // but NOT sampling config or the adaptive flag, which only act
        // after prefill
        let drafter_key = match (&drafter, &route.drafter) {
            (Some(_), Some((dname, variant))) => {
                Some((dname.clone(), variant.clone(), route.text_only_draft, vision_ratio))
            }
            _ => None,
        };
        let mut session = DecodeSession::new(
            target.clone(),
            drafter.clone(),
            params,
            req.gen.clone(),
            start,
            adaptive,
            route.text_only_draft,
        );
        session.set_draft_vision_ratio(vision_ratio);
        if let Some(pool) = &self.kv_pool {
            session.set_kv_pool(pool.clone());
        }
        Ok(SessionParts { session, target, drafter, prompt_ids, len, drafter_key })
    }

    /// Resolve request pixels for a cold encode: inline pixels are served
    /// (and registered) from the store; id-only requests must hit it.
    /// Only called when the encode itself must run -- prefix hits and
    /// cached encodings never need pixels, so an id-only request survives
    /// pixel eviction as long as its downstream cache lines are warm.
    fn resolve_pixels(&self, job: &Job, image_id: u64) -> Result<Arc<Vec<f32>>> {
        if !job.req.image.is_empty() {
            return Ok(self.cache.put_image_hashed(image_id, &job.req.image));
        }
        self.cache.get_image(image_id).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown image_id {} (never sent to this server or evicted; \
                 resend the pixels)",
                cache::format_image_id(image_id)
            )
        })
    }

    /// Cache-aware prefill: fork a cached prefix on hit; on miss run the
    /// cold prefill under single-flight (the image encode is itself
    /// single-flighted and shared across prompts) and fill the cache.
    /// Pixels are only touched when the encode actually runs.
    fn prefill_with_cache(
        &self,
        session: &mut DecodeSession,
        target: &TargetModel,
        key: &PrefixKey,
        job: &Job,
        prompt_ids: &[i32],
        len: usize,
    ) -> Result<StepOutcome> {
        match PrefixCache::prefix(&self.cache, key) {
            PrefixLookup::Hit(snap) => session.prefill_from(&snap),
            PrefixLookup::Fill(fill) => {
                let mut encode_us = 0u64;
                let (enc, _hit) = self.cache.encoding(key.image, || {
                    let pixels = self.resolve_pixels(job, key.image)?;
                    let t0 = Instant::now();
                    let enc = target.encode_image(&pixels)?;
                    encode_us = t0.elapsed().as_micros() as u64;
                    // share the pixel Arc we already hold instead of the
                    // copy the raw-encode fallback made, so the encodings
                    // table never stores a second pixel buffer
                    Ok(match enc {
                        VisionEncoding::Raw(_) => VisionEncoding::Raw(pixels),
                        other => other,
                    })
                })?;
                let out = session.prefill_encoded(&enc, prompt_ids, len, encode_us)?;
                // the snapshot is taken before any decode step; a session
                // that finished at prefill (EOS as the free token) still
                // exports a valid prefix
                if let Some(snap) = session.export_prefix() {
                    fill.fill(Arc::new(snap));
                }
                Ok(out)
            }
        }
    }

    /// Deliver newly emitted tokens to a streaming client.  A dropped
    /// receiver means the client went away: flag the session cancelled so
    /// the next dispatch drops it.
    fn send_chunk(&self, active: &mut Active, tokens: &[i32]) {
        if tokens.is_empty() {
            return;
        }
        active.streamed += tokens.len();
        if let Reply::Stream(tx) = &active.job.reply {
            if tx.send(Update::Chunk(tokens.to_vec())).is_err() {
                active.job.cancel.store(true, Ordering::Relaxed);
            }
        }
    }

    /// Flush any not-yet-streamed tail of `stats.tokens` (the terminal
    /// iteration's tokens, or everything generated before an abort), then
    /// finalize.  `reason` overrides the natural eos/length finish reason.
    fn flush_and_finalize(&self, active: Active, stats: GenStats, reason: Option<&str>) {
        if active.streamed < stats.tokens.len() {
            self.send_tail(&active.job, &stats.tokens[active.streamed..]);
        }
        let Active { job, queue_ms, started, steps, .. } = active;
        self.finalize(job, queue_ms, started, steps, stats, reason);
    }

    /// Terminal chunk delivery (no bookkeeping: the session is ending).
    fn send_tail(&self, job: &Job, tokens: &[i32]) {
        if tokens.is_empty() {
            return;
        }
        if let Reply::Stream(tx) = &job.reply {
            let _ = tx.send(Update::Chunk(tokens.to_vec()));
        }
    }

    /// Aggregate counters every terminal outcome contributes -- success,
    /// cancel/deadline, or failure with partial progress: generated
    /// tokens, model-call counts, and the MAL/tree accounting they feed.
    /// Shared between `finalize` and `finalize_failure` so the two paths
    /// cannot drift.
    fn record_terminal_stats(&self, stats: &GenStats) {
        let m = &self.metrics;
        m.tokens_generated.add(stats.tokens.len() as u64);
        m.verify_calls.add(stats.verify_calls as u64);
        m.draft_calls.add(stats.draft_calls as u64);
        m.draft_tokens_accepted.add(stats.accepted_draft as u64);
        if stats.verify_calls > 0 && stats.draft_calls > 0 {
            m.per_request_mal.record(stats.mal());
        }
        if stats.tree_iters > 0 {
            m.tree_requests.inc();
            m.tree_nodes_drafted.add(stats.tree_nodes_drafted as u64);
            m.tree_iterations.add(stats.tree_iters as u64);
            m.tree_path_accepted.add(stats.path_depth_sum as u64);
        }
    }

    /// Terminal path for errors (routing, prefill, or mid-decode).  The
    /// partial output generated before the error is still delivered in the
    /// failure response, keeping streamed chunks consistent with `tokens`.
    #[allow(clippy::too_many_arguments)]
    fn finalize_failure(
        &self,
        job: Job,
        queue_ms: f64,
        started: Instant,
        steps: usize,
        stats: GenStats,
        err: String,
    ) {
        self.metrics.inflight.add(-1);
        self.cancels.lock().unwrap().remove(&job.req.id);
        self.metrics.requests_failed.inc();
        let tc = self.metrics.tenant(&job.req.tenant);
        tc.failed.inc();
        tc.tokens.add(stats.tokens.len() as u64);
        let latency_ms = started.elapsed().as_secs_f64() * 1000.0;
        self.metrics.queue_ms.record(queue_ms);
        self.metrics.latency_ms.record(latency_ms);
        self.metrics.steps_per_request.record(steps as f64);
        // failed requests that actually ran a prefill are terminal
        // outcomes too: keep the prefill/tpot histograms consistent with
        // the success path (routing failures have prefill_micros == 0 and
        // are skipped, same as never-admitted requests)
        if stats.prefill_micros > 0 {
            self.metrics.prefill_ms.record(stats.prefill_micros as f64 / 1000.0);
            self.metrics.prefill_encode_ms.record(stats.encode_micros as f64 / 1000.0);
            self.metrics.prefill_text_ms.record(
                stats.prefill_micros.saturating_sub(stats.encode_micros) as f64 / 1000.0,
            );
        }
        if stats.tokens.len() > 1 {
            let decode_ms = stats.decode_micros as f64 / 1000.0;
            self.metrics.tpot_ms.record(decode_ms / (stats.tokens.len() - 1) as f64);
        }
        // partial progress before the error is real serving work: keep the
        // aggregate token/call counters (and the MAL/tree accounting they
        // feed) consistent with the success path, so a session that dies
        // mid-batch after N tokens still shows up in throughput and MAL
        self.record_terminal_stats(&stats);
        let mut resp = Response::failure(job.req.id, err);
        resp.text = decode_text(&self.tokenizer, &stats.tokens, self.models.manifest.eos_id);
        resp.tokens = stats.tokens;
        resp.queue_ms = queue_ms;
        resp.latency_ms = latency_ms;
        resp.steps = steps;
        resp.image_id = job.image_id.map(cache::format_image_id).unwrap_or_default();
        resp.cache_hit = stats.prefill_cache_hit;
        resp.prefill_ms = stats.prefill_micros as f64 / 1000.0;
        send_final(&job.reply, resp);
    }

    /// Common terminal accounting + response construction.
    fn finalize(
        &self,
        job: Job,
        queue_ms: f64,
        started: Instant,
        steps: usize,
        stats: GenStats,
        reason_override: Option<&str>,
    ) {
        self.metrics.inflight.add(-1);
        self.cancels.lock().unwrap().remove(&job.req.id);
        let m = &self.metrics;
        let finish_reason = match reason_override {
            Some(r) => r.to_string(),
            None if stats.finished_by_eos => "eos".to_string(),
            None => "length".to_string(),
        };
        let tc = m.tenant(&job.req.tenant);
        match finish_reason.as_str() {
            "cancelled" => {
                m.requests_cancelled.inc();
                tc.cancelled.inc();
            }
            "deadline" => {
                m.requests_deadline_exceeded.inc();
                tc.deadline.inc();
            }
            _ => {
                m.requests_completed.inc();
                tc.completed.inc();
            }
        }
        tc.tokens.add(stats.tokens.len() as u64);
        self.record_terminal_stats(&stats);
        if steps > 0 {
            // requests dropped before admission never ran prefill; a 0.0
            // sample would drag the histogram toward zero
            m.prefill_ms.record(stats.prefill_micros as f64 / 1000.0);
            // prefill-time split: image encode vs prompt/KV build
            m.prefill_encode_ms.record(stats.encode_micros as f64 / 1000.0);
            m.prefill_text_ms
                .record(stats.prefill_micros.saturating_sub(stats.encode_micros) as f64 / 1000.0);
        }
        let latency_ms = started.elapsed().as_secs_f64() * 1000.0;
        m.latency_ms.record(latency_ms);
        m.queue_ms.record(queue_ms);
        m.steps_per_request.record(steps as f64);
        if stats.tokens.len() > 1 {
            let decode_ms = stats.decode_micros as f64 / 1000.0;
            m.tpot_ms.record(decode_ms / (stats.tokens.len() - 1) as f64);
        }
        let text = decode_text(&self.tokenizer, &stats.tokens, self.models.manifest.eos_id);
        let resp = Response {
            id: job.req.id,
            text,
            mal: if stats.draft_calls > 0 { stats.mal() } else { 0.0 },
            verify_calls: stats.verify_calls,
            accepted_draft: stats.accepted_draft,
            mean_path_depth: stats.mean_path_depth(),
            tree_nodes_drafted: stats.tree_nodes_drafted,
            finished_by_eos: stats.finished_by_eos,
            steps,
            finish_reason,
            tokens: stats.tokens,
            queue_ms,
            latency_ms,
            image_id: job.image_id.map(cache::format_image_id).unwrap_or_default(),
            cache_hit: stats.prefill_cache_hit,
            prefill_ms: stats.prefill_micros as f64 / 1000.0,
            error: None,
        };
        send_final(&job.reply, resp);
    }
}

/// Decode tokens to text, stripping only a *trailing* terminator: a
/// legitimate mid-stream token equal to eos_id must survive into the text
/// (the old path filtered every occurrence, which would silently corrupt
/// such outputs).
fn decode_text(tokenizer: &Tokenizer, tokens: &[i32], eos_id: i32) -> String {
    let visible = match tokens.split_last() {
        Some((&t, head)) if t == eos_id => head,
        _ => tokens,
    };
    tokenizer.decode(&visible.iter().map(|&t| t as u32).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::scripted;

    fn test_worker(dir: &str) -> Worker {
        let models = ModelSet::load(dir).unwrap();
        let metrics = Arc::new(Metrics::new());
        Worker {
            tokenizer: Arc::new(Tokenizer::load(dir).unwrap()),
            cache: PrefixCache::new(1 << 20, metrics.clone()),
            kv_pool: Some(KvPool::with_metrics(
                KvPoolConfig::default(),
                Some(metrics.clone()),
            )),
            metrics,
            models,
            sched: Arc::new(Scheduler::new(16)),
            router: Arc::new(Router::new("qwensim-L".to_string())),
            calibrator: None,
            cancels: Arc::new(Mutex::new(HashMap::new())),
            policy: SchedPolicy::Continuous,
            max_batch: 8,
            workers: 1,
            draft_vision_ratio: 0,
        }
    }

    /// The mid-batch failure path must leave the SAME metric samples a
    /// successful terminal leaves: queue/latency/tpot/steps histograms plus
    /// the aggregate token/call counters for the partial progress (the old
    /// path dropped the counters, so a session that died after N tokens
    /// vanished from throughput and MAL).
    #[test]
    fn failure_path_records_full_metrics_for_partial_progress() {
        let dir = scripted::write_test_artifacts("engine_fail_metrics", 48, false);
        let w = test_worker(&dir);
        let (tx, rx) = mpsc::channel();
        let id = 7u64;
        let job = Job {
            req: Request::simple(id, "w5 w6", scripted::demo_image(0)),
            enqueued: Instant::now(),
            reply: Reply::Oneshot(tx),
            cancel: Arc::new(AtomicBool::new(false)),
            image_id: Some(1),
        };
        w.cancels.lock().unwrap().insert(id, job.cancel.clone());
        w.metrics.inflight.add(1);
        let stats = GenStats {
            tokens: vec![5, 6, 7, 8],
            verify_calls: 3,
            draft_calls: 3,
            accepted_draft: 1,
            iters: 3,
            emitted_sum: 4,
            emitted_max: 2,
            prefill_micros: 900,
            decode_micros: 3000,
            ..GenStats::default()
        };
        w.finalize_failure(
            job,
            2.5,
            Instant::now(),
            4,
            stats,
            "injected mid-batch failure".into(),
        );
        let resp = rx.recv().unwrap();
        assert_eq!(resp.finish_reason, "error");
        assert_eq!(resp.tokens, vec![5, 6, 7, 8], "partial output must be delivered");
        assert!(resp.error.unwrap().contains("injected"));
        assert_eq!(resp.steps, 4);
        let m = &w.metrics;
        assert_eq!(m.queue_ms.count(), 1, "queue_ms sample must be recorded");
        assert_eq!(m.latency_ms.count(), 1);
        assert_eq!(m.tpot_ms.count(), 1, "tpot_ms sample must be recorded");
        assert_eq!(m.steps_per_request.count(), 1);
        assert_eq!(m.prefill_ms.count(), 1);
        assert_eq!(m.tokens_generated.get(), 4, "partial tokens count toward throughput");
        assert_eq!(m.verify_calls.get(), 3);
        assert_eq!(m.draft_calls.get(), 3);
        assert_eq!(m.draft_tokens_accepted.get(), 1);
        assert_eq!(m.per_request_mal.count(), 1, "partial MAL must be recorded");
        assert_eq!(m.inflight.get(), 0, "session must be freed");
        assert_eq!(m.requests_failed.get(), 1);
        assert!(w.cancels.lock().unwrap().is_empty(), "cancel registry must be cleaned");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn time_shares_sum_to_total_without_truncation() {
        let s: Vec<u64> = time_shares(10, 4).collect();
        assert_eq!(s, vec![3, 3, 2, 2]);
        assert_eq!(time_shares(3, 8).sum::<u64>(), 3, "sub-lane totals must not vanish");
        assert_eq!(time_shares(0, 3).sum::<u64>(), 0);
        assert_eq!(time_shares(7, 1).sum::<u64>(), 7);
    }

    /// Routing-level failures (no prefill ran) keep the pre-existing
    /// skip rules: no prefill/tpot samples, zero counters.
    #[test]
    fn failure_path_without_progress_skips_model_histograms() {
        let dir = scripted::write_test_artifacts("engine_fail_empty", 48, false);
        let w = test_worker(&dir);
        let (tx, rx) = mpsc::channel();
        let job = Job {
            req: Request::simple(9, "w5", scripted::demo_image(1)),
            enqueued: Instant::now(),
            reply: Reply::Oneshot(tx),
            cancel: Arc::new(AtomicBool::new(false)),
            image_id: Some(2),
        };
        w.metrics.inflight.add(1);
        w.finalize_failure(job, 0.5, Instant::now(), 1, GenStats::default(), "no route".into());
        let resp = rx.recv().unwrap();
        assert!(resp.tokens.is_empty());
        let m = &w.metrics;
        assert_eq!(m.queue_ms.count(), 1);
        assert_eq!(m.prefill_ms.count(), 0, "no prefill ran -> no prefill sample");
        assert_eq!(m.tpot_ms.count(), 0, "a single token cannot yield a tpot sample");
        assert_eq!(m.tokens_generated.get(), 0);
        assert_eq!(m.per_request_mal.count(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
