//! The serving engine: a worker pool draining the scheduler, running
//! speculative decoding against shared compiled executables.
//!
//! PJRT CPU executables are batch-1 (DESIGN.md section 3), so continuous
//! batching happens at *request* granularity: N workers keep N sequences
//! in flight, sharing the compiled target/drafter executables (which the
//! TFRT CPU runtime executes concurrently on its own thread pool).  The
//! scheduler provides the two-priority admission-controlled queue in
//! front; the router picks the (target, drafter) pair per request.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::request::{DecodeMode, Request, Response};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{Scheduler, Submit};
use crate::metrics::Metrics;
use crate::models::ModelSet;
use crate::spec::{AdaptiveConfig, AdaptiveDecoder, GenStats, SpecDecoder, SpecMode};
use crate::tokenizer::Tokenizer;

pub struct EngineConfig {
    pub default_target: String,
    pub workers: usize,
    pub queue_capacity: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 4,
            queue_capacity: 256,
        }
    }
}

struct Job {
    req: Request,
    enqueued: Instant,
    reply: mpsc::Sender<Response>,
}

pub struct Engine {
    pub models: Arc<ModelSet>,
    pub tokenizer: Arc<Tokenizer>,
    pub metrics: Arc<Metrics>,
    sched: Arc<Scheduler<Job>>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Engine {
    pub fn start(artifacts_dir: &str, cfg: EngineConfig) -> Result<Engine> {
        let models = ModelSet::load(artifacts_dir)?;
        let tokenizer = Arc::new(Tokenizer::load(artifacts_dir)?);
        let metrics = Arc::new(Metrics::new());
        let sched = Arc::new(Scheduler::new(cfg.queue_capacity));
        let router = Arc::new(Router::new(cfg.default_target.clone()));

        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let models = models.clone();
            let tokenizer = tokenizer.clone();
            let metrics = metrics.clone();
            let sched = sched.clone();
            let router = router.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("massv-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(&models, &tokenizer, &metrics, &sched, &router)
                    })?,
            );
        }
        Ok(Engine {
            models,
            tokenizer,
            metrics,
            sched,
            workers,
            next_id: AtomicU64::new(1),
        })
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a request; the response arrives on the returned channel.
    /// Backpressure: a full queue yields an immediate rejected Response.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.metrics.requests_received.inc();
        let id = req.id;
        let priority = req.priority;
        let job = Job { req, enqueued: Instant::now(), reply: tx.clone() };
        match self.sched.submit(job, priority) {
            Submit::Accepted => {
                self.metrics.queue_depth.set(self.sched.len() as i64);
            }
            Submit::Rejected => {
                self.metrics.requests_rejected.inc();
                let _ = tx.send(Response::failure(id, "queue full (backpressure)".into()));
            }
        }
        rx
    }

    /// Submit and wait (convenience for examples/benches).
    pub fn run(&self, req: Request) -> Response {
        let id = req.id;
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Response::failure(id, "engine shut down".into()))
    }

    /// Graceful shutdown: drain the queue, then join workers.
    pub fn shutdown(mut self) {
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    models: &Arc<ModelSet>,
    tokenizer: &Tokenizer,
    metrics: &Arc<Metrics>,
    sched: &Arc<Scheduler<Job>>,
    router: &Router,
) {
    while let Some(job) = sched.pop() {
        metrics.queue_depth.set(sched.len() as i64);
        metrics.inflight.add(1);
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1000.0;
        let t0 = Instant::now();
        let resp = match run_request(models, tokenizer, router, &job.req) {
            Ok(stats) => {
                let text = tokenizer.decode(
                    &stats
                        .tokens
                        .iter()
                        .filter(|&&t| t != models.manifest.eos_id)
                        .map(|&t| t as u32)
                        .collect::<Vec<_>>(),
                );
                metrics.requests_completed.inc();
                metrics.tokens_generated.add(stats.tokens.len() as u64);
                metrics.verify_calls.add(stats.verify_calls as u64);
                metrics.draft_calls.add(stats.draft_calls as u64);
                metrics.draft_tokens_accepted.add(stats.accepted_draft as u64);
                metrics.prefill_ms.record(stats.prefill_micros as f64 / 1000.0);
                if stats.verify_calls > 0 && stats.draft_calls > 0 {
                    metrics.per_request_mal.record(stats.mal());
                }
                if !stats.per_iter_path_depth.is_empty() {
                    metrics.tree_requests.inc();
                    metrics.tree_nodes_drafted.add(stats.tree_nodes_drafted as u64);
                    metrics
                        .tree_iterations
                        .add(stats.per_iter_path_depth.len() as u64);
                    metrics
                        .tree_path_accepted
                        .add(stats.per_iter_path_depth.iter().sum::<usize>() as u64);
                }
                let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
                metrics.latency_ms.record(latency_ms);
                Response {
                    id: job.req.id,
                    text,
                    mal: if stats.draft_calls > 0 { stats.mal() } else { 0.0 },
                    verify_calls: stats.verify_calls,
                    accepted_draft: stats.accepted_draft,
                    mean_path_depth: stats.mean_path_depth(),
                    tree_nodes_drafted: stats.tree_nodes_drafted,
                    finished_by_eos: stats.finished_by_eos,
                    tokens: stats.tokens,
                    queue_ms,
                    latency_ms,
                    error: None,
                }
            }
            Err(e) => {
                log::error!("request {} failed: {e:#}", job.req.id);
                Response::failure(job.req.id, format!("{e:#}"))
            }
        };
        metrics.inflight.add(-1);
        let _ = job.reply.send(resp);
    }
}

/// Resolve the route and run one request to completion.
fn run_request(
    models: &Arc<ModelSet>,
    tokenizer: &Tokenizer,
    router: &Router,
    req: &Request,
) -> Result<GenStats> {
    let route = router
        .route(req, &models.manifest)
        .map_err(|e| anyhow::anyhow!(e))?;
    let target = models.target(&route.target)?;
    let (prompt_ids, len) = tokenizer.encode_prompt(&req.prompt, models.manifest.p_max)?;

    match (&req.mode, &route.drafter) {
        (DecodeMode::TargetOnly, _) | (_, None) => {
            SpecDecoder::generate_baseline(&target, &req.image, &prompt_ids, len, &req.gen)
        }
        (DecodeMode::Speculative { adaptive, .. }, Some((dname, variant))) => {
            let drafter = models.drafter(dname, variant)?;
            let mut dec = SpecDecoder::new(target, drafter);
            dec.text_only_draft = route.text_only_draft;
            if *adaptive {
                AdaptiveDecoder::new(dec, AdaptiveConfig::default())
                    .generate(&req.image, &prompt_ids, len, &req.gen)
            } else {
                dec.generate(&req.image, &prompt_ids, len, &req.gen)
            }
        }
        (DecodeMode::Tree { adaptive, .. }, Some((dname, variant))) => {
            let drafter = models.drafter(dname, variant)?;
            let mut dec = SpecDecoder::new(target, drafter);
            dec.text_only_draft = route.text_only_draft;
            if *adaptive {
                AdaptiveDecoder::new(dec, AdaptiveConfig::default()).generate_with_mode(
                    SpecMode::Tree,
                    &req.image,
                    &prompt_ids,
                    len,
                    &req.gen,
                )
            } else {
                dec.generate_tree(&req.image, &prompt_ids, len, &req.gen)
            }
        }
    }
}
