//! Request/response types and the request lifecycle FSM.

use crate::spec::GenConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// latency-sensitive (chat-style)
    Interactive,
    /// throughput-oriented (bulk captioning, evals)
    Batch,
}

#[derive(Debug, Clone, PartialEq)]
pub enum DecodeMode {
    /// MASSV speculative decoding with the given drafter variant
    /// ("baseline" | "massv_wo_sdvit" | "massv").  `adaptive` enables the
    /// acceptance-EMA fallback controller (spec::adaptive).
    Speculative { variant: String, text_only_draft: bool, adaptive: bool },
    /// Plain target autoregression (the 1.00x reference).
    TargetOnly,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// task label (metrics bucketing only)
    pub task: String,
    pub prompt: String,
    /// 16x16x3 row-major image; required (targets are multimodal)
    pub image: Vec<f32>,
    /// target model override; empty -> engine default
    pub target: String,
    pub mode: DecodeMode,
    pub gen: GenConfig,
    pub priority: Priority,
}

impl Request {
    pub fn simple(id: u64, prompt: &str, image: Vec<f32>) -> Request {
        Request {
            id,
            task: "adhoc".into(),
            prompt: prompt.into(),
            image,
            target: String::new(),
            mode: DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            },
            gen: GenConfig::default(),
            priority: Priority::Interactive,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    /// mean accepted length for this request (0 for TargetOnly)
    pub mal: f64,
    pub verify_calls: usize,
    pub accepted_draft: usize,
    pub finished_by_eos: bool,
    pub queue_ms: f64,
    pub latency_ms: f64,
    pub error: Option<String>,
}

impl Response {
    pub fn failure(id: u64, err: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: vec![],
            mal: 0.0,
            verify_calls: 0,
            accepted_draft: 0,
            finished_by_eos: false,
            queue_ms: 0.0,
            latency_ms: 0.0,
            error: Some(err),
        }
    }
}

/// Observability lifecycle (the engine tracks transitions per request).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    Queued,
    Running,
    Done,
    Failed,
    Rejected,
}

impl Lifecycle {
    /// Legal transitions of the FSM (property-tested in the scheduler).
    pub fn can_transition(self, next: Lifecycle) -> bool {
        use Lifecycle::*;
        matches!(
            (self, next),
            (Queued, Running) | (Queued, Rejected) | (Running, Done) | (Running, Failed)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_legal_transitions() {
        use Lifecycle::*;
        assert!(Queued.can_transition(Running));
        assert!(Queued.can_transition(Rejected));
        assert!(Running.can_transition(Done));
        assert!(Running.can_transition(Failed));
        assert!(!Done.can_transition(Running));
        assert!(!Rejected.can_transition(Running));
        assert!(!Queued.can_transition(Done));
    }

    #[test]
    fn simple_request_defaults() {
        let r = Request::simple(7, "hi", vec![0.0; 768]);
        assert_eq!(r.priority, Priority::Interactive);
        assert!(matches!(r.mode, DecodeMode::Speculative { .. }));
    }
}
