//! Request/response types and the request lifecycle FSM.

use crate::spec::GenConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    /// latency-sensitive (chat-style)
    Interactive,
    /// throughput-oriented (bulk captioning, evals)
    Batch,
}

#[derive(Debug, Clone, PartialEq)]
pub enum DecodeMode {
    /// MASSV chain speculative decoding with the given drafter variant
    /// ("baseline" | "massv_wo_sdvit" | "massv").  `adaptive` enables the
    /// acceptance-EMA fallback controller (spec::adaptive).
    Speculative { variant: String, text_only_draft: bool, adaptive: bool },
    /// Token-tree speculative decoding (spec::tree): the drafter proposes a
    /// branching candidate tree, verified in one target call with the
    /// longest root-to-leaf path accepted losslessly.  `adaptive` lets the
    /// controller switch tree<->chain per request.
    Tree { variant: String, text_only_draft: bool, adaptive: bool },
    /// Plain target autoregression (the 1.00x reference).
    TargetOnly,
}

impl DecodeMode {
    /// Drafter variant + text-only flag for speculative modes (`None` for
    /// TargetOnly) -- what the router needs to resolve a drafter.
    pub fn drafting(&self) -> Option<(&str, bool)> {
        match self {
            DecodeMode::Speculative { variant, text_only_draft, .. }
            | DecodeMode::Tree { variant, text_only_draft, .. } => {
                Some((variant.as_str(), *text_only_draft))
            }
            DecodeMode::TargetOnly => None,
        }
    }

    pub fn is_tree(&self) -> bool {
        matches!(self, DecodeMode::Tree { .. })
    }

    pub fn wire_name(&self) -> &'static str {
        match self {
            DecodeMode::Speculative { .. } => "speculative",
            DecodeMode::Tree { .. } => "tree",
            DecodeMode::TargetOnly => "target_only",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// task label (metrics bucketing only)
    pub task: String,
    pub prompt: String,
    /// Row-major image pixels (`Manifest::image_shape`); may be empty when
    /// `image_id` references pixels a previous request already sent
    pub image: Vec<f32>,
    /// Content address of a previously sent image (see `crate::cache`);
    /// requests must carry pixels, an id, or both (pixels win)
    pub image_id: Option<u64>,
    /// target model override; empty -> engine default
    pub target: String,
    pub mode: DecodeMode,
    pub gen: GenConfig,
    /// Drafter-side vision token compression ratio override.  Precedence:
    /// this field (Some) > `EngineConfig::draft_vision_ratio` (non-zero) >
    /// manifest default.  Values are clamped to >= 1; the target always
    /// runs at full resolution, so the knob is output-lossless.
    pub draft_vision_ratio: Option<u32>,
    pub priority: Priority,
    /// Per-request deadline in milliseconds, measured from submission.
    /// Checked between decode steps: an expired session is dropped cleanly
    /// and the client gets the partial output with `finish_reason =
    /// "deadline"`.  `None` = no deadline.
    pub deadline_ms: Option<u64>,
    /// Tenant the request is billed to: the weighted-fair scheduler queues
    /// per tenant, and per-tenant counters surface as `tenant_*` scrape
    /// keys.  Comes from the HTTP `x-tenant` header or the wire `tenant`
    /// field; defaults to `scheduler::DEFAULT_TENANT`.
    pub tenant: String,
}

impl Request {
    pub fn simple(id: u64, prompt: &str, image: Vec<f32>) -> Request {
        Request {
            id,
            task: "adhoc".into(),
            prompt: prompt.into(),
            image,
            image_id: None,
            target: String::new(),
            mode: DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            },
            gen: GenConfig::default(),
            draft_vision_ratio: None,
            priority: Priority::Interactive,
            deadline_ms: None,
            tenant: crate::coordinator::scheduler::DEFAULT_TENANT.into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<i32>,
    /// mean accepted length for this request (0 for TargetOnly)
    pub mal: f64,
    pub verify_calls: usize,
    pub accepted_draft: usize,
    /// mean accepted root-to-leaf path length over tree iterations
    /// (0 when the request never ran tree-mode iterations)
    pub mean_path_depth: f64,
    /// candidate tree nodes drafted (0 outside tree mode)
    pub tree_nodes_drafted: usize,
    pub finished_by_eos: bool,
    /// Decode steps (scheduler dispatches) this request consumed, prefill
    /// included -- the unit of interleaving under continuous batching.
    pub steps: usize,
    /// Why the request terminated: "eos" | "length" | "cancelled" |
    /// "deadline" | "rejected" | "error".  Cancelled/deadline responses
    /// still carry the partial output generated so far.
    pub finish_reason: String,
    pub queue_ms: f64,
    pub latency_ms: f64,
    /// Content address of this request's image -- clients reuse it as
    /// `image_id` on follow-up requests to skip resending pixels.  Empty
    /// when the request never resolved an image (e.g. rejected with
    /// neither pixels nor id).
    pub image_id: String,
    /// True when prefill was served from the prefix cache (forked KV
    /// snapshots; no model forward pass ran).
    pub cache_hit: bool,
    /// Prefill wall time in ms (encode + prompt KV build; ~0 on hits).
    pub prefill_ms: f64,
    pub error: Option<String>,
}

impl Response {
    pub fn failure(id: u64, err: String) -> Response {
        Response {
            id,
            text: String::new(),
            tokens: vec![],
            mal: 0.0,
            verify_calls: 0,
            accepted_draft: 0,
            mean_path_depth: 0.0,
            tree_nodes_drafted: 0,
            finished_by_eos: false,
            steps: 0,
            finish_reason: "error".into(),
            queue_ms: 0.0,
            latency_ms: 0.0,
            image_id: String::new(),
            cache_hit: false,
            prefill_ms: 0.0,
            error: Some(err),
        }
    }
}

/// Observability lifecycle (the engine tracks transitions per request).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    Queued,
    Running,
    Done,
    Failed,
    Rejected,
    /// Dropped by client cancellation or deadline expiry (from the queue or
    /// mid-decode); the client still receives the partial output.
    Cancelled,
}

impl Lifecycle {
    /// Legal transitions of the FSM (property-tested in the scheduler).
    pub fn can_transition(self, next: Lifecycle) -> bool {
        use Lifecycle::*;
        matches!(
            (self, next),
            (Queued, Running)
                | (Queued, Rejected)
                | (Queued, Cancelled)
                | (Running, Done)
                | (Running, Failed)
                | (Running, Cancelled)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_legal_transitions() {
        use Lifecycle::*;
        assert!(Queued.can_transition(Running));
        assert!(Queued.can_transition(Rejected));
        assert!(Queued.can_transition(Cancelled));
        assert!(Running.can_transition(Done));
        assert!(Running.can_transition(Failed));
        assert!(Running.can_transition(Cancelled));
        assert!(!Done.can_transition(Running));
        assert!(!Rejected.can_transition(Running));
        assert!(!Queued.can_transition(Done));
        assert!(!Cancelled.can_transition(Running));
    }

    #[test]
    fn simple_request_defaults() {
        let r = Request::simple(7, "hi", vec![0.0; 768]);
        assert_eq!(r.priority, Priority::Interactive);
        assert!(matches!(r.mode, DecodeMode::Speculative { .. }));
    }

    #[test]
    fn decode_mode_drafting_accessor() {
        let spec = DecodeMode::Speculative {
            variant: "massv".into(),
            text_only_draft: false,
            adaptive: false,
        };
        assert_eq!(spec.drafting(), Some(("massv", false)));
        assert!(!spec.is_tree());
        let tree = DecodeMode::Tree {
            variant: "massv".into(),
            text_only_draft: true,
            adaptive: true,
        };
        assert_eq!(tree.drafting(), Some(("massv", true)));
        assert!(tree.is_tree());
        assert_eq!(tree.wire_name(), "tree");
        assert_eq!(DecodeMode::TargetOnly.drafting(), None);
    }
}
