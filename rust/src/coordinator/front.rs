//! `EngineFront`: the server-facing engine surface.
//!
//! The TCP front-end and the wire protocol only need a narrow slice of the
//! engine -- request-id allocation, the artifact manifest for request
//! validation, submit/cancel, and the metrics scrape.  Both the
//! single-replica `Engine` and the multi-replica `cluster::ClusterEngine`
//! implement this trait, so `server::Server` serves either transparently:
//! the `replicas` knob changes topology, never the wire protocol.

use std::collections::HashMap;

use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::stream::UpdateReceiver;
use crate::manifest::Manifest;

pub trait EngineFront: Send + Sync + 'static {
    /// Allocate a request id unique across the whole deployment (all
    /// replicas share one id space, so cancel-by-id is unambiguous).
    fn next_id(&self) -> u64;

    /// The manifest requests are validated against (image shape, models).
    fn manifest(&self) -> &Manifest;

    /// Submit and wait for the final response.
    fn run(&self, req: Request) -> Response;

    /// Submit for streaming delivery: one `Update::Chunk` per decode step,
    /// then `Update::Done` with the summary response.  The channel is
    /// bounded (see `coordinator::stream`): a slow consumer gets coalesced
    /// chunks, never a reordered or truncated token sequence.
    fn submit_streaming(&self, req: Request) -> UpdateReceiver;

    /// Cancel a queued or in-flight request anywhere in the deployment.
    /// Returns true if the id was still live.
    fn cancel(&self, id: u64) -> bool;

    /// Flat metrics snapshot (the wire `metrics` op).
    fn scrape(&self) -> HashMap<String, f64>;

    /// Per-executable call statistics: (entry point, calls, mean micros).
    fn exec_stats(&self) -> Vec<(String, u64, f64)>;
}

impl EngineFront for Engine {
    fn next_id(&self) -> u64 {
        Engine::next_id(self)
    }

    fn manifest(&self) -> &Manifest {
        &self.models.manifest
    }

    fn run(&self, req: Request) -> Response {
        Engine::run(self, req)
    }

    fn submit_streaming(&self, req: Request) -> UpdateReceiver {
        Engine::submit_streaming(self, req)
    }

    fn cancel(&self, id: u64) -> bool {
        Engine::cancel(self, id)
    }

    fn scrape(&self) -> HashMap<String, f64> {
        Engine::scrape(self)
    }

    fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        self.models.exec_stats()
    }
}
