//! Workloads: the four paper task suites (loaded from the fixed eval sets
//! emitted by python/compile/aot.py) plus open- and closed-loop load
//! generation for the serving benchmarks.

use anyhow::{anyhow, Result};

use crate::tokenizer::Tokenizer;
use crate::util::json::parse;
use crate::util::rng::Rng;

pub mod scenario;

/// Paper tasks (Section 4.1): LLaVA-150k, LLaVA-Bench(wild), GQA, COCO
/// analogs -- see DESIGN.md section 2 for the substitution argument.
pub const TASKS: [&str; 4] = ["instruct", "wild", "gqa", "coco"];

#[derive(Debug, Clone)]
pub struct EvalItem {
    pub task: String,
    pub prompt: String,
    pub reference: String,
    /// 16x16x3 row-major f32 image
    pub image: Vec<f32>,
    /// prompt pre-encoded to the padded layout
    pub prompt_ids: Vec<i32>,
    pub prompt_len: usize,
}

pub fn load_task(
    artifacts_dir: &str,
    task: &str,
    tok: &Tokenizer,
    p_max: usize,
) -> Result<Vec<EvalItem>> {
    let text = crate::util::read_file(&format!("{artifacts_dir}/eval/{task}.json"))?;
    let v = parse(&text)?;
    let items = v.req("items")?.as_arr()?;
    items
        .iter()
        .map(|it| {
            let prompt = it.req("prompt")?.as_str()?.to_string();
            let image = it.req("image")?.to_f32_vec()?;
            if image.len() != 16 * 16 * 3 {
                return Err(anyhow!("bad image size {}", image.len()));
            }
            let (prompt_ids, prompt_len) = tok.encode_prompt(&prompt, p_max)?;
            Ok(EvalItem {
                task: task.to_string(),
                reference: it.req("reference")?.as_str()?.to_string(),
                prompt,
                image,
                prompt_ids,
                prompt_len,
            })
        })
        .collect()
}

pub fn load_all_tasks(
    artifacts_dir: &str,
    tok: &Tokenizer,
    p_max: usize,
) -> Result<Vec<(String, Vec<EvalItem>)>> {
    TASKS
        .iter()
        .map(|t| Ok((t.to_string(), load_task(artifacts_dir, t, tok, p_max)?)))
        .collect()
}

/// Workload classes the schedule generators tag arrivals with, mirroring
/// the serving-mix taxonomy (`Request::task` buckets): interactive
/// multi-turn chat, bulk captioning, and document/OCR-style long reads.
/// The acceptance calibrator (`spec::calibrate`) keys its per-class EWMAs
/// on these strings.
pub const CLASSES: [&str; 3] = ["chat", "caption", "doc"];

/// Deterministic per-arrival class stream.  Classes draw from an rng
/// derived from (but distinct from) the schedule seed, so tagging never
/// perturbs the at/item/image sequences existing benches and tests pin.
pub(crate) fn class_rng(seed: u64) -> Rng {
    Rng::seeded(seed ^ 0x9E37_79B9_7F4A_7C15)
}

pub(crate) fn draw_class(rng: &mut Rng) -> &'static str {
    CLASSES[rng.range(CLASSES.len())]
}

/// Inter-arrival gap shared by the open-loop generators.  A non-positive
/// (or non-finite) `rate` is the documented closed-loop degenerate: every
/// arrival lands at offset 0.0 instead of panicking (debug) or producing
/// `+inf` offsets (release) inside `Rng::exponential`.  The degenerate
/// branch still consumes exactly one draw so the item/image/class streams
/// stay aligned with the paced schedule at the same seed -- `rate` is a
/// knob that may move arrival *times* but never the arrival *contents*.
fn arrival_gap(rng: &mut Rng, rate: f64) -> f64 {
    if rate > 0.0 && rate.is_finite() {
        rng.exponential(rate)
    } else {
        let _ = rng.next_u64();
        0.0
    }
}

/// Bounded (truncated) Pareto draw on `[lo, hi]` via inverse-CDF: the
/// heavy-tailed length law the scenario suite uses for prompt/output
/// sizes.  Smaller `alpha` means heavier tail (more mass near `hi`).
/// Degenerates are defined, not panics: `lo == hi` is the constant
/// distribution and `alpha <= 0` (or non-finite) falls back to uniform on
/// `[lo, hi]`.  Always consumes exactly one draw, so sweeping `alpha`
/// never perturbs other streams derived from the same rng.
pub fn bounded_pareto(rng: &mut Rng, alpha: f64, lo: f64, hi: f64) -> f64 {
    assert!(lo > 0.0 && hi >= lo, "bounded_pareto needs 0 < lo <= hi, got [{lo}, {hi}]");
    let u = rng.f64();
    if hi == lo {
        return lo;
    }
    if alpha <= 0.0 || !alpha.is_finite() {
        return lo + u * (hi - lo);
    }
    let ratio = (lo / hi).powf(alpha);
    lo / (1.0 - u * (1.0 - ratio)).powf(1.0 / alpha)
}

/// Arrival offsets for an inhomogeneous Poisson process whose rate is the
/// piecewise-constant cycle `segments` = `[(duration_s, rate), ...]`
/// repeated forever: the bursty/diurnal arrival law of the scenario
/// suite.  Sampling is exact (time-rescaling: one unit-rate exponential
/// is consumed across segment capacities), not thinning, so every arrival
/// costs exactly one draw regardless of the segment layout -- reshaping
/// the rate profile never perturbs sibling rng streams.
///
/// Degenerates are defined, not hangs: segments with non-positive
/// duration are skipped, zero-rate segments pass wall time without
/// arrivals, and if no segment has positive duration *and* positive rate
/// (including an empty slice) every arrival lands at offset 0.0.
pub fn piecewise_poisson(n: usize, segments: &[(f64, f64)], rng: &mut Rng) -> Vec<f64> {
    let usable = segments
        .iter()
        .any(|&(d, r)| d > 0.0 && r > 0.0 && r.is_finite());
    let mut seg = 0usize;
    let mut into = 0.0; // time already consumed within the current segment
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            let mut e = rng.exponential(1.0);
            if !usable {
                return 0.0;
            }
            loop {
                let (dur, rate) = segments[seg % segments.len()];
                if dur > 0.0 && rate > 0.0 && rate.is_finite() {
                    let cap = (dur - into) * rate;
                    if e < cap {
                        let dt = e / rate;
                        into += dt;
                        t += dt;
                        return t;
                    }
                    e -= cap;
                    t += dur - into;
                } else if dur > 0.0 {
                    t += dur - into;
                }
                seg += 1;
                into = 0.0;
            }
        })
        .collect()
}

/// Open-loop arrival schedule: Poisson process at `rate` req/s over `n`
/// requests drawn round-robin-with-jitter from the eval items.
#[derive(Debug, Clone)]
pub struct Arrival {
    /// offset from test start, seconds
    pub at: f64,
    /// index into the item pool
    pub item: usize,
    /// workload class tag (see `CLASSES`)
    pub class: &'static str,
}

pub fn poisson_schedule(n: usize, rate: f64, pool: usize, seed: u64) -> Vec<Arrival> {
    assert!(pool > 0, "pools must be non-empty");
    let mut rng = Rng::seeded(seed);
    let mut crng = class_rng(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += arrival_gap(&mut rng, rate);
            Arrival { at: t, item: rng.range(pool), class: draw_class(&mut crng) }
        })
        .collect()
}

/// Knobs for the repeated-image / multi-turn arrival generator: benches
/// and tests sweep these to move the prefix-cache hit regime (see
/// `docs/prefix_cache.md`).
#[derive(Debug, Clone)]
pub struct RepeatKnobs {
    /// distinct images in circulation
    pub image_pool: usize,
    /// probability an arrival keeps the previous arrival's image
    /// (multi-turn chat continuing on one image); the rest draw uniformly
    /// from the pool
    pub reuse_prob: f64,
}

/// One multimodal arrival: a prompt-pool index plus an image-pool index.
#[derive(Debug, Clone)]
pub struct MmArrival {
    /// offset from test start, seconds
    pub at: f64,
    /// index into the prompt/item pool
    pub item: usize,
    /// index into the image pool
    pub image: usize,
    /// workload class tag (see `CLASSES`).  Multi-turn continuations
    /// (image reuse) keep the previous arrival's class: a chat turn on
    /// the same image is still the same conversation.
    pub class: &'static str,
}

/// Poisson arrivals over a prompt pool with correlated image reuse: with
/// probability `reuse_prob` an arrival continues on the previous image
/// (the multi-turn regime SpecVLM/ViSpec-style vision-token reuse
/// targets), otherwise it picks a fresh image uniformly.  `reuse_prob = 0`
/// gives i.i.d. images (hit rate bounded by pool reuse); `reuse_prob = 1`
/// pins every request to one image (maximal warm-prefill regime).
pub fn repeated_image_schedule(
    n: usize,
    rate: f64,
    item_pool: usize,
    knobs: &RepeatKnobs,
    seed: u64,
) -> Vec<MmArrival> {
    assert!(item_pool > 0 && knobs.image_pool > 0, "pools must be non-empty");
    let mut rng = Rng::seeded(seed);
    let mut crng = class_rng(seed);
    let mut t = 0.0;
    let mut image = 0usize;
    let mut class = CLASSES[0];
    (0..n)
        .map(|i| {
            t += arrival_gap(&mut rng, rate);
            if i == 0 || rng.f64() >= knobs.reuse_prob {
                image = rng.range(knobs.image_pool);
                class = draw_class(&mut crng);
            }
            MmArrival { at: t, item: rng.range(item_pool), image, class }
        })
        .collect()
}

/// Knobs for the hot-spot (skewed-popularity) image arrival generator.
#[derive(Debug, Clone)]
pub struct HotSpotKnobs {
    /// distinct images in circulation
    pub image_pool: usize,
    /// Zipf skew exponent: image k is drawn with weight 1/(k+1)^s.
    /// `s = 0` is uniform; `s ~ 1.1` makes image 0 a clear hot spot.
    pub zipf_s: f64,
    /// probability an arrival keeps the previous arrival's image
    /// (multi-turn continuation), before the Zipf draw applies
    pub reuse_prob: f64,
}

/// Poisson arrivals whose images follow a Zipf-like popularity law with
/// multi-turn continuation: a few hot images dominate the stream while a
/// long tail stays cold.  This is the regime prefix-affinity routing
/// (`crate::cluster`) targets -- hot images concentrate on their home
/// replicas instead of warming every replica's cache -- and is shared by
/// `benches/micro_cluster.rs` and the scenario harness.
pub fn hotspot_image_schedule(
    n: usize,
    rate: f64,
    item_pool: usize,
    knobs: &HotSpotKnobs,
    seed: u64,
) -> Vec<MmArrival> {
    assert!(item_pool > 0 && knobs.image_pool > 0, "pools must be non-empty");
    // inverse-CDF sampling over the (unnormalized) Zipf weights
    let mut cdf = Vec::with_capacity(knobs.image_pool);
    let mut acc = 0.0;
    for k in 0..knobs.image_pool {
        acc += 1.0 / ((k + 1) as f64).powf(knobs.zipf_s);
        cdf.push(acc);
    }
    let total = acc;
    let mut rng = Rng::seeded(seed);
    let mut crng = class_rng(seed);
    let mut t = 0.0;
    let mut image = 0usize;
    let mut class = CLASSES[0];
    (0..n)
        .map(|i| {
            t += arrival_gap(&mut rng, rate);
            if i == 0 || rng.f64() >= knobs.reuse_prob {
                let u = rng.f64() * total;
                image = cdf.partition_point(|&c| c <= u).min(knobs.image_pool - 1);
                class = draw_class(&mut crng);
            }
            MmArrival { at: t, item: rng.range(item_pool), image, class }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_is_sorted_and_rate_correct() {
        let s = poisson_schedule(5000, 20.0, 10, 42);
        assert_eq!(s.len(), 5000);
        for w in s.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        let span = s.last().unwrap().at;
        let rate = 5000.0 / span;
        assert!((rate - 20.0).abs() < 1.5, "rate {rate}");
        assert!(s.iter().all(|a| a.item < 10));
    }

    #[test]
    fn repeated_image_schedule_sweeps_reuse_regimes() {
        let knobs = |p| RepeatKnobs { image_pool: 8, reuse_prob: p };
        for p in [0.0, 0.5, 0.9] {
            let s = repeated_image_schedule(4000, 50.0, 4, &knobs(p), 11);
            assert_eq!(s.len(), 4000);
            for w in s.windows(2) {
                assert!(w[0].at <= w[1].at, "arrivals must be time-ordered");
            }
            assert!(s.iter().all(|a| a.item < 4 && a.image < 8));
            let repeats = s.windows(2).filter(|w| w[0].image == w[1].image).count();
            let frac = repeats as f64 / (s.len() - 1) as f64;
            // observed repeat fraction = reuse_prob + (1-reuse_prob)/pool
            let expect = p + (1.0 - p) / 8.0;
            assert!(
                (frac - expect).abs() < 0.05,
                "reuse_prob {p}: repeat fraction {frac:.3}, expected ~{expect:.3}"
            );
        }
        // the extremes pin the hit regime
        let pinned = repeated_image_schedule(100, 50.0, 4, &knobs(1.0), 3);
        let first = pinned[0].image;
        assert!(pinned.iter().all(|a| a.image == first), "reuse 1.0 = one image");
        // determinism: same seed, same schedule
        let a = repeated_image_schedule(64, 50.0, 4, &knobs(0.5), 9);
        let b = repeated_image_schedule(64, 50.0, 4, &knobs(0.5), 9);
        assert!(a.iter().zip(&b).all(|(x, y)| x.image == y.image && x.item == y.item));
    }

    #[test]
    fn hotspot_schedule_skews_toward_low_indices() {
        let knobs = HotSpotKnobs { image_pool: 16, zipf_s: 1.2, reuse_prob: 0.0 };
        let s = hotspot_image_schedule(8000, 100.0, 4, &knobs, 7);
        assert_eq!(s.len(), 8000);
        for w in s.windows(2) {
            assert!(w[0].at <= w[1].at, "arrivals must be time-ordered");
        }
        assert!(s.iter().all(|a| a.item < 4 && a.image < 16));
        let mut counts = [0usize; 16];
        for a in &s {
            counts[a.image] += 1;
        }
        // image 0's analytic share under s=1.2 over 16 images is ~0.365;
        // the tail image's is ~0.013
        let head = counts[0] as f64 / s.len() as f64;
        let tail = counts[15] as f64 / s.len() as f64;
        assert!(head > 0.25 && head < 0.5, "hot-spot share {head:.3}");
        assert!(tail < 0.05, "tail share {tail:.3}");
        assert!(head > 4.0 * tail, "popularity must be skewed");
    }

    #[test]
    fn hotspot_schedule_zero_skew_is_uniform_and_reuse_pins() {
        let uniform = HotSpotKnobs { image_pool: 8, zipf_s: 0.0, reuse_prob: 0.0 };
        let s = hotspot_image_schedule(8000, 100.0, 4, &uniform, 21);
        let mut counts = [0usize; 8];
        for a in &s {
            counts[a.image] += 1;
        }
        for (k, &c) in counts.iter().enumerate() {
            let frac = c as f64 / s.len() as f64;
            assert!((frac - 0.125).abs() < 0.02, "image {k} share {frac:.3} not ~1/8");
        }
        // reuse_prob = 1.0 pins the whole stream to the first draw
        let pinned = HotSpotKnobs { image_pool: 8, zipf_s: 1.1, reuse_prob: 1.0 };
        let p = hotspot_image_schedule(200, 100.0, 4, &pinned, 3);
        let first = p[0].image;
        assert!(p.iter().all(|a| a.image == first));
        // determinism: same seed, same schedule
        let knobs = HotSpotKnobs { image_pool: 8, zipf_s: 1.1, reuse_prob: 0.3 };
        let a = hotspot_image_schedule(64, 100.0, 4, &knobs, 9);
        let b = hotspot_image_schedule(64, 100.0, 4, &knobs, 9);
        assert!(a.iter().zip(&b).all(|(x, y)| x.image == y.image && x.item == y.item));
    }

    #[test]
    fn schedules_tag_workload_classes() {
        // every arrival carries a known class, all classes appear, and the
        // tagging is deterministic per seed
        let s = poisson_schedule(600, 20.0, 4, 42);
        assert!(s.iter().all(|a| CLASSES.contains(&a.class)));
        for c in CLASSES {
            assert!(s.iter().any(|a| a.class == c), "class {c} never drawn");
        }
        let s2 = poisson_schedule(600, 20.0, 4, 42);
        assert!(s.iter().zip(&s2).all(|(a, b)| a.class == b.class));

        // multi-turn continuations keep the previous class: under full
        // reuse the whole stream is one conversation, one class
        let knobs = RepeatKnobs { image_pool: 8, reuse_prob: 1.0 };
        let pinned = repeated_image_schedule(100, 50.0, 4, &knobs, 3);
        assert!(pinned.iter().all(|a| a.class == pinned[0].class));
        // and with no reuse, classes mix
        let knobs = RepeatKnobs { image_pool: 8, reuse_prob: 0.0 };
        let mixed = repeated_image_schedule(600, 50.0, 4, &knobs, 5);
        for c in CLASSES {
            assert!(mixed.iter().any(|a| a.class == c), "class {c} never drawn");
        }
        let hot = HotSpotKnobs { image_pool: 8, zipf_s: 1.1, reuse_prob: 0.3 };
        let h = hotspot_image_schedule(600, 100.0, 4, &hot, 9);
        assert!(h.iter().all(|a| CLASSES.contains(&a.class)));
    }

    #[test]
    fn rate_zero_is_defined_and_content_aligned() {
        // rate <= 0 (and non-finite rates) degrade to "all arrivals at
        // offset 0" instead of panicking, and the item/image/class streams
        // are byte-identical to any paced schedule at the same seed: rate
        // moves times, never contents.
        for rate in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let s = poisson_schedule(64, rate, 4, 42);
            assert_eq!(s.len(), 64);
            assert!(s.iter().all(|a| a.at == 0.0), "rate {rate}: arrivals at t=0");
        }
        let paced = poisson_schedule(64, 25.0, 4, 42);
        let parked = poisson_schedule(64, 0.0, 4, 42);
        assert!(paced
            .iter()
            .zip(&parked)
            .all(|(a, b)| a.item == b.item && a.class == b.class));
        let knobs = RepeatKnobs { image_pool: 8, reuse_prob: 0.5 };
        let paced = repeated_image_schedule(64, 25.0, 4, &knobs, 9);
        let parked = repeated_image_schedule(64, 0.0, 4, &knobs, 9);
        assert!(parked.iter().all(|a| a.at == 0.0));
        assert!(paced
            .iter()
            .zip(&parked)
            .all(|(a, b)| a.item == b.item && a.image == b.image && a.class == b.class));
        let knobs = HotSpotKnobs { image_pool: 8, zipf_s: 1.1, reuse_prob: 0.3 };
        let paced = hotspot_image_schedule(64, 25.0, 4, &knobs, 9);
        let parked = hotspot_image_schedule(64, 0.0, 4, &knobs, 9);
        assert!(parked.iter().all(|a| a.at == 0.0));
        assert!(paced
            .iter()
            .zip(&parked)
            .all(|(a, b)| a.item == b.item && a.image == b.image && a.class == b.class));
    }

    #[test]
    fn empty_pool_panics_not_wraps() {
        // pool = 0 must be a loud assert in all build profiles, not a
        // silent release-mode index-0 fallback from Rng::range(0)
        let r = std::panic::catch_unwind(|| poisson_schedule(4, 10.0, 0, 1));
        assert!(r.is_err(), "poisson_schedule(pool=0) must panic");
        let r = std::panic::catch_unwind(|| {
            repeated_image_schedule(4, 10.0, 4, &RepeatKnobs { image_pool: 0, reuse_prob: 0.5 }, 1)
        });
        assert!(r.is_err(), "repeated_image_schedule(image_pool=0) must panic");
        let r = std::panic::catch_unwind(|| {
            hotspot_image_schedule(
                4,
                10.0,
                0,
                &HotSpotKnobs { image_pool: 8, zipf_s: 1.0, reuse_prob: 0.0 },
                1,
            )
        });
        assert!(r.is_err(), "hotspot_image_schedule(item_pool=0) must panic");
    }

    #[test]
    fn piecewise_poisson_matches_segment_rates() {
        // two-phase cycle: 1s at 20/s, 1s at 200/s -- arrivals must be
        // sorted, land in both phases, and respect the per-phase rates
        let mut rng = Rng::seeded(7);
        let segs = [(1.0, 20.0), (1.0, 200.0)];
        let at = piecewise_poisson(6000, &segs, &mut rng);
        assert_eq!(at.len(), 6000);
        for w in at.windows(2) {
            assert!(w[0] <= w[1], "arrivals must be time-ordered");
        }
        let (mut low, mut high) = (0usize, 0usize);
        for &t in &at {
            if t.rem_euclid(2.0) < 1.0 {
                low += 1;
            } else {
                high += 1;
            }
        }
        let ratio = high as f64 / low.max(1) as f64;
        assert!((6.0..16.0).contains(&ratio), "burst ratio {ratio:.2}, expected ~10");
    }

    #[test]
    fn piecewise_poisson_degenerates_are_defined() {
        // zero-rate segments pass wall time without arrivals
        let mut rng = Rng::seeded(3);
        let at = piecewise_poisson(2000, &[(1.0, 100.0), (1.0, 0.0)], &mut rng);
        assert!(at.iter().all(|&t| t.rem_euclid(2.0) < 1.0), "no arrivals in the off phase");
        // empty / all-zero / zero-duration segment lists collapse to t=0
        // rather than spinning forever
        for segs in [&[][..], &[(1.0, 0.0)][..], &[(0.0, 50.0)][..], &[(-1.0, 5.0), (2.0, 0.0)][..]]
        {
            let mut rng = Rng::seeded(3);
            let at = piecewise_poisson(16, segs, &mut rng);
            assert!(at.iter().all(|&t| t == 0.0), "{segs:?} must park at t=0");
        }
        // determinism: same seed, same offsets
        let mut r1 = Rng::seeded(11);
        let mut r2 = Rng::seeded(11);
        let a = piecewise_poisson(256, &[(0.5, 30.0), (0.2, 300.0)], &mut r1);
        let b = piecewise_poisson(256, &[(0.5, 30.0), (0.2, 300.0)], &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn bounded_pareto_bounds_and_degenerates() {
        let mut rng = Rng::seeded(5);
        for alpha in [0.0, 0.5, 1.2, 3.0] {
            for _ in 0..2000 {
                let x = bounded_pareto(&mut rng, alpha, 2.0, 20.0);
                assert!((2.0..=20.0).contains(&x), "alpha {alpha}: {x} out of [2, 20]");
            }
        }
        // lo == hi is the constant distribution
        for _ in 0..16 {
            assert_eq!(bounded_pareto(&mut rng, 1.5, 4.0, 4.0), 4.0);
        }
        // alpha <= 0 falls back to uniform: mean ~ midpoint
        let mean: f64 =
            (0..4000).map(|_| bounded_pareto(&mut rng, 0.0, 2.0, 20.0)).sum::<f64>() / 4000.0;
        assert!((mean - 11.0).abs() < 0.5, "uniform fallback mean {mean:.2}");
        // heavier alpha concentrates mass near lo: median well below uniform's
        let mut xs: Vec<f64> = (0..4001).map(|_| bounded_pareto(&mut rng, 2.0, 2.0, 20.0)).collect();
        xs.sort_by(f64::total_cmp);
        assert!(xs[2000] < 4.0, "alpha=2 median {:.2} should hug lo", xs[2000]);
        // invalid bounds panic loudly
        assert!(std::panic::catch_unwind(|| {
            bounded_pareto(&mut Rng::seeded(1), 1.0, 0.0, 4.0)
        })
        .is_err());
        assert!(std::panic::catch_unwind(|| {
            bounded_pareto(&mut Rng::seeded(1), 1.0, 5.0, 4.0)
        })
        .is_err());
        // alpha is a tail knob, not a stream knob: one draw regardless
        let mut r1 = Rng::seeded(9);
        let mut r2 = Rng::seeded(9);
        bounded_pareto(&mut r1, 0.7, 2.0, 20.0);
        bounded_pareto(&mut r2, 3.0, 2.0, 20.0);
        assert_eq!(r1.next_u64(), r2.next_u64(), "alpha must not change draw count");
    }

    #[test]
    fn load_task_parses_inline_fixture() {
        // round-trip through a temp dir
        let dir = std::env::temp_dir().join(format!("massv_wl_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("eval")).unwrap();
        let img: Vec<String> = (0..768).map(|i| format!("{}", (i % 4) as f64 * 0.25)).collect();
        std::fs::write(
            dir.join("eval/coco.json"),
            format!(
                r#"{{"task":"coco","items":[{{"task":"coco","prompt":"the red circle",
                     "reference":"the red circle .","image":[{}]}}]}}"#,
                img.join(",")
            ),
        )
        .unwrap();
        let tok = Tokenizer::from_json(
            r#"{"tokens":["<pad>","<bos>","<eos>","<sep>","<img>","the","red","circle","."],
                "pad_id":0,"bos_id":1,"eos_id":2,"sep_id":3,"img_id":4}"#,
        )
        .unwrap();
        let items = load_task(dir.to_str().unwrap(), "coco", &tok, 8).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].prompt_len, 5);
        assert_eq!(items[0].prompt_ids[..5], [1, 5, 6, 7, 3]);
        assert_eq!(items[0].image.len(), 768);
        std::fs::remove_dir_all(&dir).ok();
    }
}
