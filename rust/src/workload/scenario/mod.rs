//! Named, seeded, deterministic serving scenarios.
//!
//! Each scenario composes the schedule primitives in `workload` (Poisson
//! and piecewise-Poisson arrivals, bounded-Pareto lengths, Zipf image
//! popularity, multi-turn continuation) into a replayable [`Trace`]: a
//! time-sorted list of fully-specified requests.  The same `(knobs,
//! seed)` pair always produces a byte-identical trace -- pinned by
//! `Trace::digest` in `rust/tests/workload_properties.rs` -- so a trace
//! is a reproducible experiment, not a one-shot load pattern.
//!
//! Determinism follows the derived-RNG-stream rule the flat generators
//! established: each concern (arrival times, content, class tags,
//! lengths) draws from its own rng derived from the scenario seed, and
//! every draw consumes a fixed budget regardless of knob values.  Knobs
//! therefore perturb only the streams they semantically own -- `rate`
//! moves arrival times but never images or classes, `max_new` never
//! moves arrivals, `prompt_pool` never moves images.
//!
//! The replay harness that drives a trace through the real server (TCP
//! or HTTP front, any replica count) lives in [`replay`]; the standing
//! bench over all scenarios is `benches/scenario_suite.rs`
//! (`docs/scenarios.md`).

pub mod replay;

use super::{
    arrival_gap, bounded_pareto, class_rng, draw_class, hotspot_image_schedule, piecewise_poisson,
    HotSpotKnobs,
};
use crate::util::rng::Rng;

/// One fully-specified request in a trace.  `image` is a `demo_image`
/// phase (the scripted backend's synthetic image family); `by_reference`
/// marks turns that should re-reference the image by its content address
/// (`image_id`) once a prior response has reported it, exercising the
/// pixel-free fast path -- the replay harness falls back to pixels until
/// the address is known, which is output-identical because the cache is
/// content-addressed either way.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// arrival offset from trace start, seconds
    pub at: f64,
    /// conversation this request belongs to (single-shot scenarios give
    /// every request its own conversation)
    pub conv: u64,
    /// turn index within the conversation
    pub turn: usize,
    /// workload class tag (`workload::CLASSES`)
    pub class: &'static str,
    pub tenant: String,
    /// "interactive" | "batch" (wire values of `Request::priority`)
    pub priority: &'static str,
    pub prompt: String,
    /// image identity: a `models::scripted::demo_image` phase
    pub image: usize,
    pub by_reference: bool,
    pub max_new: usize,
    /// 0.0 everywhere: greedy decoding keeps replay token streams
    /// bit-identical across fronts, replica counts, and repetitions
    pub temperature: f32,
    pub seed: u64,
    /// None from every generator; the soak tests mutate this in place
    pub deadline_ms: Option<u64>,
}

/// A named, replayable scenario trace: requests sorted by arrival offset.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub seed: u64,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Offset of the last arrival, seconds (0.0 for an empty trace).
    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.at).unwrap_or(0.0)
    }

    /// FNV-1a digest over every field of every request (floats by bit
    /// pattern).  Two traces with equal digests are byte-identical for
    /// all practical purposes; the property tests pin same-seed equality
    /// and cross-seed inequality through this.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.bytes(self.name.as_bytes());
        h.u64(self.seed);
        for r in &self.requests {
            h.u64(r.at.to_bits());
            h.u64(r.conv);
            h.u64(r.turn as u64);
            h.bytes(r.class.as_bytes());
            h.bytes(r.tenant.as_bytes());
            h.bytes(r.priority.as_bytes());
            h.bytes(r.prompt.as_bytes());
            h.u64(r.image as u64);
            h.u64(r.by_reference as u64);
            h.u64(r.max_new as u64);
            h.u64(r.temperature.to_bits() as u64);
            h.u64(r.seed);
            h.u64(r.deadline_ms.map(|d| d + 1).unwrap_or(0));
        }
        h.0
    }
}

struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
        // length terminator so ("ab","c") != ("a","bc")
        self.0 ^= b.len() as u64;
        self.0 = self.0.wrapping_mul(0x100_0000_01b3);
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
}

/// Shared scenario knobs.  Every scenario interprets them the same way:
/// `requests` is the exact emitted trace length, `rate` the target mean
/// arrival rate (req/s; <= 0 parks all arrivals at t=0), `image_pool` /
/// `prompt_pool` the distinct images / prompt stems in circulation,
/// `max_new` the per-request decode budget (scenario-specific laws may
/// scale it), and `image_base` offsets every image phase so traces
/// sharing one server don't cross-warm each other's caches.
#[derive(Debug, Clone)]
pub struct ScenarioKnobs {
    pub requests: usize,
    pub rate: f64,
    pub image_pool: usize,
    pub prompt_pool: usize,
    pub max_new: usize,
    pub image_base: usize,
}

impl Default for ScenarioKnobs {
    fn default() -> ScenarioKnobs {
        ScenarioKnobs {
            requests: 64,
            rate: 32.0,
            image_pool: 8,
            prompt_pool: 6,
            max_new: 16,
            image_base: 0,
        }
    }
}

/// The scenario registry, in bench-report order.
pub const NAMES: [&str; 6] = [
    "chat_image_reuse",
    "bursty_diurnal",
    "heavy_tail",
    "mixed_tenants",
    "multi_image_chat",
    "zipf_hotspot",
];

/// Build a named scenario; `None` for an unknown name.
pub fn by_name(name: &str, knobs: &ScenarioKnobs, seed: u64) -> Option<Trace> {
    Some(match name {
        "chat_image_reuse" => chat_image_reuse(knobs, seed),
        "bursty_diurnal" => bursty_diurnal(knobs, seed),
        "heavy_tail" => heavy_tail(knobs, seed),
        "mixed_tenants" => mixed_tenants(knobs, seed),
        "multi_image_chat" => multi_image_chat(knobs, seed),
        "zipf_hotspot" => zipf_hotspot(knobs, seed),
        _ => return None,
    })
}

/// Derived rng streams, one per concern (the PR 8 guarantee extended to
/// scenarios): arrivals, content (images/prompts/per-request seeds),
/// classes, lengths.
fn rng_streams(seed: u64) -> (Rng, Rng, Rng, Rng) {
    (
        Rng::seeded(seed ^ 0xA5A5_5A5A_0F0F_F0F0),
        Rng::seeded(seed ^ 0xC3C3_3C3C_69A9_9A96),
        class_rng(seed),
        Rng::seeded(seed ^ 0x1357_9BDF_2468_ACE0),
    )
}

/// Deterministic prompt text over the scripted vocab (`w5`..`w104`):
/// `idx` selects the stem, `salt` differentiates turns of one
/// conversation, `words` sets the length.  Stays well under the scripted
/// manifest's `p_max = 32` for `words <= 20`.
fn prompt_for(idx: usize, salt: usize, words: usize) -> String {
    let mut s = String::new();
    for k in 0..words.max(1) {
        if k > 0 {
            s.push(' ');
        }
        let w = 5 + (idx * 17 + salt * 29 + k * 7) % 100;
        s.push_str(&format!("w{w}"));
    }
    s
}

/// Sort by arrival (conversation/turn tie-break so equal-time arrivals
/// have one canonical order) and cut to the exact request budget.
fn finish(name: &str, seed: u64, knobs: &ScenarioKnobs, mut reqs: Vec<TraceRequest>) -> Trace {
    reqs.sort_by(|a, b| {
        a.at.total_cmp(&b.at).then(a.conv.cmp(&b.conv)).then(a.turn.cmp(&b.turn))
    });
    reqs.truncate(knobs.requests);
    Trace { name: name.to_string(), seed, requests: reqs }
}

fn base_request(k: &ScenarioKnobs) -> TraceRequest {
    TraceRequest {
        at: 0.0,
        conv: 0,
        turn: 0,
        class: super::CLASSES[0],
        tenant: "default".to_string(),
        priority: "interactive",
        prompt: String::new(),
        image: k.image_base,
        by_reference: false,
        max_new: k.max_new.max(1),
        temperature: 0.0,
        seed: 0,
        deadline_ms: None,
    }
}

/// Multi-turn chat with image reuse: conversations open as a Poisson
/// stream, run 1-4 turns with exponential think gaps, and every
/// follow-up turn re-references the opening turn's image (`image_id`
/// path) with a fresh prompt -- the warm-prefill regime the prefix cache
/// and vision-encode reuse target.
pub fn chat_image_reuse(k: &ScenarioKnobs, seed: u64) -> Trace {
    let (mut arr, mut content, mut class, _len) = rng_streams(seed);
    let mut reqs = Vec::new();
    let mut t = 0.0;
    let mut conv = 0u64;
    // mean 2.5 turns/conversation: open at rate/2.5 to land near `rate`
    let conv_rate = k.rate / 2.5;
    while reqs.len() < k.requests {
        t += arrival_gap(&mut arr, conv_rate);
        let turns = 1 + arr.range(4);
        let image = k.image_base + content.range(k.image_pool.max(1));
        let c = draw_class(&mut class);
        let mut at = t;
        for turn in 0..turns {
            if turn > 0 {
                at += arrival_gap(&mut arr, k.rate * 0.5);
            }
            let stem = content.range(k.prompt_pool.max(1));
            reqs.push(TraceRequest {
                at,
                conv,
                turn,
                class: c,
                prompt: prompt_for(stem, turn, 4),
                image,
                by_reference: turn > 0,
                seed: content.next_u64(),
                ..base_request(k)
            });
        }
        conv += 1;
    }
    finish("chat_image_reuse", seed, k, reqs)
}

/// Bursty/diurnal arrivals: a piecewise-rate Poisson cycle with a quiet
/// phase, a shoulder, a 4x burst spike, and a busy tail, scaled so the
/// whole trace spans roughly `requests / rate` seconds.  Content is
/// i.i.d. -- this scenario stresses admission and batching, not caching.
pub fn bursty_diurnal(k: &ScenarioKnobs, seed: u64) -> Trace {
    let (mut arr, mut content, mut class, _len) = rng_streams(seed);
    let span = if k.rate > 0.0 && k.rate.is_finite() { k.requests as f64 / k.rate } else { 1.0 };
    let segs = [
        (0.30 * span, 0.4 * k.rate),
        (0.25 * span, 1.0 * k.rate),
        (0.10 * span, 4.0 * k.rate),
        (0.35 * span, 1.1 * k.rate),
    ];
    let at = piecewise_poisson(k.requests, &segs, &mut arr);
    let reqs = at
        .into_iter()
        .enumerate()
        .map(|(i, at)| TraceRequest {
            at,
            conv: i as u64,
            class: draw_class(&mut class),
            prompt: prompt_for(content.range(k.prompt_pool.max(1)), 0, 4),
            image: k.image_base + content.range(k.image_pool.max(1)),
            seed: content.next_u64(),
            ..base_request(k)
        })
        .collect();
    finish("bursty_diurnal", seed, k, reqs)
}

/// Heavy-tailed prompt and output lengths: bounded-Pareto word counts
/// (2-18 words) and decode budgets (2 up to 3x `max_new`, capped at 48
/// to stay inside the scripted `t_max`), Poisson arrivals.  A few
/// long-read requests dominate token volume while most stay short --
/// the occupancy/fairness stress for iteration-level scheduling.
pub fn heavy_tail(k: &ScenarioKnobs, seed: u64) -> Trace {
    let (mut arr, mut content, mut class, mut len) = rng_streams(seed);
    let hi = ((k.max_new.max(2) * 3).min(48).max(k.max_new.max(2))) as f64;
    let mut t = 0.0;
    let reqs = (0..k.requests)
        .map(|i| {
            t += arrival_gap(&mut arr, k.rate);
            let words = bounded_pareto(&mut len, 1.3, 2.0, 18.0).round() as usize;
            let out = bounded_pareto(&mut len, 1.1, 2.0, hi).round() as usize;
            TraceRequest {
                at: t,
                conv: i as u64,
                class: draw_class(&mut class),
                prompt: prompt_for(content.range(k.prompt_pool.max(1)), 0, words),
                image: k.image_base + content.range(k.image_pool.max(1)),
                max_new: out.max(1),
                seed: content.next_u64(),
                ..base_request(k)
            }
        })
        .collect();
    finish("heavy_tail", seed, k, reqs)
}

/// Mixed tenants with unequal traffic shares: two interactive chat
/// tenants ("gold", "silver") at a quarter of the load each, plus a
/// "bulk" batch tenant contributing half the requests in a
/// quiet/burst piecewise cycle at twice the decode budget.  Each lane
/// gets its own derived arrival rng, so adding or re-rating one tenant
/// never perturbs another lane's schedule.
pub fn mixed_tenants(k: &ScenarioKnobs, seed: u64) -> Trace {
    let (_, mut content, mut class, _len) = rng_streams(seed);
    let lanes: [(&str, f64, &'static str, usize); 3] = [
        ("gold", 0.25, "interactive", 1),
        ("silver", 0.25, "interactive", 1),
        ("bulk", 0.5, "batch", 2),
    ];
    let mut counts: Vec<usize> = lanes.iter().map(|l| (k.requests as f64 * l.1) as usize).collect();
    let assigned: usize = counts.iter().sum();
    if let Some(last) = counts.last_mut() {
        *last += k.requests - assigned.min(k.requests);
    }
    let mut reqs = Vec::new();
    for (li, &(tenant, share, priority, mult)) in lanes.iter().enumerate() {
        let mut arr = Rng::seeded(seed ^ 0xBEEF_0000_0000_0000 ^ ((li as u64 + 1) << 32));
        let lane_rate = k.rate * share;
        let n = counts[li];
        let at: Vec<f64> = if priority == "batch" {
            // bulk traffic arrives in bursts: 4-phase quiet/spike cycle
            let span = if lane_rate > 0.0 && lane_rate.is_finite() {
                n as f64 / lane_rate
            } else {
                1.0
            };
            let segs = [
                (0.4 * span, 0.3 * lane_rate),
                (0.15 * span, 4.0 * lane_rate),
                (0.45 * span, 0.9 * lane_rate),
            ];
            piecewise_poisson(n, &segs, &mut arr)
        } else {
            let mut t = 0.0;
            (0..n)
                .map(|_| {
                    t += arrival_gap(&mut arr, lane_rate);
                    t
                })
                .collect()
        };
        for (i, at) in at.into_iter().enumerate() {
            reqs.push(TraceRequest {
                at,
                conv: ((li as u64) << 32) | i as u64,
                class: draw_class(&mut class),
                tenant: tenant.to_string(),
                priority,
                prompt: prompt_for(content.range(k.prompt_pool.max(1)), li, 4),
                image: k.image_base + content.range(k.image_pool.max(1)),
                max_new: (k.max_new.max(1) * mult).min(48),
                seed: content.next_u64(),
                ..base_request(k)
            });
        }
    }
    finish("mixed_tenants", seed, k, reqs)
}

/// Multi-image conversations: each conversation draws a pool of 2-4
/// images and cycles turns over them, revisiting each image at least
/// once; first sightings ship pixels, revisits go by reference.  This is
/// the interleaved-eviction stress for the vision-encode cache -- hits
/// require the cache to hold several images per conversation at once.
pub fn multi_image_chat(k: &ScenarioKnobs, seed: u64) -> Trace {
    let (mut arr, mut content, mut class, _len) = rng_streams(seed);
    let mut reqs = Vec::new();
    let mut t = 0.0;
    let mut conv = 0u64;
    let conv_rate = k.rate / 5.0; // ~5 turns per conversation
    while reqs.len() < k.requests {
        t += arrival_gap(&mut arr, conv_rate);
        let m = (2 + content.range(3)).min(k.image_pool.max(1));
        let images: Vec<usize> =
            (0..m).map(|_| k.image_base + content.range(k.image_pool.max(1))).collect();
        let turns = m + arr.range(m + 1);
        let c = draw_class(&mut class);
        let mut at = t;
        for turn in 0..turns {
            if turn > 0 {
                at += arrival_gap(&mut arr, k.rate * 0.5);
            }
            reqs.push(TraceRequest {
                at,
                conv,
                turn,
                class: c,
                prompt: prompt_for(content.range(k.prompt_pool.max(1)), turn, 3),
                image: images[turn % m],
                by_reference: turn >= m,
                seed: content.next_u64(),
                ..base_request(k)
            });
        }
        conv += 1;
    }
    finish("multi_image_chat", seed, k, reqs)
}

/// Zipf hot-spot images: wraps `hotspot_image_schedule` (zipf_s = 1.1,
/// 30% multi-turn continuation) so a few hot images dominate -- the
/// prefix-affinity routing regime.  All requests are marked
/// `by_reference`: once a hot image's content address is known, the
/// stream stops shipping pixels for it.
pub fn zipf_hotspot(k: &ScenarioKnobs, seed: u64) -> Trace {
    let hk = HotSpotKnobs { image_pool: k.image_pool.max(1), zipf_s: 1.1, reuse_prob: 0.3 };
    let sched = hotspot_image_schedule(k.requests, k.rate, k.prompt_pool.max(1), &hk, seed);
    let (_, mut content, _, _) = rng_streams(seed);
    let reqs = sched
        .into_iter()
        .enumerate()
        .map(|(i, a)| TraceRequest {
            at: a.at,
            conv: i as u64,
            class: a.class,
            prompt: prompt_for(a.item, 0, 4),
            image: k.image_base + a.image,
            by_reference: true,
            seed: content.next_u64(),
            ..base_request(k)
        })
        .collect();
    finish("zipf_hotspot", seed, k, reqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn knobs() -> ScenarioKnobs {
        ScenarioKnobs { requests: 96, ..ScenarioKnobs::default() }
    }

    #[test]
    fn every_scenario_emits_exact_sorted_budget() {
        for name in NAMES {
            let t = by_name(name, &knobs(), 7).unwrap();
            assert_eq!(t.name, name);
            assert_eq!(t.requests.len(), 96, "{name}");
            for w in t.requests.windows(2) {
                assert!(w[0].at <= w[1].at, "{name}: arrivals must be time-ordered");
            }
            for r in &t.requests {
                assert!(r.at >= 0.0, "{name}");
                assert!(!r.prompt.is_empty() && r.max_new >= 1, "{name}");
                assert!(super::super::CLASSES.contains(&r.class), "{name}");
                assert!(!r.tenant.is_empty(), "{name}");
                assert_eq!(r.temperature, 0.0, "{name}: traces must be greedy");
                assert!(r.deadline_ms.is_none(), "{name}");
            }
        }
        assert!(by_name("nope", &knobs(), 7).is_none());
    }

    #[test]
    fn chat_reuse_rereferences_the_conversation_image() {
        let t = chat_image_reuse(&knobs(), 3);
        let mut follow_ups = 0;
        for r in &t.requests {
            if r.turn > 0 {
                follow_ups += 1;
                assert!(r.by_reference, "follow-up turns go by image_id");
                let opener = t
                    .requests
                    .iter()
                    .find(|o| o.conv == r.conv && o.turn == 0)
                    .expect("opener in trace");
                assert_eq!(opener.image, r.image, "turns share the conversation image");
                assert_eq!(opener.class, r.class, "turns share the conversation class");
                assert_ne!(opener.prompt, r.prompt, "turns ask new questions");
            }
        }
        assert!(follow_ups > 10, "reuse regime needs follow-ups, got {follow_ups}");
    }

    #[test]
    fn mixed_tenants_shares_and_priorities() {
        let t = mixed_tenants(&ScenarioKnobs { requests: 200, ..knobs() }, 5);
        let count = |tn: &str| t.requests.iter().filter(|r| r.tenant == tn).count();
        let (g, s, b) = (count("gold"), count("silver"), count("bulk"));
        assert_eq!(g + s + b, 200);
        assert_eq!(g, 50);
        assert_eq!(s, 50);
        assert_eq!(b, 100, "bulk takes half the traffic plus rounding remainder");
        for r in &t.requests {
            let want = if r.tenant == "bulk" { "batch" } else { "interactive" };
            assert_eq!(r.priority, want);
        }
    }

    #[test]
    fn multi_image_chat_revisits_by_reference() {
        let t = multi_image_chat(&knobs(), 11);
        let mut revisits = 0;
        for r in &t.requests {
            if r.by_reference {
                revisits += 1;
                // a revisit's image appeared earlier in the same conversation
                assert!(
                    t.requests
                        .iter()
                        .any(|o| o.conv == r.conv && o.turn < r.turn && o.image == r.image),
                    "revisit must re-reference a previously shown image"
                );
            }
        }
        assert!(revisits > 5, "need revisits, got {revisits}");
    }

    #[test]
    fn zipf_hotspot_is_skewed() {
        let t = zipf_hotspot(&ScenarioKnobs { requests: 600, image_base: 40, ..knobs() }, 9);
        let hot = t.requests.iter().filter(|r| r.image == 40).count();
        assert!(
            hot as f64 / 600.0 > 0.25,
            "hot image share {:.3} should dominate",
            hot as f64 / 600.0
        );
        assert!(t.requests.iter().all(|r| (40..48).contains(&r.image)), "image_base offsets");
    }

    #[test]
    fn digest_separates_seeds_and_pins_same_seed() {
        for name in NAMES {
            let a = by_name(name, &knobs(), 7).unwrap();
            let b = by_name(name, &knobs(), 7).unwrap();
            let c = by_name(name, &knobs(), 8).unwrap();
            assert_eq!(a.digest(), b.digest(), "{name}: same seed, same trace");
            assert_ne!(a.digest(), c.digest(), "{name}: seed must matter");
        }
    }

    #[test]
    fn degenerate_knobs_are_defined() {
        // rate 0 parks arrivals at t=0; pools of 1 and a zero budget work
        for name in NAMES {
            let t = by_name(
                name,
                &ScenarioKnobs {
                    requests: 8,
                    rate: 0.0,
                    image_pool: 1,
                    prompt_pool: 1,
                    max_new: 1,
                    image_base: 0,
                },
                3,
            )
            .unwrap();
            assert_eq!(t.requests.len(), 8, "{name}");
            assert!(t.requests.iter().all(|r| r.at == 0.0), "{name}: rate 0 parks at t=0");
            let empty = by_name(
                name,
                &ScenarioKnobs { requests: 0, ..ScenarioKnobs::default() },
                3,
            )
            .unwrap();
            assert!(empty.requests.is_empty(), "{name}");
        }
    }
}
