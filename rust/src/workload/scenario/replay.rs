//! Trace replay: drive a [`Trace`](super::Trace) through the real server.
//!
//! One thread per request sleeps to its (optionally time-scaled) arrival
//! offset, opens its own connection, and runs the request against either
//! front -- TCP newline-JSON (`server::Client`) or the HTTP/SSE gateway
//! (`server::http::HttpClient`) -- streaming or not.  The harness is
//! front-agnostic on purpose: the cross-front equivalence test
//! (`rust/tests/scenario_replay.rs`) replays one trace all four ways and
//! pins bit-identical token streams.
//!
//! `by_reference` turns resolve the image's content address from a map
//! learned out of prior responses in the same replay; until the address
//! is known they fall back to shipping pixels, which is output-identical
//! because the cache is content-addressed either way.
//!
//! Shed handling: HTTP 429/503 and engine-side `finish_reason ==
//! "rejected"` are retried with a short backoff when `retry_shed` is set
//! (counted in `RequestOutcome::sheds`), so a replay's token totals stay
//! deterministic even when admission control is active -- shedding moves
//! *when* work runs, not *whether* it completes.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::{Trace, TraceRequest};
use crate::models::scripted::demo_image;
use crate::server::http::HttpClient;
use crate::server::Client;
use crate::util::json::Json;

/// Which server front to replay against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Front {
    /// newline-JSON TCP protocol (`server::Server`)
    Tcp,
    /// HTTP gateway, `POST /v1/generate` (`server::http::HttpServer`)
    Http,
}

#[derive(Debug, Clone)]
pub struct ReplayOptions {
    pub front: Front,
    /// stream per-step chunks (TCP chunk frames / SSE) instead of one
    /// blocking response; TTFT/TPOT become client-observed stamps
    pub streaming: bool,
    /// multiplier on trace arrival offsets; 0.0 disables pacing entirely
    /// (every request dispatches immediately -- a closed flood)
    pub time_scale: f64,
    /// retry 429/503/rejected with backoff instead of giving up
    pub retry_shed: bool,
    pub shed_backoff_ms: u64,
}

impl Default for ReplayOptions {
    fn default() -> ReplayOptions {
        ReplayOptions {
            front: Front::Tcp,
            streaming: true,
            time_scale: 1.0,
            retry_shed: true,
            shed_backoff_ms: 5,
        }
    }
}

/// Per-request replay result.  Latency fields are wall-clock and
/// advisory; `tokens`, `finish_reason`, `mal`, and `cache_hit` are
/// deterministic under greedy traces.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    /// index into `Trace::requests`
    pub index: usize,
    pub tokens: Vec<i32>,
    /// streaming: first chunk stamp; non-streaming: engine queue+prefill
    pub ttft_ms: f64,
    /// streaming: stamp span over post-first tokens; non-streaming:
    /// engine decode time over post-first tokens
    pub tpot_ms: f64,
    /// client-observed total for this request, retries included
    pub total_ms: f64,
    pub mal: f64,
    pub cache_hit: bool,
    pub finish_reason: String,
    /// times this request was shed (429/503/rejected) before completing
    pub sheds: u32,
    pub tenant: String,
    pub class: &'static str,
}

pub struct ReplayReport {
    pub outcomes: Vec<RequestOutcome>,
    pub wall_s: f64,
}

impl ReplayReport {
    pub fn total_tokens(&self) -> usize {
        self.outcomes.iter().map(|o| o.tokens.len()).sum()
    }

    /// Token streams in trace order (the cross-front equivalence object).
    pub fn token_streams(&self) -> Vec<Vec<i32>> {
        self.outcomes.iter().map(|o| o.tokens.clone()).collect()
    }

    /// Requests that ran to a normal terminal (eos or length).
    pub fn completed(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| o.finish_reason == "eos" || o.finish_reason == "length")
            .count()
    }

    pub fn sheds(&self) -> u64 {
        self.outcomes.iter().map(|o| o.sheds as u64).sum()
    }

    pub fn cache_hits(&self) -> usize {
        self.outcomes.iter().filter(|o| o.cache_hit).count()
    }

    pub fn mal_mean(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.mal).sum::<f64>() / self.outcomes.len() as f64
    }

    pub fn ttfts(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.outcomes.iter().map(|o| o.ttft_ms).collect();
        v.sort_by(f64::total_cmp);
        v
    }

    pub fn tpots(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.outcomes.iter().map(|o| o.tpot_ms).collect();
        v.sort_by(f64::total_cmp);
        v
    }
}

/// Ceil-rank percentile over a pre-sorted slice (same convention as the
/// metrics histogram); 0.0 on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

/// Replay `trace` against the server at `addr`.  Errors if any request
/// fails validation, loses its connection, or (streaming) its chunk
/// concatenation disagrees with the summary token array.
pub fn replay(addr: &str, trace: &Trace, opts: &ReplayOptions) -> Result<ReplayReport> {
    let ids: Arc<Mutex<HashMap<usize, String>>> = Arc::new(Mutex::new(HashMap::new()));
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(trace.requests.len());
    for (idx, r) in trace.requests.iter().cloned().enumerate() {
        let addr = addr.to_string();
        let ids = ids.clone();
        let opts = opts.clone();
        handles.push(std::thread::spawn(move || -> Result<RequestOutcome> {
            if opts.time_scale > 0.0 {
                let due = r.at * opts.time_scale;
                let elapsed = t0.elapsed().as_secs_f64();
                if due > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(due - elapsed));
                }
            }
            run_one(&addr, idx, &r, &ids, &opts)
        }));
    }
    let mut outcomes = Vec::with_capacity(handles.len());
    for h in handles {
        outcomes.push(h.join().map_err(|_| anyhow!("replay worker panicked"))??);
    }
    outcomes.sort_by_key(|o| o.index);
    Ok(ReplayReport { outcomes, wall_s: t0.elapsed().as_secs_f64() })
}

/// Wire body for one trace request.  The `op` tag is what the TCP front
/// routes on; the HTTP front ignores unknown fields, so one body serves
/// both.  `image_id` (when known) replaces the pixel payload.
fn body_for(r: &TraceRequest, image_id: Option<&str>, streaming: bool) -> Json {
    let mut fields = vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str(r.prompt.clone())),
        ("task", Json::str(r.class)),
        ("max_new", Json::num(r.max_new as f64)),
        ("temperature", Json::num(r.temperature as f64)),
        ("seed", Json::num(r.seed as f64)),
        ("priority", Json::str(r.priority)),
        ("tenant", Json::str(r.tenant.clone())),
    ];
    match image_id {
        Some(id) => fields.push(("image_id", Json::str(id))),
        None => fields.push(("image", Json::arr_f32(&demo_image(r.image)))),
    }
    if streaming {
        fields.push(("stream", Json::Bool(true)));
    }
    if let Some(d) = r.deadline_ms {
        fields.push(("deadline_ms", Json::num(d as f64)));
    }
    Json::obj(fields)
}

fn run_one(
    addr: &str,
    idx: usize,
    r: &TraceRequest,
    ids: &Mutex<HashMap<usize, String>>,
    opts: &ReplayOptions,
) -> Result<RequestOutcome> {
    let t_start = Instant::now();
    let mut sheds = 0u32;
    let mut tcp: Option<Client> = None;
    loop {
        let known = if r.by_reference { ids.lock().unwrap().get(&r.image).cloned() } else { None };
        let body = body_for(r, known.as_deref(), opts.streaming);
        let (frames, summary, status): (Vec<(f64, Vec<i32>)>, Json, u16) = match opts.front {
            Front::Tcp => {
                if tcp.is_none() {
                    tcp = Some(Client::connect(addr)?);
                }
                let c = tcp.as_mut().unwrap();
                if opts.streaming {
                    let (f, s) = c.call_streaming_timed(&body)?;
                    (f, s, 200)
                } else {
                    (Vec::new(), c.call(&body)?, 200)
                }
            }
            Front::Http => {
                let c = HttpClient::new(addr);
                if opts.streaming {
                    let (st, f, s) = c.generate_streaming_timed(&body, None)?;
                    (f, s, st)
                } else {
                    let (st, s) = c.generate(&body, None)?;
                    (Vec::new(), s, st)
                }
            }
        };
        let total_ms = t_start.elapsed().as_secs_f64() * 1e3;
        // gateway sheds (429 rate / 503 concurrency) and engine-side
        // rejections (503 with finish_reason "rejected", or the bare
        // "rejected" summary on the TCP front)
        let engine_rejected = summary
            .get("finish_reason")
            .and_then(|v| v.as_str().ok())
            .is_some_and(|f| f == "rejected");
        if status == 429 || status == 503 || engine_rejected {
            if opts.retry_shed {
                sheds += 1;
                std::thread::sleep(Duration::from_millis(opts.shed_backoff_ms.max(1)));
                continue;
            }
            let finish =
                if engine_rejected { "rejected".to_string() } else { format!("shed_{status}") };
            return Ok(RequestOutcome {
                index: idx,
                tokens: Vec::new(),
                ttft_ms: 0.0,
                tpot_ms: 0.0,
                total_ms,
                mal: 0.0,
                cache_hit: false,
                finish_reason: finish,
                sheds,
                tenant: r.tenant.clone(),
                class: r.class,
            });
        }
        if status != 200 {
            return Err(anyhow!(
                "request {idx}: HTTP {status}: {}",
                summary.get("error").and_then(|e| e.as_str().ok()).unwrap_or("?")
            ));
        }
        if let Some(e) = summary.get("error") {
            return Err(anyhow!("request {idx}: {}", e.as_str().unwrap_or("malformed error")));
        }
        let finish = summary.req("finish_reason")?.as_str()?.to_string();
        let tokens = summary.req("tokens")?.to_i32_vec()?;
        if opts.streaming {
            let concat: Vec<i32> = frames.iter().flat_map(|(_, c)| c.iter().copied()).collect();
            if concat != tokens {
                return Err(anyhow!("request {idx}: chunk concatenation != summary tokens"));
            }
        }
        // learn the image's content address for later by-reference turns
        if let Some(id) = summary.get("image_id").and_then(|v| v.as_str().ok()) {
            if !id.is_empty() {
                ids.lock().unwrap().insert(r.image, id.to_string());
            }
        }
        let num = |k: &str| summary.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        let (ttft, tpot) = if opts.streaming {
            match (frames.first(), frames.last()) {
                (Some(f), Some(l)) => {
                    let after_first = tokens.len().saturating_sub(f.1.len()).max(1);
                    (f.0, (l.0 - f.0) / after_first as f64)
                }
                _ => (total_ms, 0.0),
            }
        } else {
            let ttft = num("queue_ms") + num("prefill_ms");
            let decode = (num("latency_ms") - ttft).max(0.0);
            (ttft, decode / tokens.len().saturating_sub(1).max(1) as f64)
        };
        let hit = summary.get("cache_hit").and_then(|v| v.as_bool().ok()).unwrap_or(false);
        return Ok(RequestOutcome {
            index: idx,
            mal: num("mal"),
            tokens,
            ttft_ms: ttft,
            tpot_ms: tpot,
            total_ms,
            cache_hit: hit,
            finish_reason: finish,
            sheds,
            tenant: r.tenant.clone(),
            class: r.class,
        });
    }
}
