//! Serving metrics: counters, gauges, latency histograms with percentile
//! queries, and a throughput window.  Lock-free where it matters (counters
//! on the hot path are atomics); histograms take a short mutex only when a
//! sample is recorded.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[derive(Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if it is below (running-maximum tracking).
    pub fn max_with(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Latency histogram with exact percentile queries (stores samples; serving
/// runs here are small enough that this beats maintaining HDR buckets).
#[derive(Default)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        self.samples.lock().unwrap().push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn mean(&self) -> f64 {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return 0.0;
        }
        s.iter().sum::<f64>() / s.len() as f64
    }

    /// Exact percentile (nearest-rank).  `p` in [0, 100].
    ///
    /// Uses the ceil-based nearest-rank definition: the smallest sample
    /// such that at least `p`% of the data is <= it.  `.round()` here was
    /// a bug -- it could pick a sample *below* the requested percentile
    /// (e.g. p99 of [1..=200] rounded 197.01 down to rank 197 = 198.0,
    /// under which only 98.5% of samples sit).  Ceil never under-reports.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return 0.0;
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).ceil() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn snapshot(&self) -> Vec<f64> {
        self.samples.lock().unwrap().clone()
    }
}

/// Per-tenant terminal counters, surfaced as labeled scrape keys
/// (`tenant_received{tenant="x"}` etc.).  One instance per tenant name,
/// created lazily on first submit and never dropped -- tenant cardinality
/// is operator-bounded (quota config), not client-bounded.
#[derive(Default)]
pub struct TenantCounters {
    pub received: Counter,
    pub completed: Counter,
    pub rejected: Counter,
    pub cancelled: Counter,
    pub deadline: Counter,
    pub failed: Counter,
    /// output tokens attributed to this tenant (terminal accounting)
    pub tokens: Counter,
}

/// The registry the engine and server expose.
#[derive(Default)]
pub struct Metrics {
    pub requests_received: Counter,
    pub requests_completed: Counter,
    pub requests_rejected: Counter,
    /// requests that errored during routing/prefill/decode
    pub requests_failed: Counter,
    /// requests dropped mid-flight by client cancellation
    pub requests_cancelled: Counter,
    /// requests dropped because their per-request deadline expired
    pub requests_deadline_exceeded: Counter,
    pub tokens_generated: Counter,
    pub draft_tokens_accepted: Counter,
    pub verify_calls: Counter,
    pub draft_calls: Counter,
    pub queue_depth: Gauge,
    pub inflight: Gauge,
    /// requests that ran at least one tree-mode iteration
    pub tree_requests: Counter,
    /// candidate nodes drafted across all tree iterations
    pub tree_nodes_drafted: Counter,
    /// tree-mode SD iterations
    pub tree_iterations: Counter,
    /// accepted root-to-leaf path length summed over tree iterations
    /// (a counter, not a histogram: one sample per SD iteration would grow
    /// without bound on a long-lived server)
    pub tree_path_accepted: Counter,
    /// prefix-cache hits: requests whose entire prefill came from a forked
    /// KV snapshot
    pub prefix_cache_hits: Counter,
    /// prefix-cache misses: requests that ran a cold prefill (and filled
    /// the cache, single-flight)
    pub prefix_cache_misses: Counter,
    /// entries dropped by the LRU byte-budget policy
    pub prefix_cache_evictions: Counter,
    /// image encodes served from the cache (or a concurrent single-flight
    /// fill the request waited on)
    pub vision_encode_hits: Counter,
    /// image encodes actually executed
    pub vision_encode_fills: Counter,
    /// bytes currently held by the prefix cache (pixels + encodings + KV
    /// snapshots)
    pub prefix_cache_bytes: Gauge,
    /// entries currently held by the prefix cache (all three tables)
    pub prefix_cache_entries: Gauge,
    /// fused multi-lane ticks executed by the batched engine (single-lane
    /// dispatches take the non-batched path and are not counted here)
    pub batch_ticks: Counter,
    /// decode steps executed inside fused ticks (sum of tick occupancies;
    /// counters, not a histogram: one sample per tick would grow without
    /// bound on a long-lived server -- same rationale as
    /// `tree_path_accepted`.  Mean occupancy = batched_lane_steps /
    /// batch_ticks)
    pub batched_lane_steps: Counter,
    /// configured ganging bound (`EngineConfig::max_batch`; 1 = batching
    /// disabled)
    pub batch_max_lanes: Gauge,
    /// largest fused-tick occupancy observed (running maximum)
    pub batch_occupancy_peak: Gauge,
    /// bytes resident in the paged KV block pool (block content only; the
    /// per-sequence block-table handles are charged to their owners)
    pub kv_pool_bytes: Gauge,
    /// blocks currently allocated in the paged KV pool
    pub kv_pool_blocks: Gauge,
    /// sequence forks served as refcount bumps (no KV copy)
    pub kv_forks: Counter,
    /// blocks copied on first divergent write to a shared block
    pub kv_cow_copies: Counter,
    /// sessions swapped out of the pool under byte-budget pressure
    pub kv_swap_outs: Counter,
    /// swapped-out sessions brought back into the pool
    pub kv_swap_ins: Counter,
    /// preemption passes that swapped out at least one backlogged session
    pub kv_preemptions: Counter,
    pub latency_ms: Histogram,
    pub prefill_ms: Histogram,
    /// image-encode share of prefill time (0 for warm encodes/prefixes)
    pub prefill_encode_ms: Histogram,
    /// prefill time minus the image encode (the text/KV-build share)
    pub prefill_text_ms: Histogram,
    pub per_request_mal: Histogram,
    /// time spent queued before the first dispatch, per terminal request
    /// (rejections record it too -- their queue time is the time to the
    /// rejection decision)
    pub queue_ms: Histogram,
    /// scheduler dispatches consumed per request (prefill + decode steps)
    pub steps_per_request: Histogram,
    /// time-per-output-token: decode wall time over non-prefill tokens
    pub tpot_ms: Histogram,
    /// lazily-created per-tenant counter blocks, keyed by tenant name
    /// (gateway-level `http_*` counters live in `server::http`, which owns
    /// the shedding decisions; tenant accounting lives here because the
    /// engine owns terminal outcomes)
    tenants: Mutex<HashMap<String, Arc<TenantCounters>>>,
    start: Mutex<Option<Instant>>,
}

impl Metrics {
    pub fn new() -> Self {
        let m = Metrics::default();
        *m.start.lock().unwrap() = Some(Instant::now());
        m
    }

    pub fn uptime_secs(&self) -> f64 {
        self.start
            .lock()
            .unwrap()
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn throughput_tokens_per_sec(&self) -> f64 {
        let up = self.uptime_secs();
        if up <= 0.0 {
            return 0.0;
        }
        self.tokens_generated.get() as f64 / up
    }

    /// Counter block for `tenant`, created on first use.  Returns a clone
    /// of the `Arc` so the hot path increments without holding the map
    /// lock.
    pub fn tenant(&self, tenant: &str) -> Arc<TenantCounters> {
        let mut map = self.tenants.lock().unwrap();
        map.entry(tenant.to_string()).or_default().clone()
    }

    /// Aggregate mean accepted length across completed requests.
    pub fn overall_mal(&self) -> f64 {
        let v = self.verify_calls.get();
        if v == 0 {
            return 0.0;
        }
        (self.draft_tokens_accepted.get() + v) as f64 / v as f64
    }

    /// Render a flat name->value map (the server's `metrics` op).
    pub fn render(&self) -> HashMap<String, f64> {
        let mut out = HashMap::new();
        out.insert("requests_received".into(), self.requests_received.get() as f64);
        out.insert("requests_completed".into(), self.requests_completed.get() as f64);
        out.insert("requests_rejected".into(), self.requests_rejected.get() as f64);
        out.insert("requests_failed".into(), self.requests_failed.get() as f64);
        out.insert("requests_cancelled".into(), self.requests_cancelled.get() as f64);
        out.insert(
            "requests_deadline_exceeded".into(),
            self.requests_deadline_exceeded.get() as f64,
        );
        out.insert("tokens_generated".into(), self.tokens_generated.get() as f64);
        out.insert("draft_tokens_accepted".into(), self.draft_tokens_accepted.get() as f64);
        out.insert("verify_calls".into(), self.verify_calls.get() as f64);
        out.insert("draft_calls".into(), self.draft_calls.get() as f64);
        out.insert("queue_depth".into(), self.queue_depth.get() as f64);
        out.insert("inflight".into(), self.inflight.get() as f64);
        // `inflight` counts admitted-but-unfinished sessions; exported under
        // the serving-layer name too
        out.insert("active_sessions".into(), self.inflight.get() as f64);
        out.insert("queue_ms_p50".into(), self.queue_ms.percentile(50.0));
        out.insert("queue_ms_p99".into(), self.queue_ms.percentile(99.0));
        out.insert("steps_per_request_mean".into(), self.steps_per_request.mean());
        out.insert("tpot_ms_p50".into(), self.tpot_ms.percentile(50.0));
        out.insert("tpot_ms_p99".into(), self.tpot_ms.percentile(99.0));
        out.insert("latency_ms_p50".into(), self.latency_ms.percentile(50.0));
        out.insert("latency_ms_p95".into(), self.latency_ms.percentile(95.0));
        out.insert("latency_ms_p99".into(), self.latency_ms.percentile(99.0));
        out.insert("latency_ms_mean".into(), self.latency_ms.mean());
        out.insert("overall_mal".into(), self.overall_mal());
        out.insert("throughput_tps".into(), self.throughput_tokens_per_sec());
        out.insert("uptime_secs".into(), self.uptime_secs());
        out.insert("prefix_cache_hits".into(), self.prefix_cache_hits.get() as f64);
        out.insert("prefix_cache_misses".into(), self.prefix_cache_misses.get() as f64);
        out.insert("prefix_cache_hit_rate".into(), self.prefix_cache_hit_rate());
        out.insert(
            "prefix_cache_evictions".into(),
            self.prefix_cache_evictions.get() as f64,
        );
        out.insert("vision_encode_hits".into(), self.vision_encode_hits.get() as f64);
        out.insert("vision_encode_fills".into(), self.vision_encode_fills.get() as f64);
        out.insert("prefix_cache_bytes".into(), self.prefix_cache_bytes.get() as f64);
        out.insert("prefix_cache_entries".into(), self.prefix_cache_entries.get() as f64);
        out.insert("prefill_ms_mean".into(), self.prefill_ms.mean());
        out.insert("prefill_encode_ms_mean".into(), self.prefill_encode_ms.mean());
        out.insert("prefill_text_ms_mean".into(), self.prefill_text_ms.mean());
        out.insert("batch_ticks".into(), self.batch_ticks.get() as f64);
        out.insert("batched_lane_steps".into(), self.batched_lane_steps.get() as f64);
        out.insert("batch_max_lanes".into(), self.batch_max_lanes.get() as f64);
        out.insert("batch_occupancy_mean".into(), self.batch_occupancy_mean());
        out.insert("batch_occupancy_max".into(), self.batch_occupancy_peak.get() as f64);
        out.insert("kv_pool_bytes".into(), self.kv_pool_bytes.get() as f64);
        out.insert("kv_pool_blocks".into(), self.kv_pool_blocks.get() as f64);
        out.insert("kv_forks".into(), self.kv_forks.get() as f64);
        out.insert("kv_cow_copies".into(), self.kv_cow_copies.get() as f64);
        out.insert("kv_swap_outs".into(), self.kv_swap_outs.get() as f64);
        out.insert("kv_swap_ins".into(), self.kv_swap_ins.get() as f64);
        out.insert("kv_preemptions".into(), self.kv_preemptions.get() as f64);
        out.insert("tree_requests".into(), self.tree_requests.get() as f64);
        out.insert("tree_nodes_drafted".into(), self.tree_nodes_drafted.get() as f64);
        out.insert("tree_iterations".into(), self.tree_iterations.get() as f64);
        out.insert("tree_path_depth_mean".into(), self.tree_path_depth_mean());
        out.insert("branch_utilization".into(), self.branch_utilization());
        for (name, tc) in self.tenants.lock().unwrap().iter() {
            let key = |stat: &str| format!("tenant_{stat}{{tenant=\"{name}\"}}");
            out.insert(key("received"), tc.received.get() as f64);
            out.insert(key("completed"), tc.completed.get() as f64);
            out.insert(key("rejected"), tc.rejected.get() as f64);
            out.insert(key("cancelled"), tc.cancelled.get() as f64);
            out.insert(key("deadline"), tc.deadline.get() as f64);
            out.insert(key("failed"), tc.failed.get() as f64);
            out.insert(key("tokens"), tc.tokens.get() as f64);
        }
        out
    }

    /// Mean lanes per fused tick (0.0 before any multi-lane tick ran).
    pub fn batch_occupancy_mean(&self) -> f64 {
        let ticks = self.batch_ticks.get();
        if ticks == 0 {
            return 0.0;
        }
        self.batched_lane_steps.get() as f64 / ticks as f64
    }

    /// Fraction of admitted prefills served from the prefix cache.
    pub fn prefix_cache_hit_rate(&self) -> f64 {
        let h = self.prefix_cache_hits.get();
        let total = h + self.prefix_cache_misses.get();
        if total == 0 {
            return 0.0;
        }
        h as f64 / total as f64
    }

    /// Mean accepted root-to-leaf path length per tree iteration.
    pub fn tree_path_depth_mean(&self) -> f64 {
        let iters = self.tree_iterations.get();
        if iters == 0 {
            return 0.0;
        }
        self.tree_path_accepted.get() as f64 / iters as f64
    }

    /// Aggregate fraction of drafted tree nodes that landed on an accepted
    /// path (tree-mode drafting efficiency).
    pub fn branch_utilization(&self) -> f64 {
        let drafted = self.tree_nodes_drafted.get();
        if drafted == 0 {
            return 0.0;
        }
        self.tree_path_accepted.get() as f64 / drafted as f64
    }
}

/// True when `key` names a monotonically increasing scrape counter --
/// the keys `scrape_delta` differences.  A `replica<i>_` prefix and a
/// `{tenant="..."}` label are stripped first so per-replica and
/// per-tenant copies classify like their flat equivalents; everything
/// else (gauges, percentiles, means, config constants) is point-in-time
/// and keeps its end-of-window value.
fn monotone_scrape_key(key: &str) -> bool {
    let mut k = key;
    if let Some(rest) = k.strip_prefix("replica") {
        if let Some(us) = rest.find('_') {
            if us > 0 && rest[..us].bytes().all(|b| b.is_ascii_digit()) {
                k = &rest[us + 1..];
            }
        }
    }
    let k = k.split('{').next().unwrap_or(k);
    matches!(
        k,
        "requests_received"
            | "requests_completed"
            | "requests_rejected"
            | "requests_failed"
            | "requests_cancelled"
            | "requests_deadline_exceeded"
            | "tokens_generated"
            | "draft_tokens_accepted"
            | "verify_calls"
            | "draft_calls"
            | "prefix_cache_hits"
            | "prefix_cache_misses"
            | "prefix_cache_evictions"
            | "vision_encode_hits"
            | "vision_encode_fills"
            | "batch_ticks"
            | "batched_lane_steps"
            | "kv_forks"
            | "kv_cow_copies"
            | "kv_swap_outs"
            | "kv_swap_ins"
            | "kv_preemptions"
            | "tree_requests"
            | "tree_nodes_drafted"
            | "tree_iterations"
            | "cluster_spills"
            | "cluster_routed_affinity"
            | "cluster_routed_blind"
            | "routed"
            | "tenant_received"
            | "tenant_completed"
            | "tenant_rejected"
            | "tenant_cancelled"
            | "tenant_deadline"
            | "tenant_failed"
            | "tenant_tokens"
            | "http_requests"
            | "http_shed_429"
            | "http_shed_503"
    )
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Difference two scrape snapshots into a per-window view: monotone
/// counters become `after - before` (so one long-lived engine can serve
/// many measured runs, the scenario-suite pattern), gauges and latency
/// percentiles keep their end-of-window value, and the derived ratios
/// (`prefix_cache_hit_rate`, `batch_occupancy_mean`, `overall_mal`,
/// including `replica<i>_` copies) are recomputed from the window's own
/// deltas rather than inherited from lifetime totals.  Also derives
/// `vision_encode_hit_rate` (hits / (hits + fills) over the window),
/// which has no lifetime scrape equivalent.  Keys absent from `before`
/// delta from zero.
pub fn scrape_delta(
    before: &HashMap<String, f64>,
    after: &HashMap<String, f64>,
) -> HashMap<String, f64> {
    let mut out: HashMap<String, f64> = after
        .iter()
        .map(|(k, &v)| {
            let v = if monotone_scrape_key(k) {
                v - before.get(k).copied().unwrap_or(0.0)
            } else {
                v
            };
            (k.clone(), v)
        })
        .collect();
    let get = |m: &HashMap<String, f64>, k: String| m.get(&k).copied().unwrap_or(0.0);
    let derived: Vec<String> = out
        .keys()
        .filter(|k| {
            k.ends_with("prefix_cache_hit_rate")
                || k.ends_with("batch_occupancy_mean")
                || k.ends_with("overall_mal")
        })
        .cloned()
        .collect();
    for key in derived {
        let v = if let Some(p) = key.strip_suffix("prefix_cache_hit_rate") {
            let h = get(&out, format!("{p}prefix_cache_hits"));
            ratio(h, h + get(&out, format!("{p}prefix_cache_misses")))
        } else if let Some(p) = key.strip_suffix("batch_occupancy_mean") {
            ratio(get(&out, format!("{p}batched_lane_steps")), get(&out, format!("{p}batch_ticks")))
        } else {
            let p = key.strip_suffix("overall_mal").unwrap_or("");
            let vc = get(&out, format!("{p}verify_calls"));
            ratio(get(&out, format!("{p}draft_tokens_accepted")) + vc, vc)
        };
        out.insert(key, v);
    }
    if after.contains_key("vision_encode_hits") {
        let h = get(&out, "vision_encode_hits".into());
        let f = get(&out, "vision_encode_fills".into());
        out.insert("vision_encode_hit_rate".into(), ratio(h, h + f));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrape_delta_windows_counters_and_recomputes_ratios() {
        let mut before = HashMap::new();
        let mut after = HashMap::new();
        for (k, b, a) in [
            ("requests_completed", 10.0, 16.0),
            ("prefix_cache_hits", 8.0, 11.0),
            ("prefix_cache_misses", 2.0, 3.0),
            ("prefix_cache_hit_rate", 0.8, 11.0 / 14.0),
            ("inflight", 1.0, 2.0),
            ("latency_ms_p50", 5.0, 7.0),
            ("replica1_tokens_generated", 100.0, 140.0),
            ("replica1_prefix_cache_hits", 5.0, 9.0),
            ("replica1_prefix_cache_misses", 5.0, 7.0),
            ("replica1_prefix_cache_hit_rate", 0.5, 9.0 / 16.0),
            ("tenant_tokens{tenant=\"bulk\"}", 50.0, 80.0),
            ("vision_encode_hits", 4.0, 6.0),
            ("vision_encode_fills", 4.0, 5.0),
            ("batch_ticks", 10.0, 10.0),
            ("batched_lane_steps", 30.0, 30.0),
            ("batch_occupancy_mean", 3.0, 3.0),
            ("verify_calls", 10.0, 14.0),
            ("draft_tokens_accepted", 20.0, 30.0),
            ("overall_mal", 3.0, 44.0 / 14.0),
        ] {
            before.insert(k.to_string(), b);
            after.insert(k.to_string(), a);
        }
        // a key absent before deltas from zero
        after.insert("cluster_spills".into(), 3.0);
        let d = scrape_delta(&before, &after);
        assert_eq!(d["requests_completed"], 6.0);
        assert_eq!(d["replica1_tokens_generated"], 40.0);
        assert_eq!(d["tenant_tokens{tenant=\"bulk\"}"], 30.0);
        assert_eq!(d["cluster_spills"], 3.0);
        // gauges and percentiles keep their end-of-window value
        assert_eq!(d["inflight"], 2.0);
        assert_eq!(d["latency_ms_p50"], 7.0);
        // ratios recomputed from the window's own deltas, flat and
        // per-replica: 3/(3+1) and 4/(4+2)
        assert!((d["prefix_cache_hit_rate"] - 0.75).abs() < 1e-12);
        assert!((d["replica1_prefix_cache_hit_rate"] - 4.0 / 6.0).abs() < 1e-12);
        // derived encode hit rate over the window: 2 hits, 1 fill
        assert!((d["vision_encode_hit_rate"] - 2.0 / 3.0).abs() < 1e-12);
        // zero-width windows give 0, not NaN
        assert_eq!(d["batch_occupancy_mean"], 0.0);
        // mal over the window: (10 + 4) / 4
        assert!((d["overall_mal"] - 3.5).abs() < 1e-12);
    }

    #[test]
    fn counters_and_gauges() {
        let m = Metrics::new();
        m.requests_received.inc();
        m.requests_received.add(4);
        assert_eq!(m.requests_received.get(), 5);
        m.queue_depth.set(3);
        m.queue_depth.add(-1);
        assert_eq!(m.queue_depth.get(), 2);
    }

    #[test]
    fn histogram_percentiles() {
        let h = Histogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile(95.0) - 95.0).abs() <= 1.0);
        assert_eq!(h.percentile(100.0), 100.0);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentile_is_ceil_nearest_rank() {
        // Hand-computed ranks on 1..=10: rank = ceil(p/100 * 9).
        let h = Histogram::default();
        for i in (1..=10).rev() {
            h.record(i as f64); // reverse insertion: percentile must sort
        }
        assert_eq!(h.percentile(0.0), 1.0);
        assert_eq!(h.percentile(50.0), 6.0); // ceil(4.5) = 5 -> 6.0
        assert_eq!(h.percentile(90.0), 10.0); // ceil(8.1) = 9 -> 10.0
        assert_eq!(h.percentile(99.0), 10.0); // ceil(8.91) = 9 -> 10.0
        assert_eq!(h.percentile(100.0), 10.0);
    }

    #[test]
    fn percentile_never_under_reports() {
        // Regression for the `.round()` nearest-rank bug: on 60 samples,
        // p99's fractional rank is 0.99 * 59 = 58.41; rounding DOWN picked
        // s[58] = 59.0, below which only 59/60 = 98.3% of samples sit.
        // Ceil picks s[59] = 60.0.
        let h = Histogram::default();
        for i in 1..=60 {
            h.record(i as f64);
        }
        let p99 = h.percentile(99.0);
        assert_eq!(p99, 60.0);
        let frac_below_or_eq =
            h.snapshot().iter().filter(|&&v| v <= p99).count() as f64 / 60.0;
        assert!(frac_below_or_eq >= 0.99, "p99 under-reports: {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn overall_mal() {
        let m = Metrics::new();
        m.verify_calls.add(10);
        m.draft_tokens_accepted.add(22);
        assert!((m.overall_mal() - 3.2).abs() < 1e-9);
    }

    #[test]
    fn render_contains_keys() {
        let m = Metrics::new();
        let r = m.render();
        assert!(r.contains_key("overall_mal"));
        assert!(r.contains_key("latency_ms_p99"));
        assert!(r.contains_key("tree_path_depth_mean"));
        assert!(r.contains_key("branch_utilization"));
        assert!(r.contains_key("active_sessions"));
        assert!(r.contains_key("steps_per_request_mean"));
        assert!(r.contains_key("tpot_ms_p99"));
        assert!(r.contains_key("requests_cancelled"));
        assert!(r.contains_key("requests_deadline_exceeded"));
        assert!(r.contains_key("queue_ms_p99"));
        assert!(r.contains_key("prefix_cache_hit_rate"));
        assert!(r.contains_key("prefix_cache_bytes"));
        assert!(r.contains_key("prefix_cache_evictions"));
        assert!(r.contains_key("vision_encode_fills"));
        assert!(r.contains_key("prefill_encode_ms_mean"));
        assert!(r.contains_key("prefill_text_ms_mean"));
        assert!(r.contains_key("batch_ticks"));
        assert!(r.contains_key("batched_lane_steps"));
        assert!(r.contains_key("batch_max_lanes"));
        assert!(r.contains_key("batch_occupancy_mean"));
        assert!(r.contains_key("batch_occupancy_max"));
        assert!(r.contains_key("kv_pool_bytes"));
        assert!(r.contains_key("kv_pool_blocks"));
        assert!(r.contains_key("kv_forks"));
        assert!(r.contains_key("kv_cow_copies"));
        assert!(r.contains_key("kv_swap_outs"));
        assert!(r.contains_key("kv_swap_ins"));
        assert!(r.contains_key("kv_preemptions"));
    }

    #[test]
    fn tenant_counters_render_labeled_keys() {
        let m = Metrics::new();
        m.tenant("gold").received.inc();
        m.tenant("gold").tokens.add(5);
        m.tenant("free").rejected.inc();
        let r = m.render();
        assert_eq!(r["tenant_received{tenant=\"gold\"}"], 1.0);
        assert_eq!(r["tenant_tokens{tenant=\"gold\"}"], 5.0);
        assert_eq!(r["tenant_rejected{tenant=\"free\"}"], 1.0);
        assert_eq!(r["tenant_completed{tenant=\"gold\"}"], 0.0);
    }

    #[test]
    fn batch_occupancy_aggregates() {
        let m = Metrics::new();
        assert_eq!(m.batch_occupancy_mean(), 0.0);
        m.batch_ticks.inc();
        m.batch_ticks.inc();
        m.batched_lane_steps.add(3);
        m.batched_lane_steps.add(5);
        m.batch_occupancy_peak.max_with(3);
        m.batch_occupancy_peak.max_with(5);
        m.batch_occupancy_peak.max_with(4); // running max keeps 5
        m.batch_max_lanes.set(8);
        let r = m.render();
        assert_eq!(r["batch_ticks"], 2.0);
        assert_eq!(r["batched_lane_steps"], 8.0);
        assert_eq!(r["batch_max_lanes"], 8.0);
        assert!((r["batch_occupancy_mean"] - 4.0).abs() < 1e-12);
        assert_eq!(r["batch_occupancy_max"], 5.0);
    }

    #[test]
    fn prefix_cache_hit_rate_aggregates() {
        let m = Metrics::new();
        assert_eq!(m.prefix_cache_hit_rate(), 0.0);
        m.prefix_cache_hits.add(3);
        m.prefix_cache_misses.add(1);
        assert!((m.prefix_cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn branch_utilization_aggregates() {
        let m = Metrics::new();
        assert_eq!(m.branch_utilization(), 0.0);
        assert_eq!(m.tree_path_depth_mean(), 0.0);
        m.tree_nodes_drafted.add(20);
        m.tree_iterations.add(2);
        m.tree_path_accepted.add(4);
        m.tree_path_accepted.add(6);
        assert!((m.branch_utilization() - 0.5).abs() < 1e-12);
        assert!((m.tree_path_depth_mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_concurrent_records() {
        use std::sync::Arc;
        let h = Arc::new(Histogram::default());
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        h.record((t * 1000 + i) as f64);
                    }
                })
            })
            .collect();
        for t in hs {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
