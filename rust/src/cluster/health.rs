//! Per-replica health snapshots: the cheap load signal the router spills
//! and load-balances on.  A snapshot is three atomic reads per replica
//! (scheduler depth, inflight gauge, KV pool residency) -- no locks on the
//! request path beyond the scheduler's own.

/// Point-in-time load/health of one engine replica.
#[derive(Debug, Clone)]
pub struct ReplicaHealth {
    pub replica: usize,
    /// Drain mode: the replica finishes in-flight work but admits nothing
    /// new (rolling-restart support).
    pub draining: bool,
    /// Scheduler depth: queued admissions plus runnable session steps.
    pub queue_depth: usize,
    /// Admitted, unfinished sessions (the engine's `inflight` gauge).
    pub active_sessions: i64,
    /// Bytes resident in the replica's paged KV pool.
    pub kv_pool_bytes: i64,
    /// The replica's KV pool byte budget (0 when paging is off).
    pub kv_pool_budget: usize,
}

impl ReplicaHealth {
    /// Scalar in-system pressure used for least-loaded spill decisions.
    /// Queue depth and active sessions dominate (each unit is one request
    /// somewhere in the system); the KV pool residency fraction is a
    /// strictly-sub-unit tiebreak between equally-queued replicas, so
    /// memory pressure steers ties without overriding queueing.
    pub fn load(&self) -> f64 {
        let q = self.queue_depth as f64 + self.active_sessions.max(0) as f64;
        let kv = if self.kv_pool_budget > 0 {
            (self.kv_pool_bytes.max(0) as f64 / self.kv_pool_budget as f64).min(0.99)
        } else {
            0.0
        };
        q + kv
    }

    /// Saturation test for the affinity router: spill away from this
    /// replica once its queue depth reaches `spill_depth`.
    pub fn saturated(&self, spill_depth: usize) -> bool {
        self.queue_depth >= spill_depth.max(1)
    }
}

/// Index of the least-loaded replica (ties break on the lower index, so
/// the choice is deterministic).  `admitting_only` skips draining
/// replicas; with it set and every replica draining, returns `None`.
pub fn least_loaded(health: &[ReplicaHealth], admitting_only: bool) -> Option<usize> {
    health
        .iter()
        .filter(|h| !admitting_only || !h.draining)
        .min_by(|a, b| a.load().partial_cmp(&b.load()).unwrap_or(std::cmp::Ordering::Equal))
        .map(|h| h.replica)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health(replica: usize, queue: usize, active: i64, kv: i64) -> ReplicaHealth {
        ReplicaHealth {
            replica,
            draining: false,
            queue_depth: queue,
            active_sessions: active,
            kv_pool_bytes: kv,
            kv_pool_budget: 1000,
        }
    }

    #[test]
    fn load_orders_by_queue_then_kv_pressure() {
        assert!(health(0, 3, 0, 0).load() > health(1, 1, 1, 0).load());
        // same in-system count: KV residency breaks the tie ...
        assert!(health(0, 2, 0, 900).load() > health(1, 2, 0, 100).load());
        // ... but never outweighs a whole queued request
        assert!(health(0, 2, 0, 999).load() < health(1, 3, 0, 0).load());
    }

    #[test]
    fn saturation_threshold() {
        assert!(!health(0, 7, 0, 0).saturated(8));
        assert!(health(0, 8, 0, 0).saturated(8));
        // spill_depth 0 is clamped to 1: an idle replica never saturates
        assert!(!health(0, 0, 0, 0).saturated(0));
        assert!(health(0, 1, 0, 0).saturated(0));
    }

    #[test]
    fn least_loaded_respects_drain_and_breaks_ties_low() {
        let mut hs = vec![health(0, 2, 0, 0), health(1, 0, 0, 0), health(2, 0, 0, 0)];
        // tie between 1 and 2 -> lower index wins (deterministic)
        assert_eq!(least_loaded(&hs, true), Some(1));
        hs[1].draining = true;
        assert_eq!(least_loaded(&hs, true), Some(2));
        hs[2].draining = true;
        hs[0].draining = true;
        assert_eq!(least_loaded(&hs, true), None);
        // ignoring drain still finds the overall minimum
        assert_eq!(least_loaded(&hs, false), Some(1));
    }
}
