//! Prefix-affinity placement: deterministic request -> replica mapping.
//!
//! The affinity key hashes the image content address (and optionally the
//! first bytes of the prompt); rendezvous (highest-random-weight) hashing
//! turns the key into a stable replica preference order.  Rendezvous keeps
//! placement stable under topology change: draining one replica only
//! remaps the keys whose first choice went away, instead of reshuffling
//! every key the way `key % n` would.

use super::health::{least_loaded, ReplicaHealth};

/// splitmix64 finalizer: cheap full-avalanche mixing.
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Affinity key for a request.  The image content address dominates --
/// that is what the vision-encode cache keys on, so all requests over one
/// image land where its encoding is warm regardless of prompt.  A nonzero
/// `prompt_bytes` additionally hashes the prompt's first bytes (byte
/// prefix, so no UTF-8 boundary concerns), sharding one very hot image
/// over several replicas while keeping per-conversation affinity.
pub fn affinity_key(image_id: u64, prompt: &str, prompt_bytes: usize) -> u64 {
    let mut h = mix64(image_id ^ 0x9E37_79B9_7F4A_7C15);
    if prompt_bytes > 0 {
        for &b in prompt.as_bytes().iter().take(prompt_bytes) {
            h = mix64(h ^ b as u64);
        }
    }
    h
}

/// Rendezvous score of `key` on `replica`; placement prefers replicas in
/// descending score order.
pub fn rendezvous_score(key: u64, replica: usize) -> u64 {
    mix64(key ^ mix64(replica as u64 ^ 0xA076_1D64_78BD_642F))
}

/// Replica indices in affinity-preference order (best first).
pub fn preference_order(key: u64, replicas: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..replicas).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(rendezvous_score(key, i)));
    order
}

/// Where an affinity-routed request landed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// The rendezvous-preferred replica (warm caches for this key).
    Affinity(usize),
    /// The affinity target was saturated or draining: least-loaded spill.
    Spill(usize),
}

impl Placement {
    pub fn replica(self) -> usize {
        match self {
            Placement::Affinity(i) | Placement::Spill(i) => i,
        }
    }
}

/// Affinity placement over a health snapshot: steer to the highest-ranked
/// replica still admitting; when it is saturated (queue depth at or past
/// `spill_depth`) spill to the least-loaded admitting replica.  A fully
/// draining cluster falls back to the least-loaded replica overall, so a
/// rolling restart can never strand a request.
pub fn place_affinity(key: u64, health: &[ReplicaHealth], spill_depth: usize) -> Placement {
    let order = preference_order(key, health.len());
    if let Some(t) = order.into_iter().find(|&i| !health[i].draining) {
        if !health[t].saturated(spill_depth) {
            return Placement::Affinity(t);
        }
    }
    let spill = least_loaded(health, true)
        .or_else(|| least_loaded(health, false))
        .unwrap_or(0);
    Placement::Spill(spill)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(replica: usize) -> ReplicaHealth {
        ReplicaHealth {
            replica,
            draining: false,
            queue_depth: 0,
            active_sessions: 0,
            kv_pool_bytes: 0,
            kv_pool_budget: 1 << 20,
        }
    }

    #[test]
    fn affinity_key_is_deterministic_and_image_dominated() {
        let a = affinity_key(42, "w5 w6", 0);
        assert_eq!(a, affinity_key(42, "w5 w6", 0));
        // prompt_bytes = 0: the prompt never enters the key
        assert_eq!(a, affinity_key(42, "completely different prompt", 0));
        assert_ne!(a, affinity_key(43, "w5 w6", 0));
        // a nonzero prefix shards by prompt
        assert_ne!(affinity_key(42, "aaaa", 8), affinity_key(42, "bbbb", 8));
        // ... but only the prefix: bytes past the cut are ignored
        assert_eq!(affinity_key(42, "aaaa-x", 4), affinity_key(42, "aaaa-y", 4));
    }

    #[test]
    fn preference_order_is_a_permutation_and_spreads_keys() {
        let mut first_choice = [0usize; 4];
        for key in 0..256u64 {
            let order = preference_order(affinity_key(key, "", 0), 4);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3]);
            first_choice[order[0]] += 1;
        }
        // roughly balanced: no replica owns fewer than 1/8 or more than
        // 1/2 of 256 keys under a decent hash
        for &c in &first_choice {
            assert!((32..=128).contains(&c), "skewed first choices: {first_choice:?}");
        }
    }

    #[test]
    fn rendezvous_is_stable_under_replica_removal() {
        // removing the last replica must only remap keys whose first
        // choice WAS that replica -- everyone else keeps their placement
        for key in 0..512u64 {
            let k = affinity_key(key, "", 0);
            let with4 = preference_order(k, 4)[0];
            let with3 = preference_order(k, 3)[0];
            if with4 != 3 {
                assert_eq!(with4, with3, "key {key} moved although replica 3 was not its target");
            }
        }
    }

    #[test]
    fn place_affinity_steers_spills_and_respects_drain() {
        let key = affinity_key(7, "", 0);
        let mut health: Vec<ReplicaHealth> = (0..4).map(idle).collect();
        let target = preference_order(key, 4)[0];
        assert_eq!(place_affinity(key, &health, 8), Placement::Affinity(target));

        // saturated target spills to the least-loaded admitting replica
        health[target].queue_depth = 8;
        for (i, h) in health.iter_mut().enumerate() {
            if i != target {
                h.queue_depth = 2 + i; // distinct loads; min is deterministic
            }
        }
        let spilled = place_affinity(key, &health, 8);
        assert!(matches!(spilled, Placement::Spill(_)));
        assert_ne!(spilled.replica(), target);

        // draining target: next-ranked admitting replica takes over even
        // when idle
        let mut health: Vec<ReplicaHealth> = (0..4).map(idle).collect();
        health[target].draining = true;
        let fallback = place_affinity(key, &health, 8);
        assert!(matches!(fallback, Placement::Affinity(_)));
        assert_ne!(fallback.replica(), target);
        assert_eq!(fallback.replica(), preference_order(key, 4)[1]);

        // fully draining cluster still places (rolling restart must not
        // strand requests)
        for h in &mut health {
            h.draining = true;
        }
        health[2].queue_depth = 0;
        health[0].queue_depth = 5;
        health[1].queue_depth = 5;
        health[3].queue_depth = 5;
        assert_eq!(place_affinity(key, &health, 8), Placement::Spill(2));
    }
}
