//! Cache-aware multi-replica scale-out (`docs/cluster.md`).
//!
//! A `ClusterEngine` runs N independent `coordinator::Engine` replicas --
//! each with its own scheduler, worker pool, prefix cache, and paged KV
//! pool -- behind a router.  The default `RoutingPolicy::Affinity` steers
//! each request to the replica already holding its vision encoding and
//! prefix KV snapshots: the (image content address, prompt prefix) key is
//! rendezvous-hashed over the replica set (`placement`), so a hot image's
//! requests all land where its caches are warm, and draining a replica
//! only remaps the keys it owned.  When the affinity target is saturated
//! the request spills to the least-loaded admitting replica (`health`).
//!
//! Replicas share one request-id space (cancel-by-id needs no routing
//! state) and the scripted backend decodes each request independently, so
//! responses are bit-identical regardless of which replica serves them --
//! `rust/tests/cluster_integration.rs` pins replicas=1 vs replicas=4
//! equality, streaming and cancel included.  The cluster implements
//! `EngineFront`, so `server::Server` serves it over the unchanged wire
//! protocol; the `--replicas` knob changes topology, never the protocol.

pub mod health;
pub mod placement;

pub use health::{least_loaded, ReplicaHealth};
pub use placement::{
    affinity_key, place_affinity, preference_order, rendezvous_score, Placement,
};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};

use anyhow::Result;

use crate::cache;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::front::EngineFront;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::stream::UpdateReceiver;
use crate::manifest::Manifest;
use crate::metrics::Counter;
use crate::util::rng::Rng;

/// How the front end picks a replica for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Prefix-affinity placement with least-loaded spill (default): warm
    /// caches win as long as the target replica keeps up.
    Affinity,
    /// Cache-blind round-robin (A/B baseline for the cluster bench).
    RoundRobin,
    /// Cache-blind seeded-uniform choice (A/B baseline).
    Random,
}

#[derive(Clone)]
pub struct ClusterConfig {
    /// Engine replica count (clamped to >= 1).
    pub replicas: usize,
    pub routing: RoutingPolicy,
    /// Prompt bytes folded into the affinity key.  0 (default) keys on the
    /// image alone, maximizing vision-encode reuse across prompts; raise
    /// it to shard one very hot image over several replicas at the cost of
    /// per-prompt cache locality.
    pub affinity_prompt_bytes: usize,
    /// Queue depth at which the affinity target is considered saturated
    /// and requests spill to the least-loaded admitting replica.
    pub spill_depth: usize,
    /// Seed for the `Random` routing policy (unused by the others).
    pub seed: u64,
    /// Per-replica engine configuration (each replica gets a clone).
    pub engine: EngineConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            routing: RoutingPolicy::Affinity,
            affinity_prompt_bytes: 0,
            spill_depth: 32,
            seed: 0,
            engine: EngineConfig::default(),
        }
    }
}

struct Replica {
    engine: Engine,
    /// Drain mode: excluded from placement; in-flight work finishes.
    draining: AtomicBool,
    /// Requests this replica has been routed (admission outcome aside).
    routed: Counter,
}

/// N engine replicas behind a prefix-affinity router (see module docs).
pub struct ClusterEngine {
    replicas: Vec<Replica>,
    routing: RoutingPolicy,
    affinity_prompt_bytes: usize,
    spill_depth: usize,
    /// `ClusterConfig::engine.kv_pool_bytes`, kept for health snapshots.
    kv_pool_budget: usize,
    /// One id space across all replicas: cancel-by-id stays unambiguous
    /// and needs no routing-table lookup.
    next_id: AtomicU64,
    rr: AtomicUsize,
    rng: Mutex<Rng>,
    routed_affinity: Counter,
    spills: Counter,
    routed_blind: Counter,
}

impl ClusterEngine {
    /// Start `cfg.replicas` engines over one artifacts directory.  Each
    /// replica loads its own `ModelSet` (own compiled executables, own
    /// caches) so replicas share nothing but the id space.
    pub fn start(artifacts_dir: &str, cfg: ClusterConfig) -> Result<ClusterEngine> {
        let n = cfg.replicas.max(1);
        let mut replicas = Vec::with_capacity(n);
        for _ in 0..n {
            replicas.push(Replica {
                engine: Engine::start(artifacts_dir, cfg.engine.clone())?,
                draining: AtomicBool::new(false),
                routed: Counter::default(),
            });
        }
        Ok(ClusterEngine {
            replicas,
            routing: cfg.routing,
            affinity_prompt_bytes: cfg.affinity_prompt_bytes,
            spill_depth: cfg.spill_depth,
            kv_pool_budget: if cfg.engine.paged_kv { cfg.engine.kv_pool_bytes } else { 0 },
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            rng: Mutex::new(Rng::seeded(cfg.seed)),
            routed_affinity: Counter::default(),
            spills: Counter::default(),
            routed_blind: Counter::default(),
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// Direct access to one replica's engine (tests, benches, drain ops).
    pub fn replica(&self, idx: usize) -> &Engine {
        &self.replicas[idx].engine
    }

    /// Put a replica in drain mode: the router stops placing new requests
    /// on it while its in-flight sessions run to completion (rolling
    /// restart).  Returns false for an out-of-range index.
    pub fn drain(&self, idx: usize) -> bool {
        match self.replicas.get(idx) {
            Some(r) => {
                r.draining.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Readmit a drained replica.  Rendezvous placement is topology-stable,
    /// so its old affinity keys come straight back to its warm caches.
    pub fn undrain(&self, idx: usize) -> bool {
        match self.replicas.get(idx) {
            Some(r) => {
                r.draining.store(false, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    pub fn is_draining(&self, idx: usize) -> bool {
        self.replicas
            .get(idx)
            .map(|r| r.draining.load(Ordering::Relaxed))
            .unwrap_or(false)
    }

    /// Cheap per-replica load snapshot: three atomic reads per replica, no
    /// queue locks beyond the scheduler's own length read.
    pub fn health(&self) -> Vec<ReplicaHealth> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaHealth {
                replica: i,
                draining: r.draining.load(Ordering::Relaxed),
                queue_depth: r.engine.queue_len(),
                active_sessions: r.engine.metrics.inflight.get(),
                kv_pool_bytes: r.engine.metrics.kv_pool_bytes.get(),
                kv_pool_budget: self.kv_pool_budget,
            })
            .collect()
    }

    /// Pick the serving replica for a request (the placement decision
    /// alone; submission happens in `run`/`submit_streaming`).  Draining
    /// replicas are skipped under every policy; a fully draining cluster
    /// falls back to the least-loaded replica so nothing is stranded.
    pub fn route(&self, req: &Request) -> usize {
        let n = self.replicas.len();
        if n == 1 {
            return 0;
        }
        let health = self.health();
        match self.routing {
            RoutingPolicy::Affinity => {
                // same content-addressing rule as engine admission: inline
                // pixels hash to their id; id-only requests reuse it
                let image_id = if req.image.is_empty() {
                    req.image_id.unwrap_or(0)
                } else {
                    cache::image_hash(&req.image)
                };
                let key = affinity_key(image_id, &req.prompt, self.affinity_prompt_bytes);
                match place_affinity(key, &health, self.spill_depth) {
                    Placement::Affinity(i) => {
                        self.routed_affinity.inc();
                        i
                    }
                    Placement::Spill(i) => {
                        self.spills.inc();
                        i
                    }
                }
            }
            RoutingPolicy::RoundRobin => {
                self.routed_blind.inc();
                for _ in 0..n {
                    let i = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                    if !health[i].draining {
                        return i;
                    }
                }
                least_loaded(&health, false).unwrap_or(0)
            }
            RoutingPolicy::Random => {
                self.routed_blind.inc();
                let mut rng = self.rng.lock().unwrap();
                for _ in 0..4 * n {
                    let i = rng.range(n);
                    if !health[i].draining {
                        return i;
                    }
                }
                least_loaded(&health, false).unwrap_or(0)
            }
        }
    }

    fn place(&self, req: &Request) -> &Replica {
        let r = &self.replicas[self.route(req)];
        r.routed.inc();
        r
    }

    /// Route + submit; the final response arrives on the returned channel.
    /// Per-replica backpressure applies: a full target queue yields the
    /// engine's immediate rejected response.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<Response> {
        self.place(&req).engine.submit(req)
    }

    /// Route + submit for streaming delivery.
    pub fn submit_streaming(&self, req: Request) -> UpdateReceiver {
        self.place(&req).engine.submit_streaming(req)
    }

    /// Route + submit + wait.
    pub fn run(&self, req: Request) -> Response {
        self.place(&req).engine.run(req)
    }

    /// Cancel anywhere in the cluster.  Ids are unique across replicas, so
    /// broadcasting is exact: at most one replica knows the id.
    pub fn cancel(&self, id: u64) -> bool {
        self.replicas.iter().any(|r| r.engine.cancel(id))
    }

    pub fn next_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Total scheduler depth across replicas.
    pub fn queue_len(&self) -> usize {
        self.replicas.iter().map(|r| r.engine.queue_len()).sum()
    }

    /// Cluster metrics: the flat per-engine scrape rolled up across
    /// replicas under the same key names (so existing dashboards read a
    /// cluster exactly like a single engine), plus `cluster_*` routing
    /// counters and the full per-replica maps under `replica{i}_`
    /// prefixes.  Counters and additive gauges are summed; derived ratios
    /// (hit rate, overall MAL) are recomputed from the summed numerators
    /// and denominators; percentile/mean keys take the max over replicas
    /// -- an upper bound on the true cluster percentile, which cannot be
    /// recomputed from per-replica summaries.
    pub fn scrape(&self) -> HashMap<String, f64> {
        // keys aggregated by summation: counters and additive gauges.
        // throughput_tps sums too: replicas start together, so equal
        // uptimes make the sum the aggregate rate.
        const SUMMED: &[&str] = &[
            "requests_received",
            "requests_completed",
            "requests_rejected",
            "requests_failed",
            "requests_cancelled",
            "requests_deadline_exceeded",
            "tokens_generated",
            "draft_tokens_accepted",
            "verify_calls",
            "draft_calls",
            "queue_depth",
            "inflight",
            "active_sessions",
            "throughput_tps",
            "prefix_cache_hits",
            "prefix_cache_misses",
            "prefix_cache_evictions",
            "vision_encode_hits",
            "vision_encode_fills",
            "prefix_cache_bytes",
            "prefix_cache_entries",
            "batch_ticks",
            "batched_lane_steps",
            "kv_pool_bytes",
            "kv_pool_blocks",
            "kv_forks",
            "kv_cow_copies",
            "kv_swap_outs",
            "kv_swap_ins",
            "kv_preemptions",
            "tree_requests",
            "tree_nodes_drafted",
            "tree_iterations",
        ];
        // keys aggregated by max: per-replica percentiles/means cannot be
        // merged exactly, so report the worst replica (upper bound).
        const MAXED: &[&str] = &[
            "queue_ms_p50",
            "queue_ms_p99",
            "steps_per_request_mean",
            "tpot_ms_p50",
            "tpot_ms_p99",
            "latency_ms_p50",
            "latency_ms_p95",
            "latency_ms_p99",
            "latency_ms_mean",
            "prefill_ms_mean",
            "prefill_encode_ms_mean",
            "prefill_text_ms_mean",
            "batch_max_lanes",
            "batch_occupancy_mean",
            "batch_occupancy_max",
            "tree_path_depth_mean",
            "branch_utilization",
            "uptime_secs",
        ];
        let scrapes: Vec<HashMap<String, f64>> =
            self.replicas.iter().map(|r| r.engine.scrape()).collect();
        let mut out = HashMap::new();
        let get = |s: &HashMap<String, f64>, k: &str| s.get(k).copied().unwrap_or(0.0);
        for &k in SUMMED {
            out.insert(k.to_string(), scrapes.iter().map(|s| get(s, k)).sum());
        }
        for &k in MAXED {
            let v = scrapes.iter().map(|s| get(s, k)).fold(0.0, f64::max);
            out.insert(k.to_string(), v);
        }
        // per-tenant labeled keys (`tenant_received{tenant="x"}` ...) are
        // dynamic -- one set per tenant name -- so they are summed by
        // prefix scan instead of being listed in SUMMED
        for s in &scrapes {
            for (k, v) in s {
                if k.starts_with("tenant_") {
                    *out.entry(k.clone()).or_insert(0.0) += v;
                }
            }
        }
        // derived ratios recomputed from the summed parts (a mean of
        // per-replica ratios would weight an idle replica like a busy one)
        let hits = out["prefix_cache_hits"];
        let lookups = hits + out["prefix_cache_misses"];
        out.insert(
            "prefix_cache_hit_rate".into(),
            if lookups > 0.0 { hits / lookups } else { 0.0 },
        );
        let verify = out["verify_calls"];
        out.insert(
            "overall_mal".into(),
            if verify > 0.0 { (out["draft_tokens_accepted"] + verify) / verify } else { 0.0 },
        );
        // routing-layer counters (cluster-only keys)
        out.insert("cluster_replicas".into(), self.replicas.len() as f64);
        let draining = self
            .replicas
            .iter()
            .filter(|r| r.draining.load(Ordering::Relaxed))
            .count();
        out.insert("cluster_draining".into(), draining as f64);
        out.insert("cluster_spills".into(), self.spills.get() as f64);
        out.insert("cluster_routed_affinity".into(), self.routed_affinity.get() as f64);
        out.insert("cluster_routed_blind".into(), self.routed_blind.get() as f64);
        // full per-replica maps for drill-down
        for (i, (r, s)) in self.replicas.iter().zip(&scrapes).enumerate() {
            for (k, v) in s {
                out.insert(format!("replica{i}_{k}"), *v);
            }
            out.insert(
                format!("replica{i}_draining"),
                r.draining.load(Ordering::Relaxed) as u8 as f64,
            );
            out.insert(format!("replica{i}_routed"), r.routed.get() as f64);
        }
        out
    }

    /// Per-executable stats merged across replicas: calls sum, means are
    /// call-weighted.
    pub fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        let mut merged: HashMap<String, (u64, f64)> = HashMap::new();
        for r in &self.replicas {
            for (name, calls, mean_us) in r.engine.models.exec_stats() {
                let e = merged.entry(name).or_insert((0, 0.0));
                let total = e.0 + calls;
                if total > 0 {
                    e.1 = (e.1 * e.0 as f64 + mean_us * calls as f64) / total as f64;
                }
                e.0 = total;
            }
        }
        let mut out: Vec<(String, u64, f64)> =
            merged.into_iter().map(|(n, (c, m))| (n, c, m)).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Graceful shutdown: every replica drains its queue and joins its
    /// workers.
    pub fn shutdown(self) {
        for r in self.replicas {
            r.engine.shutdown();
        }
    }
}

impl EngineFront for ClusterEngine {
    fn next_id(&self) -> u64 {
        ClusterEngine::next_id(self)
    }

    fn manifest(&self) -> &Manifest {
        &self.replicas[0].engine.models.manifest
    }

    fn run(&self, req: Request) -> Response {
        ClusterEngine::run(self, req)
    }

    fn submit_streaming(&self, req: Request) -> UpdateReceiver {
        ClusterEngine::submit_streaming(self, req)
    }

    fn cancel(&self, id: u64) -> bool {
        ClusterEngine::cancel(self, id)
    }

    fn scrape(&self) -> HashMap<String, f64> {
        ClusterEngine::scrape(self)
    }

    fn exec_stats(&self) -> Vec<(String, u64, f64)> {
        ClusterEngine::exec_stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::scripted;

    fn cluster(tag: &str, replicas: usize, routing: RoutingPolicy) -> (ClusterEngine, String) {
        let dir = scripted::write_test_artifacts(tag, 64, false);
        let ce = ClusterEngine::start(
            &dir,
            ClusterConfig {
                replicas,
                routing,
                engine: EngineConfig { workers: 1, ..EngineConfig::default() },
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        (ce, dir)
    }

    fn req_with_image(id: u64, phase: usize) -> Request {
        Request::simple(id, "w5 w6", scripted::demo_image(phase))
    }

    #[test]
    fn affinity_routing_is_sticky_per_image() {
        let (ce, dir) = cluster("cluster_sticky", 4, RoutingPolicy::Affinity);
        // same image -> same replica, every time, regardless of prompt
        let home = ce.route(&req_with_image(1, 0));
        for i in 0..8 {
            let mut r = req_with_image(10 + i, 0);
            r.prompt = format!("w{} w{}", i, i + 1);
            assert_eq!(ce.route(&r), home);
        }
        // distinct images spread: 16 images must not all share one replica
        let homes: std::collections::HashSet<usize> =
            (0..16).map(|p| ce.route(&req_with_image(100 + p as u64, p))).collect();
        assert!(homes.len() > 1, "16 images all routed to replica {home}");
        // an id-only follow-up routes with its pixel-carrying original
        let original = req_with_image(200, 3);
        let mut follow_up = Request::simple(201, "w7", vec![]);
        follow_up.image_id = Some(cache::image_hash(&original.image));
        assert_eq!(ce.route(&original), ce.route(&follow_up));
        ce.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn drain_excludes_replica_and_undrain_restores_home() {
        let (ce, dir) = cluster("cluster_drain_route", 3, RoutingPolicy::Affinity);
        let r = req_with_image(1, 0);
        let home = ce.route(&r);
        assert!(ce.drain(home));
        assert!(ce.is_draining(home));
        for _ in 0..10 {
            assert_ne!(ce.route(&r), home, "draining replica must not be routed");
        }
        assert!(ce.undrain(home));
        assert_eq!(ce.route(&r), home, "rendezvous brings the key back home");
        // out-of-range drain is refused, not a panic
        assert!(!ce.drain(99));
        assert!(!ce.undrain(99));
        assert!(!ce.is_draining(99));
        ce.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn round_robin_cycles_and_skips_draining() {
        let (ce, dir) = cluster("cluster_rr", 3, RoutingPolicy::RoundRobin);
        let r = req_with_image(1, 0);
        let first: Vec<usize> = (0..6).map(|_| ce.route(&r)).collect();
        assert_eq!(first, vec![0, 1, 2, 0, 1, 2]);
        ce.drain(1);
        for _ in 0..6 {
            assert_ne!(ce.route(&r), 1);
        }
        ce.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scrape_rolls_up_and_exposes_per_replica_keys() {
        let (ce, dir) = cluster("cluster_scrape", 2, RoutingPolicy::Affinity);
        // run a few requests through the cluster so counters move
        for i in 0..4 {
            let resp = ce.run(req_with_image(ce.next_id(), i % 2));
            assert!(resp.error.is_none(), "unexpected failure: {:?}", resp.error);
        }
        let s = ce.scrape();
        assert_eq!(s["cluster_replicas"], 2.0);
        assert_eq!(s["cluster_draining"], 0.0);
        assert_eq!(s["requests_received"], 4.0);
        assert_eq!(s["requests_completed"], 4.0);
        // rollup equals the sum of the per-replica keys it came from
        let per: f64 = (0..2).map(|i| s[&format!("replica{i}_tokens_generated")]).sum();
        assert_eq!(s["tokens_generated"], per);
        assert!(s["tokens_generated"] > 0.0);
        // recomputed ratio matches the summed parts
        let lookups = s["prefix_cache_hits"] + s["prefix_cache_misses"];
        assert!(lookups > 0.0);
        assert!((s["prefix_cache_hit_rate"] - s["prefix_cache_hits"] / lookups).abs() < 1e-12);
        // routing counters account for every placement
        assert_eq!(
            s["cluster_routed_affinity"] + s["cluster_spills"] + s["cluster_routed_blind"],
            4.0
        );
        assert!(s.contains_key("replica0_draining"));
        assert!(s.contains_key("replica1_routed"));
        ce.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ids_are_unique_across_the_cluster_and_cancel_broadcasts() {
        let (ce, dir) = cluster("cluster_ids", 2, RoutingPolicy::RoundRobin);
        let a = ce.next_id();
        let b = ce.next_id();
        assert_ne!(a, b);
        // cancel of an unknown id is false everywhere
        assert!(!ce.cancel(10_000));
        ce.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}
