//! Statistics used by the evaluation harness: total variation distance
//! (Figure 4's distribution analysis, Eq. 6), histogram binning, and small
//! aggregation helpers.

/// Total variation distance TVD(P, Q) = 1/2 * sum |P(x) - Q(x)| (Eq. 6).
/// Inputs must be distributions over the same vocabulary.
pub fn tvd(p: &[f32], q: &[f32]) -> f64 {
    debug_assert_eq!(p.len(), q.len());
    0.5 * p
        .iter()
        .zip(q)
        .map(|(&a, &b)| (a as f64 - b as f64).abs())
        .sum::<f64>()
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; out-of-range
/// values clamp into the edge buckets (TVD lives in [0,1] so none occur).
#[derive(Debug, Clone)]
pub struct FixedHistogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
}

impl FixedHistogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        FixedHistogram { lo, hi, counts: vec![0; bins], n: 0 }
    }

    pub fn record(&mut self, v: f64) {
        let bins = self.counts.len() as f64;
        let idx = ((v - self.lo) / (self.hi - self.lo) * bins)
            .floor()
            .clamp(0.0, bins - 1.0) as usize;
        self.counts[idx] += 1;
        self.n += 1;
    }

    pub fn fraction(&self, bin: usize) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.counts[bin] as f64 / self.n as f64
        }
    }

    /// Mass at or below `v` (inclusive of the bin containing v).
    pub fn cdf(&self, v: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let bins = self.counts.len() as f64;
        let idx = ((v - self.lo) / (self.hi - self.lo) * bins)
            .floor()
            .clamp(0.0, bins - 1.0) as usize;
        self.counts[..=idx].iter().sum::<u64>() as f64 / self.n as f64
    }

    /// ASCII rendering for bench output (Figure-4 style).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let bins = self.counts.len();
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let a = self.lo + (self.hi - self.lo) * i as f64 / bins as f64;
            let b = self.lo + (self.hi - self.lo) * (i + 1) as f64 / bins as f64;
            let bar = "#".repeat((c as usize * width / max as usize).max(usize::from(c > 0)));
            out.push_str(&format!("[{a:.2},{b:.2}) {c:6} {bar}\n"));
        }
        out
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median of (a copy of) the samples.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{propcheck, random_distribution, small_size};

    #[test]
    fn tvd_identical_is_zero() {
        let p = vec![0.25, 0.25, 0.5];
        assert_eq!(tvd(&p, &p), 0.0);
    }

    #[test]
    fn tvd_disjoint_is_one() {
        assert!((tvd(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tvd_known_value() {
        // |0.6-0.2| + |0.4-0.8| = 0.8 -> TVD 0.4
        assert!((tvd(&[0.6, 0.4], &[0.2, 0.8]) - 0.4).abs() < 1e-6);
    }

    #[test]
    fn prop_tvd_bounds_and_symmetry() {
        propcheck("tvd in [0,1], symmetric", 300, |rng| {
            let n = small_size(rng, 64);
            let p = random_distribution(rng, n);
            let q = random_distribution(rng, n);
            let d = tvd(&p, &q);
            if !(0.0..=1.0 + 1e-6).contains(&d) {
                return Err(format!("tvd {d}"));
            }
            if (d - tvd(&q, &p)).abs() > 1e-9 {
                return Err("asymmetric".into());
            }
            // triangle inequality with a third distribution
            let r = random_distribution(rng, n);
            if tvd(&p, &r) > tvd(&p, &q) + tvd(&q, &r) + 1e-6 {
                return Err("triangle violated".into());
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_binning() {
        let mut h = FixedHistogram::new(0.0, 1.0, 10);
        h.record(0.05);
        h.record(0.15);
        h.record(0.15);
        h.record(0.999);
        h.record(1.5); // clamps to last bin
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.n, 5);
        assert!((h.cdf(0.19) - 0.6).abs() < 1e-9);
        assert!(h.render(20).lines().count() == 10);
    }

    #[test]
    fn summary_stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.2909944487358056).abs() < 1e-9);
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
