//! Minimal JSON parser/writer.
//!
//! `serde`/`serde_json` are not in the offline vendored crate set
//! (DESIGN.md section 2), so the artifact manifest, vocab tables, eval sets
//! and the wire protocol use this hand-rolled implementation.  It supports
//! the full JSON grammar (RFC 8259) minus some float edge cases that the
//! Python emitters never produce, preserves object key order, and is
//! round-trip tested (unit + property tests below).

use std::collections::HashMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved (insertion order of the source document).
    Obj(Vec<(String, Json)>),
}

/// Parse/accessor errors (`thiserror` is not in the offline vendored set;
/// the Display/Error impls are written out by hand below).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonError {
    Eof(usize),
    Unexpected(usize, char),
    BadNumber(usize),
    BadEscape(usize),
    BadUtf8(usize),
    Trailing(usize),
    Type(&'static str),
    Missing(String),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(i, c) => write!(f, "unexpected byte {c:?} at {i}"),
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(i) => write!(f, "invalid escape at byte {i}"),
            JsonError::BadUtf8(i) => write!(f, "invalid utf-8 in string at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type(t) => write!(f, "type error: expected {t}"),
            JsonError::Missing(k) => write!(f, "missing key {k:?}"),
        }
    }
}

impl std::error::Error for JsonError {}

pub type JResult<T> = Result<T, JsonError>;

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_bool(&self) -> JResult<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }

    pub fn as_f64(&self) -> JResult<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }

    pub fn as_i64(&self) -> JResult<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> JResult<usize> {
        let v = self.as_f64()?;
        if v < 0.0 {
            return Err(JsonError::Type("non-negative integer"));
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> JResult<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    pub fn as_arr(&self) -> JResult<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }

    pub fn as_obj(&self) -> JResult<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }

    /// Object field lookup (linear scan; manifests are small).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> JResult<&Json> {
        self.get(key).ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------- constructors

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_i32(v: &[i32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Extract `[f32]` from a numeric array.
    pub fn to_f32_vec(&self) -> JResult<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    pub fn to_i32_vec(&self) -> JResult<Vec<i32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as i32)).collect()
    }

    /// Build an object from a hash map (key order: sorted, deterministic).
    pub fn from_map(map: HashMap<String, Json>) -> Json {
        let mut pairs: Vec<_> = map.into_iter().collect();
        pairs.sort_by(|a, b| a.0.cmp(&b.0));
        Json::Obj(pairs)
    }

    // ------------------------------------------------------------ writing

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_number(*n, out),
            Json::Str(s) => write_string(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{}", n);
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

pub fn parse(input: &str) -> JResult<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(JsonError::Trailing(p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> JResult<u8> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> JResult<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Unexpected(self.i, self.b[self.i] as char))
        }
    }

    fn value(&mut self) -> JResult<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(self.i, c as char)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> JResult<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.i, self.b[self.i] as char))
        }
    }

    fn number(&mut self) -> JResult<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }

    fn string(&mut self) -> JResult<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or(JsonError::BadEscape(self.i))?);
                        }
                        _ => return Err(JsonError::BadEscape(self.i - 1)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte utf-8: find the full char
                    let s = std::str::from_utf8(&self.b[self.i - 1..])
                        .map_err(|_| JsonError::BadUtf8(self.i - 1))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> JResult<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek()?;
            self.i += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(JsonError::BadEscape(self.i - 1)),
                };
        }
        Ok(v)
    }

    fn array(&mut self) -> JResult<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => return Err(JsonError::Unexpected(self.i, c as char)),
            }
        }
    }

    fn object(&mut self) -> JResult<Json> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => return Err(JsonError::Unexpected(self.i, c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert!(a[2].get("b").unwrap().is_null());
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" \u{e9} \u{1F600}");
    }

    #[test]
    fn parse_whitespace_and_empty() {
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{ }").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("123 45").is_err());
        assert!(parse("\"\\x\"").is_err());
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn write_round_trip_hand_cases() {
        for s in [
            "null",
            "[1,2,3]",
            r#"{"a":"b \" c","n":-2.5}"#,
            r#"[[],{},[{"x":[null,true,false]}]]"#,
        ] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(4) } else { rng.range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.range(2) == 0),
            2 => Json::Num((rng.range(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let n = rng.range(8);
                Json::Str((0..n).map(|_| rng.pick(&['a', '"', '\\', 'é', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.range(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn prop_round_trip_random_documents() {
        let mut rng = Rng::seeded(0xC0FFEE);
        for _ in 0..500 {
            let v = random_json(&mut rng, 3);
            let s = v.to_string();
            let back = parse(&s).unwrap_or_else(|e| panic!("reparse failed: {e} for {s}"));
            assert_eq!(back, v, "document {s}");
        }
    }
}
