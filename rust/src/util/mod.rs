//! Infrastructure substitutions for the offline environment (DESIGN.md
//! section 2): JSON codec, CLI parser, PRNG, and a property-test harness.

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

/// Read a whole file to string with a path-annotated error.
pub fn read_file(path: &str) -> anyhow::Result<String> {
    std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))
}

/// Resolve the artifacts directory: $MASSV_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> String {
    std::env::var("MASSV_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
