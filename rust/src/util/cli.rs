//! Minimal CLI argument parser (`clap` is not vendored offline).
//!
//! Grammar: `prog [subcommand] [--key value | --flag] [positional...]`.
//! Used by the `massv` binary, examples, and bench harnesses.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first element must NOT be argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I, subcommands: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        if let Some(first) = it.peek() {
            if subcommands.contains(&first.as_str()) {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse(subcommands: &[&str]) -> Args {
        Args::parse_from(std::env::args().skip(1), subcommands)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_options_flags_positional() {
        // NB: a bare `--flag` followed by a non-dashed token would consume
        // it as a value (documented grammar); flags go last or use `=`.
        let a = Args::parse_from(
            argv("serve --port 7777 --rate=2.5 input.json --verbose"),
            &["serve", "eval"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("7777"));
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["input.json"]);
    }

    #[test]
    fn no_subcommand_when_unknown() {
        let a = Args::parse_from(argv("frobnicate --x 1"), &["serve"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["frobnicate"]);
    }

    #[test]
    fn trailing_flag_is_flag_not_option() {
        let a = Args::parse_from(argv("--a 1 --b"), &[]);
        assert_eq!(a.get("a"), Some("1"));
        assert!(a.has_flag("b"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(argv(""), &[]);
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_or("x", "d"), "d");
    }
}
