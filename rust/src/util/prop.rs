//! Tiny property-testing harness (`proptest` is not vendored offline).
//!
//! Usage:
//! ```ignore
//! propcheck("sampler normalizes", 500, |rng| {
//!     let n = 1 + rng.range(50);
//!     // ... build a random case from rng, return Err(msg) on violation
//!     Ok(())
//! });
//! ```
//! Failures report the case index and the derived seed so a case can be
//! replayed exactly with `propcheck_seeded`.  No shrinking: generators are
//! encouraged to draw sizes small-biased (see `small_size`).

use super::rng::Rng;

pub const DEFAULT_SEED: u64 = 0x4D41_5353_565F_5250; // "MASSV_RP"

/// Run `n` random cases of `f`; panic with a replay seed on failure.
pub fn propcheck<F>(name: &str, n: usize, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    propcheck_seeded(name, n, DEFAULT_SEED, f)
}

pub fn propcheck_seeded<F>(name: &str, n: usize, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut meta = Rng::seeded(seed);
    for case in 0..n {
        let case_seed = meta.next_u64();
        let mut rng = Rng::seeded(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property {name:?} failed at case {case}/{n} \
                 (replay: propcheck_case({name:?}, 0x{case_seed:x}, f)): {msg}"
            );
        }
    }
}

/// Replay a single failing case from its reported seed.
pub fn propcheck_case<F>(name: &str, case_seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::seeded(case_seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property {name:?} failed on replay: {msg}");
    }
}

/// Small-biased size draw in [1, max]: half the mass below max/8.
pub fn small_size(rng: &mut Rng, max: usize) -> usize {
    debug_assert!(max >= 1);
    if rng.range(2) == 0 {
        1 + rng.range(max.div_ceil(8))
    } else {
        1 + rng.range(max)
    }
}

/// A random probability distribution over `n` outcomes (possibly sparse).
pub fn random_distribution(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut p: Vec<f32> = (0..n)
        .map(|_| if rng.range(4) == 0 { 0.0 } else { rng.f32() + 1e-6 })
        .collect();
    let s: f32 = p.iter().sum();
    if s <= 0.0 {
        p[rng.range(n)] = 1.0;
        return p;
    }
    for v in &mut p {
        *v /= s;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        propcheck("tautology", 100, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn panics_with_replay_info() {
        propcheck("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn random_distribution_sums_to_one() {
        propcheck("distribution normalized", 200, |rng| {
            let n = small_size(rng, 64);
            let p = random_distribution(rng, n);
            let s: f32 = p.iter().sum();
            if (s - 1.0).abs() < 1e-4 && p.iter().all(|&v| v >= 0.0) {
                Ok(())
            } else {
                Err(format!("sum {s}"))
            }
        });
    }

    #[test]
    fn small_size_in_bounds() {
        propcheck("small_size bounds", 500, |rng| {
            let m = 1 + rng.range(100);
            let s = small_size(rng, m);
            if (1..=m).contains(&s) {
                Ok(())
            } else {
                Err(format!("size {s} for max {m}"))
            }
        });
    }
}
