//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! The `rand` crate is not in the offline vendored set; the coordinator
//! needs high-quality, *reproducible* randomness for stochastic acceptance
//! sampling (Section 2.1), workload generation, and the property-test
//! mini-framework.  Statistical sanity is covered by unit tests below and
//! by the chi-square test in spec/sampler.rs.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seeded(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = splitmix64(&mut x);
        }
        // all-zero state is invalid for xoshiro; splitmix never yields it
        // for four consecutive outputs, but belt and braces:
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Rng { s }
    }

    /// xoshiro256** next
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(xs.len())]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.range(i + 1));
        }
    }

    /// Standard exponential variate (for Poisson arrivals in the workload
    /// generator's open-loop mode).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Derive an independent stream (for per-request seeds).
    pub fn fork(&mut self) -> Rng {
        Rng::seeded(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(Rng::seeded(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::seeded(7);
        let n = 100_000;
        let mut mean = 0.0;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            mean += x;
            buckets[(x * 10.0) as usize] += 1;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        for b in buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.01, "bucket {frac}");
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut rng = Rng::seeded(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.range(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(3);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seeded(9);
        let n = 50_000;
        let rate = 4.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seeded(5);
        let mut a = base.fork();
        let mut b = base.fork();
        let xs: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
