//! Kernel-path vs serving-path artifact equivalence (the L1 contract at
//! the PJRT boundary): the Pallas-kernel lowering and the fused-jnp
//! lowering of the SAME trained model must produce numerically identical
//! outputs through the Rust runtime.  This is what licenses serving from
//! the fused lowering on CPU while the kernel remains the TPU story
//! (see python/compile/aot.py and EXPERIMENTS.md section Perf).

use massv::manifest::Manifest;
use massv::models::ModelSet;
use massv::runtime::{lit_f32, lit_i32, scalar_i32, to_vec_f32};
use massv::tokenizer::Tokenizer;
use massv::workload;

fn artifacts() -> Option<String> {
    let dir = std::env::var("MASSV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn kernel_and_serving_artifacts_agree() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let raw = massv::util::json::parse(
        &massv::util::read_file(&format!("{dir}/manifest.json")).unwrap(),
    )
    .unwrap();
    let Some(kv_records) = raw.get("kernel_validation") else {
        eprintln!("SKIP: artifacts predate kernel_validation records");
        return;
    };
    let kernel_target = kv_records.as_arr().unwrap().iter().find(|r| {
        r.get("kind").and_then(|k| k.as_str().ok()) == Some("kernel_validation")
            && r.get("name").and_then(|n| n.as_str().ok()) == Some("qwensim-L")
    });
    let Some(kernel_target) = kernel_target else {
        eprintln!("SKIP: no kernel validation record for qwensim-L");
        return;
    };

    let models = ModelSet::load(&dir).unwrap();
    let tok = Tokenizer::load(&dir).unwrap();
    let items = workload::load_task(&dir, "coco", &tok, manifest.p_max).unwrap();
    let it = &items[0];

    // serving-path prefill + verify
    let target = models.target("qwensim-L").unwrap();
    let (serving_logits, mut st) =
        target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len).unwrap();
    let toks: Vec<i32> = (10..=(10 + manifest.gamma as i32)).collect();
    let serving_verify = target.verify(&mut st, &toks).unwrap();

    // kernel-path prefill + verify through raw executables
    let entries = kernel_target.req("entries").unwrap();
    let file = |e: &str| {
        format!(
            "{dir}/{}",
            entries.req(e).unwrap().req("file").unwrap().as_str().unwrap()
        )
    };
    let prefill = models.rt.load_exec(&file("prefill_mm"), "k_prefill").unwrap();
    let out = prefill
        .call(&[
            lit_f32(&it.image, &[16, 16, 3]).unwrap(),
            lit_i32(&it.prompt_ids, &[manifest.p_max]).unwrap(),
            scalar_i32(it.prompt_len as i32),
        ])
        .unwrap();
    let kernel_logits = to_vec_f32(&out[0]).unwrap();
    let kv = out.into_iter().nth(1).unwrap();

    for (a, b) in serving_logits.iter().zip(&kernel_logits) {
        assert!((a - b).abs() < 1e-3, "prefill logits diverge: {a} vs {b}");
    }

    let verify = models.rt.load_exec(&file("verify"), "k_verify").unwrap();
    let pos = (manifest.n_visual + it.prompt_len) as i32;
    let out = verify
        .call(&[
            lit_i32(&toks, &[manifest.gamma + 1]).unwrap(),
            scalar_i32(pos),
            kv,
        ])
        .unwrap();
    let kernel_verify = to_vec_f32(&out[0]).unwrap();
    for (a, b) in serving_verify.data.iter().zip(&kernel_verify) {
        assert!((a - b).abs() < 1e-3, "verify logits diverge: {a} vs {b}");
    }
}
