//! HTTP/SSE gateway integration tests: wire equivalence against the TCP
//! front end (both fronts over the SAME engine must produce bit-identical
//! token streams), per-tenant admission control (429/503 + Retry-After),
//! endpoint routing, per-tenant metrics rollup, and shared validation --
//! over both `Engine` and `ClusterEngine` fronts, scripted backend only.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use massv::cluster::{ClusterConfig, ClusterEngine, RoutingPolicy};
use massv::coordinator::{Engine, EngineConfig, EngineFront};
use massv::server::http::{GatewayConfig, HttpClient, HttpServer, Quota};
use massv::server::{Client, Server};
use massv::util::json::Json;

fn scripted_artifacts(tag: &str, gen_max: usize) -> String {
    massv::models::scripted::write_test_artifacts(tag, gen_max, false)
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

/// Both front ends -- the TCP server and the HTTP gateway -- bound to
/// ephemeral ports over one shared engine.
struct Fronts {
    tcp: String,
    http: String,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn start_fronts<F: EngineFront>(engine: Arc<F>, gateway: GatewayConfig) -> Fronts {
    let tcp_server = Server::new(engine.clone());
    let http_server = HttpServer::new(engine, gateway);
    let stops = vec![tcp_server.stop_handle(), http_server.stop_handle()];
    let (tx, rx) = std::sync::mpsc::channel();
    let t1 = std::thread::spawn(move || {
        tcp_server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let tcp = rx.recv().unwrap().to_string();
    let (tx, rx) = std::sync::mpsc::channel();
    let t2 = std::thread::spawn(move || {
        http_server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let http = rx.recv().unwrap().to_string();
    Fronts { tcp, http, stops, handles: vec![t1, t2] }
}

impl Fronts {
    fn stop(self) {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

/// THE wire-equivalence property: for every decode mode, the HTTP JSON
/// response and the SSE chunk concatenation must be bit-identical to the
/// TCP front end's `tokens` -- streaming and non-streaming, same engine.
fn assert_wire_equivalence<F: EngineFront>(engine: Arc<F>) {
    let fronts = start_fronts(engine, GatewayConfig::default());
    let mut tcp = Client::connect(&fronts.tcp).unwrap();
    let http = HttpClient::new(fronts.http.clone());

    for mode in ["massv", "tree", "target_only"] {
        let body = |stream: bool| {
            Json::obj(vec![
                // "op" is the TCP envelope; the HTTP gateway routes by path
                // and ignores it
                ("op", Json::str("generate")),
                ("prompt", Json::str("w5 w6 w7")),
                ("image", Json::arr_f32(&image(0))),
                ("mode", Json::str(mode)),
                ("seed", Json::num(0.0)),
                ("stream", Json::Bool(stream)),
            ])
        };
        // non-streaming: identical tokens through both fronts
        let tcp_resp = tcp.call(&body(false)).unwrap();
        assert!(tcp_resp.get("error").is_none(), "{tcp_resp:?}");
        let tcp_tokens = tcp_resp.get("tokens").unwrap().to_i32_vec().unwrap();
        let (status, http_resp) = http.generate(&body(false), None).unwrap();
        assert_eq!(status, 200, "{http_resp:?}");
        assert_eq!(
            http_resp.get("tokens").unwrap().to_i32_vec().unwrap(),
            tcp_tokens,
            "{mode}: HTTP tokens must equal TCP tokens"
        );
        assert_eq!(
            http_resp.get("finish_reason").unwrap().as_str().unwrap(),
            tcp_resp.get("finish_reason").unwrap().as_str().unwrap()
        );

        // streaming: SSE chunks reuse the TCP chunk frames, so the
        // concatenation is bit-identical to the TCP token list
        let (status, chunks, summary) = http.generate_streaming(&body(true), None).unwrap();
        assert_eq!(status, 200, "{summary:?}");
        assert!(summary.get("error").is_none(), "{summary:?}");
        assert!(chunks.len() > 1, "{mode}: expected multiple SSE frames");
        let concat: Vec<i32> = chunks.into_iter().flatten().collect();
        assert_eq!(
            concat,
            summary.get("tokens").unwrap().to_i32_vec().unwrap(),
            "{mode}: SSE chunks must concatenate to the summary tokens"
        );
        assert_eq!(concat, tcp_tokens, "{mode}: SSE stream must be wire-equivalent to TCP");

        // the TCP streaming path agrees with both
        let (tcp_chunks, tcp_summary) = tcp.call_streaming(&body(true)).unwrap();
        assert!(tcp_summary.get("error").is_none(), "{tcp_summary:?}");
        let tcp_concat: Vec<i32> = tcp_chunks.into_iter().flatten().collect();
        assert_eq!(tcp_concat, concat, "{mode}: TCP and SSE streams must agree");
    }
    fronts.stop();
}

#[test]
fn http_and_tcp_fronts_are_wire_equivalent_over_engine() {
    let dir = scripted_artifacts("gw_engine", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    assert_wire_equivalence(engine);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn http_and_tcp_fronts_are_wire_equivalent_over_cluster() {
    let dir = scripted_artifacts("gw_cluster", 48);
    let cluster = Arc::new(
        ClusterEngine::start(
            &dir,
            ClusterConfig {
                replicas: 2,
                routing: RoutingPolicy::Affinity,
                engine: EngineConfig { workers: 1, ..EngineConfig::default() },
                ..ClusterConfig::default()
            },
        )
        .unwrap(),
    );
    assert_wire_equivalence(cluster);
    std::fs::remove_dir_all(&dir).ok();
}

/// Per-tenant rate quota: an over-quota tenant is shed with 429 and a
/// usable Retry-After while an idle tenant on the default (unlimited)
/// quota completes normally.
#[test]
fn over_quota_tenant_sheds_429_while_idle_tenant_completes() {
    let dir = scripted_artifacts("gw_quota", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let fronts = start_fronts(
        engine,
        GatewayConfig {
            default_quota: Quota::default(),
            tenant_quotas: vec![(
                "flood".to_string(),
                Quota { rps: 0.001, burst: 1.0, max_concurrent: 0 },
            )],
        },
    );
    let http = HttpClient::new(fronts.http.clone());
    let body = Json::obj(vec![
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(0))),
    ]);
    // burst of 1: the first flood request is admitted...
    let (status, resp) = http.generate(&body, Some("flood")).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    // ...the second is shed with 429 + Retry-After before the engine sees it
    let (status, headers, text) = http
        .request("POST", "/v1/generate", &[("x-tenant", "flood")], Some(&body))
        .unwrap();
    assert_eq!(status, 429, "{text}");
    let retry: u64 = HttpClient::header(&headers, "retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .unwrap();
    assert!(retry >= 1, "retry-after {retry}");
    let parsed = massv::util::json::parse(&text).unwrap();
    assert!(parsed.get("error").unwrap().as_str().unwrap().contains("rate quota"));
    assert!(parsed.get("retry_after").unwrap().as_f64().unwrap() >= 1.0);
    // a streaming request from the shed tenant is rejected the same way
    let (status, chunks, summary) = http
        .generate_streaming(
            &Json::obj(vec![
                ("prompt", Json::str("w5 w6")),
                ("image", Json::arr_f32(&image(0))),
                ("stream", Json::Bool(true)),
            ]),
            Some("flood"),
        )
        .unwrap();
    assert_eq!(status, 429);
    assert!(chunks.is_empty());
    assert!(summary.get("error").is_some());
    // an idle tenant is unaffected by the flooding tenant's shedding
    let (status, resp) = http.generate(&body, Some("idle")).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    fronts.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// Per-tenant concurrency quota: while one long streaming request holds
/// the tenant's only slot, a second request is shed 503 busy; releasing
/// the slot readmits the tenant.
#[test]
fn over_concurrency_tenant_sheds_503_busy() {
    use std::io::{BufRead, BufReader, Read, Write};

    let dir = scripted_artifacts("gw_busy", 16384);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let fronts = start_fronts(
        engine,
        GatewayConfig {
            default_quota: Quota::default(),
            tenant_quotas: vec![(
                "serial".to_string(),
                Quota { rps: 0.0, burst: 0.0, max_concurrent: 1 },
            )],
        },
    );
    // a long streaming request takes the tenant's only in-flight slot
    let body = Json::obj(vec![
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(0))),
        ("max_new", Json::num(16000.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    let stream = std::net::TcpStream::connect(&fronts.http).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: x\r\nx-tenant: serial\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    writer.write_all(req.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 200"), "{line:?}");

    // while it streams, a second request for the tenant is shed busy
    let http = HttpClient::new(fronts.http.clone());
    let probe = Json::obj(vec![
        ("prompt", Json::str("w7")),
        ("image", Json::arr_f32(&image(1))),
    ]);
    let (status, headers, text) = http
        .request("POST", "/v1/generate", &[("x-tenant", "serial")], Some(&probe))
        .unwrap();
    assert_eq!(status, 503, "{text}");
    assert_eq!(HttpClient::header(&headers, "retry-after"), Some("1"));
    // ...but a different tenant still gets through (per-tenant slots)
    let (status, resp) = http.generate(&probe, Some("other")).unwrap();
    assert_eq!(status, 200, "{resp:?}");

    // drain the stream; the permit drops with the handler, readmitting
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("[DONE]"), "stream must finish cleanly");
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let (status, _) = http.generate(&probe, Some("serial")).unwrap();
        if status == 200 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot never released after the stream finished"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    fronts.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// Endpoint surface: healthz, metrics (engine scrape + `http_*` gateway
/// counters + per-tenant labeled keys), cancel, and the 400/404/405 error
/// paths.
#[test]
fn endpoints_health_metrics_cancel_and_errors() {
    let dir = scripted_artifacts("gw_endpoints", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let fronts = start_fronts(engine, GatewayConfig::default());
    let http = HttpClient::new(fronts.http.clone());

    let (status, _, text) = http.request("GET", "/healthz", &[], None).unwrap();
    assert_eq!(status, 200);
    assert!(massv::util::json::parse(&text).unwrap().get("ok").unwrap().as_bool().unwrap());

    // one generate under an explicit tenant header...
    let body = Json::obj(vec![
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(0))),
    ]);
    let (status, resp) = http.generate(&body, Some("gold")).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let id = resp.get("id").unwrap().as_i64().unwrap();

    // ...shows up in the scrape under both global and tenant-labeled keys
    let (status, _, text) = http.request("GET", "/metrics", &[], None).unwrap();
    assert_eq!(status, 200);
    let m = massv::util::json::parse(&text).unwrap();
    assert_eq!(m.get("requests_completed").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(
        m.get("tenant_completed{tenant=\"gold\"}").unwrap().as_f64().unwrap(),
        1.0,
        "x-tenant header must route per-tenant accounting"
    );
    assert!(m.get("http_requests").unwrap().as_f64().unwrap() >= 2.0);
    assert_eq!(m.get("http_shed_429").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(m.get("http_shed_503").unwrap().as_f64().unwrap(), 0.0);

    // cancel: a finished id reports ok:false; malformed ids are 400
    let (status, _, text) =
        http.request("POST", &format!("/v1/cancel/{id}"), &[], None).unwrap();
    assert_eq!(status, 200);
    assert!(!massv::util::json::parse(&text).unwrap().get("ok").unwrap().as_bool().unwrap());
    let (status, _, _) = http.request("POST", "/v1/cancel/notanid", &[], None).unwrap();
    assert_eq!(status, 400);

    // routing errors: unknown path 404, wrong method on a known path 405,
    // malformed JSON body 400, empty x-tenant 400
    let (status, _, _) = http.request("GET", "/nope", &[], None).unwrap();
    assert_eq!(status, 404);
    let (status, _, _) = http.request("GET", "/v1/generate", &[], None).unwrap();
    assert_eq!(status, 405);
    let (status, _, _) = http.request("POST", "/healthz", &[], None).unwrap();
    assert_eq!(status, 405);
    let mut stream = std::net::TcpStream::connect(&fronts.http).unwrap();
    {
        use std::io::{Read, Write};
        let bad = "{not json";
        let req = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{bad}",
            bad.len()
        );
        stream.write_all(req.as_bytes()).unwrap();
        let mut raw = String::new();
        stream.read_to_string(&mut raw).unwrap();
        assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");
    }
    let (status, _, text) = http
        .request("POST", "/v1/generate", &[("x-tenant", "")], Some(&body))
        .unwrap();
    assert_eq!(status, 400, "{text}");
    assert!(text.contains("x-tenant"));

    fronts.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// Shared validation: the same malformed body is rejected by BOTH front
/// ends with the same field-naming message (`protocol::parse_generate` is
/// the single validation path) -- the HTTP gateway maps it to 400.
#[test]
fn both_fronts_reject_malformed_fields_identically() {
    let dir = scripted_artifacts("gw_validation", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let fronts = start_fronts(engine, GatewayConfig::default());
    let mut tcp = Client::connect(&fronts.tcp).unwrap();
    let http = HttpClient::new(fronts.http.clone());

    let cases: Vec<(&str, Vec<(&str, Json)>)> = vec![
        ("temperature", vec![("temperature", Json::str("hot"))]),
        ("top_p", vec![("top_p", Json::num(2.0))]),
        ("max_new", vec![("max_new", Json::num(0.0))]),
        ("seed", vec![("seed", Json::num(-1.0))]),
        ("stream", vec![("stream", Json::str("yes"))]),
        ("priority", vec![("priority", Json::str("urgent"))]),
        ("deadline_ms", vec![("deadline_ms", Json::num(0.5))]),
        ("tenant", vec![("tenant", Json::str(""))]),
        ("prompt", vec![("prompt", Json::num(5.0))]),
    ];
    for (field, poison) in cases {
        let mut obj = vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("w5 w6")),
            ("image", Json::arr_f32(&image(0))),
        ];
        for (k, v) in poison {
            obj.retain(|(name, _)| *name != k);
            obj.push((k, v));
        }
        let body = Json::obj(obj);
        let tcp_resp = tcp.call(&body).unwrap();
        let tcp_err = tcp_resp
            .get("error")
            .unwrap_or_else(|| panic!("TCP coerced bad {field:?}: {tcp_resp:?}"))
            .as_str()
            .unwrap()
            .to_string();
        let (status, http_resp) = http.generate(&body, None).unwrap();
        assert_eq!(status, 400, "HTTP must reject bad {field:?}: {http_resp:?}");
        let http_err = http_resp.get("error").unwrap().as_str().unwrap().to_string();
        assert_eq!(
            tcp_err, http_err,
            "both fronts must produce the identical message for bad {field:?}"
        );
        assert!(
            http_err.contains(&format!("{field:?}")),
            "error for {field:?} must name the field: {http_err}"
        );
    }
    fronts.stop();
    std::fs::remove_dir_all(&dir).ok();
}

/// The `tenant` body field is honored when no `x-tenant` header is sent,
/// and the header outranks the body when both are present.
#[test]
fn tenant_header_outranks_body_field() {
    let dir = scripted_artifacts("gw_tenant", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let fronts = start_fronts(engine.clone(), GatewayConfig::default());
    let http = HttpClient::new(fronts.http.clone());

    let body = Json::obj(vec![
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(0))),
        ("tenant", Json::str("bodyteam")),
    ]);
    // no header: the body field wins
    let (status, resp) = http.generate(&body, None).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    // header present: it outranks the body field
    let (status, resp) = http.generate(&body, Some("headerteam")).unwrap();
    assert_eq!(status, 200, "{resp:?}");

    let m = engine.scrape();
    assert_eq!(m["tenant_completed{tenant=\"bodyteam\"}"], 1.0);
    assert_eq!(m["tenant_completed{tenant=\"headerteam\"}"], 1.0);
    fronts.stop();
    std::fs::remove_dir_all(&dir).ok();
}
