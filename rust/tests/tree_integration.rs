//! End-to-end token-tree speculation over the scripted backend: request ->
//! coordinator -> decoder -> protocol response, with no PJRT involved
//! (`manifest.backend == "scripted"`, see models::scripted).  This is the
//! integration tier the vendored-stub build can always run.

use std::sync::Arc;

use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};
use massv::util::json::Json;

/// Scripted-backend artifact dir under tmp (shared fixture, with the
/// "baseline" drafter variant alongside "massv").
fn scripted_artifacts(tag: &str) -> String {
    massv::models::scripted::write_test_artifacts(tag, 48, true)
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn request(engine: &Engine, mode: DecodeMode, prompt: &str, img_phase: usize) -> Request {
    let mut req = Request::simple(engine.next_id(), prompt, image(img_phase));
    req.mode = mode;
    req
}

const PROMPTS: [&str; 4] = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14"];

fn spec_mode() -> DecodeMode {
    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive: false }
}

fn tree_mode(adaptive: bool) -> DecodeMode {
    DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive }
}

/// Tree mode through Engine::run is lossless and at least as accepting as
/// chain mode on the high-agreement ("massv") scripted workload.
#[test]
fn engine_tree_mode_lossless_and_mal_dominates_chain() {
    let dir = scripted_artifacts("engine");
    let engine = Engine::start(
        &dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 2,
            queue_capacity: 64,
            ..EngineConfig::default()
        },
    )
    .unwrap();

    let mut chain_mal_sum = 0.0;
    let mut tree_mal_sum = 0.0;
    for (i, prompt) in PROMPTS.iter().enumerate() {
        let base = engine.run(request(&engine, DecodeMode::TargetOnly, prompt, i));
        assert!(base.error.is_none(), "{:?}", base.error);
        assert!(base.finished_by_eos);

        let chain = engine.run(request(&engine, spec_mode(), prompt, i));
        assert!(chain.error.is_none(), "{:?}", chain.error);
        let tree = engine.run(request(&engine, tree_mode(false), prompt, i));
        assert!(tree.error.is_none(), "{:?}", tree.error);

        // losslessness through the whole serving stack
        assert_eq!(chain.tokens, base.tokens, "chain != target-only on {prompt:?}");
        assert_eq!(tree.tokens, base.tokens, "tree != target-only on {prompt:?}");
        assert!(!tree.text.is_empty());

        // tree bookkeeping made it to the response
        assert!(tree.tree_nodes_drafted > 0);
        assert!(tree.mean_path_depth > 0.0);
        assert_eq!(chain.tree_nodes_drafted, 0);

        chain_mal_sum += chain.mal;
        tree_mal_sum += tree.mal;
        assert!(
            tree.mal + 1e-9 >= chain.mal,
            "prompt {prompt:?}: tree MAL {:.3} < chain MAL {:.3}",
            tree.mal,
            chain.mal
        );
    }
    assert!(
        tree_mal_sum > chain_mal_sum,
        "across the workload the recovery branch must raise MAL: tree {tree_mal_sum:.3} vs chain {chain_mal_sum:.3}"
    );

    // engine metrics picked up the tree iterations
    assert!(engine.metrics.tree_requests.get() >= PROMPTS.len() as u64);
    assert!(engine.metrics.tree_nodes_drafted.get() > 0);
    assert!(engine.metrics.branch_utilization() > 0.0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The adaptive controller in tree mode stays lossless end to end.
#[test]
fn engine_adaptive_tree_mode_lossless() {
    let dir = scripted_artifacts("adaptive");
    let engine = Engine::start(&dir, EngineConfig::default()).unwrap();
    let base = engine.run(request(&engine, DecodeMode::TargetOnly, PROMPTS[0], 0));
    let adaptive = engine.run(request(&engine, tree_mode(true), PROMPTS[0], 0));
    assert!(adaptive.error.is_none(), "{:?}", adaptive.error);
    assert_eq!(adaptive.tokens, base.tokens);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Full TCP round-trip: mode "tree" over the wire, new response fields, and
/// tree metrics visible through the metrics op.
#[test]
fn server_tree_round_trip() {
    let dir = scripted_artifacts("server");
    let engine = Arc::new(
        Engine::start(
            &dir,
            EngineConfig {
                default_target: "qwensim-L".into(),
                workers: 2,
                queue_capacity: 16,
                ..EngineConfig::default()
            },
        )
        .unwrap(),
    );
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();
    assert!(client.ping().unwrap());

    let gen_req = |mode: &str| {
        Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(PROMPTS[0])),
            ("image", Json::arr_f32(&image(0))),
            ("mode", Json::str(mode)),
            ("seed", Json::num(0.0)),
        ])
    };

    let chain = client.call(&gen_req("massv")).unwrap();
    assert!(chain.get("error").is_none(), "{chain:?}");
    let tree = client.call(&gen_req("tree")).unwrap();
    assert!(tree.get("error").is_none(), "{tree:?}");

    // identical outputs (lossless), tree at least as accepting
    assert_eq!(
        tree.get("tokens").unwrap().to_i32_vec().unwrap(),
        chain.get("tokens").unwrap().to_i32_vec().unwrap()
    );
    let chain_mal = chain.get("mal").unwrap().as_f64().unwrap();
    let tree_mal = tree.get("mal").unwrap().as_f64().unwrap();
    assert!(tree_mal + 1e-9 >= chain_mal, "tree {tree_mal:.3} < chain {chain_mal:.3}");
    assert!(tree.get("mean_path_depth").unwrap().as_f64().unwrap() > 0.0);
    assert!(tree.get("tree_nodes_drafted").unwrap().as_f64().unwrap() > 0.0);

    let metrics = client.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert!(metrics.get("tree_requests").unwrap().as_f64().unwrap() >= 1.0);
    assert!(metrics.get("tree_iterations").unwrap().as_f64().unwrap() >= 1.0);

    // a typo'd tree variant is a hard protocol error, not a silent
    // target-only fallback
    let bad = client
        .call(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str(PROMPTS[0])),
            ("image", Json::arr_f32(&image(0))),
            ("mode", Json::str("tree")),
            ("variant", Json::str("masv")),
        ]))
        .unwrap();
    let err = bad.get("error").expect("typo'd variant must error").as_str().unwrap();
    assert!(err.contains("variant"), "{err}");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
