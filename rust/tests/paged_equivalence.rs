//! Paged-KV equivalence tests: the headline invariant of the block pool
//! (`massv::kv`, `docs/paged_kv.md`) is that paging is *invisible* in the
//! output.  Pinned here at two levels:
//!
//!   * the session-level batched-vs-sequential oracle with every lane's KV
//!     paged through one shared pool (chain/tree/adaptive x cold/warm x
//!     batched/sequential), and
//!   * the full engine A/B: identical request sets served with
//!     `paged_kv` on and off must produce identical responses, including
//!     tree mode -- and again with a starved pool that forces constant
//!     preemption (swap-out/swap-in cycles on queued sessions).
//!
//! Scripted backend throughout (`manifest.backend == "scripted"`); no PJRT.

use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request, Response};
use massv::kv::{KvPool, KvPoolConfig};
use massv::models::scripted::{demo_image, write_test_artifacts};
use massv::models::ModelSet;
use massv::spec::testing::{run_batched_vs_sequential_pooled, OracleLane};
use massv::spec::{GenConfig, SpecMode, TreeConfig};

/// The batched-vs-sequential determinism oracle with every session's KV
/// paged through one shared pool with deliberately tiny blocks (lots of
/// sharing, lots of copy-on-write traffic).
#[test]
fn prop_pooled_oracle_is_bit_identical() {
    let dir = write_test_artifacts("paged_oracle", 48, false);
    let set = ModelSet::load(&dir).unwrap();

    massv::util::prop::propcheck("batched == sequential (paged pool)", 16, |rng| {
        let pool = KvPool::with_metrics(
            KvPoolConfig { block_words: 4, budget_bytes: 1 << 20 },
            None,
        );
        let n_lanes = 1 + rng.range(6);
        let lanes: Vec<OracleLane> = (0..n_lanes)
            .map(|_| {
                let mode = match rng.range(4) {
                    0 => None, // target-only (plain-decode lane)
                    1 => Some(SpecMode::Tree),
                    _ => Some(SpecMode::Chain),
                };
                OracleLane {
                    adaptive: mode.is_some() && rng.range(3) == 0,
                    mode,
                    cfg: GenConfig {
                        temperature: if rng.range(2) == 0 { 0.0 } else { 1.0 },
                        seed: rng.next_u64(),
                        max_new: 8 + rng.range(32),
                        tree: Some(TreeConfig {
                            branch: vec![2, 2, 1, 1, 1],
                            max_nodes: 16,
                        }),
                        ..GenConfig::default()
                    },
                    image_phase: rng.range(4),
                    prompt: (0..(2 + rng.range(5)))
                        .map(|_| 5 + rng.range(90) as i32)
                        .collect(),
                    warm: rng.range(3) == 0,
                }
            })
            .collect();
        run_batched_vs_sequential_pooled(&set, "qwensim-L", "massv", &lanes, Some(&pool))
    });
    std::fs::remove_dir_all(&dir).ok();
}

fn mixed_requests(engine: &Engine, n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            let mut req = Request::simple(
                engine.next_id(),
                &format!("w{} w{}", 5 + i % 4, 9 + i % 3),
                demo_image(i % 3),
            );
            req.mode = match i % 3 {
                0 => DecodeMode::TargetOnly,
                1 => DecodeMode::Speculative {
                    variant: "massv".into(),
                    text_only_draft: false,
                    adaptive: false,
                },
                _ => DecodeMode::Tree {
                    variant: "massv".into(),
                    text_only_draft: false,
                    adaptive: false,
                },
            };
            req.gen.max_new = 40;
            req.gen.temperature = if i % 2 == 0 { 0.0 } else { 1.0 };
            req.gen.seed = 2000 + i as u64;
            req
        })
        .collect()
}

fn run_engine(
    dir: &str,
    cfg: EngineConfig,
    n: usize,
) -> (Vec<Response>, std::collections::HashMap<String, f64>) {
    let engine = Engine::start(dir, cfg).unwrap();
    let rxs: Vec<_> = mixed_requests(&engine, n)
        .into_iter()
        .map(|req| engine.submit(req))
        .collect();
    let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let metrics = engine.scrape();
    engine.shutdown();
    (responses, metrics)
}

fn assert_identical(a: &[Response], b: &[Response], label: &str) {
    for (x, y) in a.iter().zip(b) {
        assert!(x.error.is_none() && y.error.is_none(), "{label}: {:?}/{:?}", x.error, y.error);
        assert_eq!(x.tokens, y.tokens, "{label}: tokens diverge");
        assert_eq!(x.verify_calls, y.verify_calls, "{label}");
        assert_eq!(x.accepted_draft, y.accepted_draft, "{label}");
        assert_eq!(x.finish_reason, y.finish_reason, "{label}");
        assert_eq!(x.finished_by_eos, y.finished_by_eos, "{label}");
        assert_eq!(x.tree_nodes_drafted, y.tree_nodes_drafted, "{label}");
    }
}

/// Engine A/B: `paged_kv` on vs off over a chain/tree/target-only request
/// mix must be response-identical, while the paged engine demonstrably
/// runs on the pool (fork counter, residency gauges).
#[test]
fn engine_paged_matches_unpaged_including_tree() {
    let dir = write_test_artifacts("paged_engine_eq", 2048, false);
    let base = || EngineConfig {
        workers: 2,
        queue_capacity: 128,
        max_batch: 4,
        ..EngineConfig::default()
    };
    let (unpaged, m_off) = run_engine(&dir, EngineConfig { paged_kv: false, ..base() }, 12);
    let (paged, m_on) = run_engine(&dir, EngineConfig { paged_kv: true, ..base() }, 12);

    assert_identical(&unpaged, &paged, "paged vs unpaged");
    assert_eq!(m_off["kv_forks"], 0.0, "pool off must never touch the pool");
    assert_eq!(m_off["kv_pool_blocks"], 0.0);
    assert!(
        m_on["kv_forks"] > 0.0,
        "prefix exports/hits must fork paged KV as refcount bumps: {m_on:?}"
    );
    assert!(
        m_on["kv_pool_blocks"] > 0.0,
        "cached prefix snapshots keep pool blocks resident after shutdown scrape"
    );
    assert_eq!(m_on["kv_swap_outs"], 0.0, "a roomy pool must never preempt");
    std::fs::remove_dir_all(&dir).ok();
}

/// Preemption equivalence: a pool starved to zero bytes keeps every
/// backlogged session swapped out (each pop swaps it back in), yet the
/// decoded output is identical to a roomy pool's.
#[test]
fn preempted_engine_output_is_identical_to_unpressured() {
    let dir = write_test_artifacts("paged_engine_preempt", 2048, false);
    let base = || EngineConfig {
        workers: 2,
        queue_capacity: 128,
        max_batch: 4,
        paged_kv: true,
        kv_block_words: 8,
        ..EngineConfig::default()
    };
    let (roomy, m_roomy) = run_engine(&dir, base(), 10);
    let (starved, m_starved) =
        run_engine(&dir, EngineConfig { kv_pool_bytes: 0, ..base() }, 10);

    assert_identical(&roomy, &starved, "starved vs roomy pool");
    assert_eq!(m_roomy["kv_swap_outs"], 0.0);
    assert!(
        m_starved["kv_preemptions"] > 0.0,
        "a zero-byte budget must force preemption passes: {m_starved:?}"
    );
    assert!(m_starved["kv_swap_outs"] > 0.0);
    assert!(
        m_starved["kv_swap_ins"] > 0.0,
        "every preempted session that stepped again must have swapped back in"
    );
    std::fs::remove_dir_all(&dir).ok();
}
