//! Drafter-side vision compression equivalence tests (docs/drafting.md):
//! pooling the DRAFTER's vision sequence changes drafter cost and
//! acceptance rates, never emitted greedy tokens -- the target always
//! verifies at full resolution, and greedy acceptance emits exactly the
//! target's argmax sequence no matter what the drafter proposed.  Also
//! covers the acceptance calibrator's serving-level guarantees: greedy
//! outputs are bit-identical with calibration on or off, and the
//! telemetry JSONL export is well-formed.

use std::sync::Arc;

use massv::coordinator::{DecodeMode, Engine, EngineConfig, Request};

fn scripted_artifacts(tag: &str, gen_max: usize) -> String {
    massv::models::scripted::write_test_artifacts(tag, gen_max, false)
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn request(engine: &Engine, mode: DecodeMode, prompt: &str, img_phase: usize) -> Request {
    let mut req = Request::simple(engine.next_id(), prompt, image(img_phase));
    req.mode = mode;
    req
}

fn spec_mode(adaptive: bool) -> DecodeMode {
    DecodeMode::Speculative { variant: "massv".into(), text_only_draft: false, adaptive }
}

fn tree_mode(adaptive: bool) -> DecodeMode {
    DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive }
}

/// THE compression property: greedy outputs are bit-identical across
/// drafter vision ratios 1/4/16, for chain, tree, and adaptive sessions,
/// cold and warm.  Acceptance accounting (verify_calls, accepted_draft)
/// may differ -- a compressed drafter agrees less -- but the token stream
/// may not.
#[test]
fn prop_compressed_drafter_preserves_greedy_tokens() {
    let dir = scripted_artifacts("drafting_ratio_prop", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14"];

    let eng = engine.clone();
    massv::util::prop::propcheck("greedy tokens invariant under vision ratio", 18, move |rng| {
        let prompt = prompts[rng.range(prompts.len())];
        let phase = rng.range(5);
        let mode = match rng.range(3) {
            0 => spec_mode(rng.range(2) == 0),
            1 => tree_mode(rng.range(2) == 0),
            _ => spec_mode(false),
        };
        let seed = rng.next_u64();
        let make = |ratio: Option<u32>| {
            let mut r = request(&eng, mode.clone(), prompt, phase);
            r.gen.temperature = 0.0;
            r.gen.seed = seed;
            r.draft_vision_ratio = ratio;
            r
        };

        let full = eng.run(make(None));
        if full.error.is_some() {
            return Err(format!("full-res run failed: {:?}", full.error));
        }
        for ratio in [4u32, 16] {
            // cold at this ratio (first touch fills a ratio-specific
            // prefix line), then warm
            for pass in ["cold", "warm"] {
                let r = eng.run(make(Some(ratio)));
                if r.error.is_some() {
                    return Err(format!("ratio {ratio} {pass} run failed: {:?}", r.error));
                }
                if r.tokens != full.tokens {
                    return Err(format!(
                        "ratio {ratio} {pass} tokens {:?} != full-res tokens {:?}",
                        r.tokens, full.tokens
                    ));
                }
                if r.finish_reason != full.finish_reason
                    || r.finished_by_eos != full.finished_by_eos
                {
                    return Err(format!(
                        "ratio {ratio} {pass} finish ({}, {}) != full-res ({}, {})",
                        r.finish_reason, r.finished_by_eos, full.finish_reason,
                        full.finished_by_eos
                    ));
                }
            }
        }
        Ok(())
    });

    let engine = Arc::try_unwrap(engine).unwrap_or_else(|_| panic!("engine still shared"));
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Prefix-cache separation across ratios: a warm start at one ratio must
/// not resume from another ratio's drafter KV.  Same image + prompt at
/// ratio 1 then ratio 4: the second is a cache MISS (ratio is part of the
/// key), and each ratio is warm on its own resubmission.
#[test]
fn prefix_cache_keys_separate_vision_ratios() {
    let dir = scripted_artifacts("drafting_ratio_cache", 48);
    let engine = Engine::start(&dir, EngineConfig::default()).unwrap();
    let make = |ratio: u32| {
        let mut r = request(&engine, spec_mode(false), "w5 w6 w7", 0);
        r.gen.temperature = 0.0;
        r.draft_vision_ratio = Some(ratio);
        r
    };

    let a = engine.run(make(1));
    assert!(a.error.is_none(), "{:?}", a.error);
    assert!(!a.cache_hit, "first touch is cold");

    let b = engine.run(make(4));
    assert!(b.error.is_none(), "{:?}", b.error);
    assert!(!b.cache_hit, "a different ratio must not hit ratio 1's prefix");
    assert_eq!(b.tokens, a.tokens, "compression is output-lossless");

    let a2 = engine.run(make(1));
    let b2 = engine.run(make(4));
    assert!(a2.cache_hit && b2.cache_hit, "both ratios must be warm now");
    assert_eq!(a2.tokens, a.tokens);
    assert_eq!(b2.tokens, a.tokens);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The engine-level default (`EngineConfig::draft_vision_ratio`) applies
/// to requests without their own override, produces the same tokens as
/// full resolution, and per-request overrides still win.
#[test]
fn engine_config_ratio_default_is_lossless() {
    let dir = scripted_artifacts("drafting_engine_cfg", 48);
    let full = Engine::start(&dir, EngineConfig::default()).unwrap();
    let pooled = Engine::start(
        &dir,
        EngineConfig { draft_vision_ratio: 4, ..EngineConfig::default() },
    )
    .unwrap();

    for (i, prompt) in ["w5 w6 w7", "w8 w9", "w10 w11"].iter().enumerate() {
        let a = full.run(request(&full, spec_mode(false), prompt, i));
        let b = pooled.run(request(&pooled, spec_mode(false), prompt, i));
        assert!(a.error.is_none() && b.error.is_none());
        assert_eq!(b.tokens, a.tokens, "engine-level ratio must be lossless on {prompt:?}");

        // per-request override beats the engine default and stays lossless
        let mut over = request(&pooled, spec_mode(false), prompt, i);
        over.draft_vision_ratio = Some(16);
        let c = pooled.run(over);
        assert!(c.error.is_none(), "{:?}", c.error);
        assert_eq!(c.tokens, a.tokens);
    }
    full.shutdown();
    pooled.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Calibration is output-lossless at T=0 (chain<->tree steering never
/// changes greedy tokens), warms per-class state visible in `scrape`, and
/// streams well-formed JSONL telemetry for the self-distillation exporter.
#[test]
fn calibration_is_lossless_and_exports_telemetry() {
    let dir = scripted_artifacts("drafting_calib", 48);
    let jsonl = std::path::PathBuf::from(format!("{dir}/acceptance.jsonl"));
    let plain = Engine::start(&dir, EngineConfig::default()).unwrap();
    let calibrated = Engine::start(
        &dir,
        EngineConfig {
            calibration: true,
            calib_jsonl: Some(jsonl.clone()),
            ..EngineConfig::default()
        },
    )
    .unwrap();
    assert!(calibrated.calibrator.is_some());

    let classes = ["chat", "caption", "doc"];
    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13"];
    // enough traffic per class to pass the calibrator's warmup; greedy
    // outputs must match the uncalibrated engine request for request
    for round in 0..6 {
        for (ci, class) in classes.iter().enumerate() {
            let prompt = prompts[(round + ci) % prompts.len()];
            let phase = (round + ci) % 4;
            let make = |eng: &Engine| {
                let mut r = request(eng, spec_mode(false), prompt, phase);
                r.task = class.to_string();
                r.gen.temperature = 0.0;
                r
            };
            let a = plain.run(make(&plain));
            let b = calibrated.run(make(&calibrated));
            assert!(a.error.is_none() && b.error.is_none());
            assert_eq!(
                b.tokens, a.tokens,
                "calibration must not change greedy tokens (class {class}, round {round})"
            );
        }
    }

    // per-class state is exported through scrape
    let m = calibrated.scrape();
    for class in classes {
        let obs = m
            .get(&format!("calib_obs{{class=\"{class}\"}}"))
            .unwrap_or_else(|| panic!("scrape must export calib_obs for {class}: {m:?}"));
        assert!(*obs > 0.0, "class {class} saw no observations");
        assert!(m.contains_key(&format!("calib_alpha{{class=\"{class}\"}}")));
        assert!(m.contains_key(&format!("calib_gamma{{class=\"{class}\"}}")));
        assert!(m.contains_key(&format!("calib_tree{{class=\"{class}\"}}")));
    }

    plain.shutdown();
    calibrated.shutdown();

    // the JSONL telemetry is one well-formed object per observation
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "telemetry file must not be empty");
    for line in &lines {
        let v = massv::util::json::parse(line)
            .unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e:#}"));
        let class = v.req("class").unwrap().as_str().unwrap().to_string();
        assert!(classes.contains(&class.as_str()), "unknown class {class:?}");
        let mode = v.req("mode").unwrap().as_str().unwrap().to_string();
        assert!(mode == "chain" || mode == "tree", "unknown mode {mode:?}");
        let drafted = v.req("drafted").unwrap().as_usize().unwrap();
        let accepted = v.req("accepted").unwrap().as_usize().unwrap();
        assert!(drafted >= 1, "observations only cover drafting iterations");
        assert!(accepted <= drafted, "accepted {accepted} > drafted {drafted}");
        v.req("image_reuse").unwrap().as_bool().unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}
