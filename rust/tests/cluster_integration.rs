//! Multi-replica cluster tests over the scripted backend: routing
//! determinism (replicas=1 vs replicas=4 must be bit-exact, streaming and
//! cancel included), drain semantics, wire-protocol transparency through
//! `Server<ClusterEngine>`, and the affinity-vs-blind cache hit-rate gap.

use std::sync::Arc;

use massv::cluster::{ClusterConfig, ClusterEngine, RoutingPolicy};
use massv::coordinator::{DecodeMode, EngineConfig, Request, Update};
use massv::util::json::Json;

fn scripted_artifacts(tag: &str, gen_max: usize) -> String {
    massv::models::scripted::write_test_artifacts(tag, gen_max, false)
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn cluster(dir: &str, replicas: usize, routing: RoutingPolicy) -> ClusterEngine {
    ClusterEngine::start(
        dir,
        ClusterConfig {
            replicas,
            routing,
            // one worker per replica: replica count, not pool size, is the
            // variable under test
            engine: EngineConfig { workers: 1, queue_capacity: 256, ..EngineConfig::default() },
            ..ClusterConfig::default()
        },
    )
    .unwrap()
}

/// A deterministic mixed request matrix: modes x temperatures x seeds x
/// images x prompts.  `id` comes from the serving cluster.
fn matrix_request(ce: &ClusterEngine, i: usize) -> Request {
    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14"];
    let mut r = Request::simple(ce.next_id(), prompts[i % prompts.len()], image(i % 8));
    r.mode = match i % 3 {
        0 => DecodeMode::Speculative {
            variant: "massv".into(),
            text_only_draft: false,
            adaptive: false,
        },
        1 => DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive: false },
        _ => DecodeMode::TargetOnly,
    };
    r.gen.temperature = if i % 2 == 0 { 0.0 } else { 1.0 };
    r.gen.seed = i as u64;
    r.gen.max_new = 24;
    r
}

/// (tokens, finish_reason, streamed-chunk concatenation; empty for one-shot)
type Outcome = (Vec<i32>, String, Vec<i32>);

/// Run the full matrix through a cluster: even indices one-shot, odd
/// indices streaming.
fn run_matrix(ce: &ClusterEngine, n: usize) -> Vec<Outcome> {
    (0..n)
        .map(|i| {
            let req = matrix_request(ce, i);
            if i % 2 == 0 {
                let resp = ce.run(req);
                assert!(resp.error.is_none(), "request {i} failed: {:?}", resp.error);
                (resp.tokens, resp.finish_reason, Vec::new())
            } else {
                let rx = ce.submit_streaming(req);
                let mut streamed = Vec::new();
                loop {
                    match rx.recv().expect("stream ended without Done") {
                        Update::Chunk(toks) => streamed.extend(toks),
                        Update::Done(resp) => {
                            assert!(
                                resp.error.is_none(),
                                "streaming request {i} failed: {:?}",
                                resp.error
                            );
                            break (resp.tokens, resp.finish_reason, streamed);
                        }
                    }
                }
            }
        })
        .collect()
}

/// THE cluster determinism property: the same seeded request set produces
/// bit-exact tokens whether it is served by one replica or spread over
/// four -- each request is an independent seeded decode, so placement must
/// never leak into output.  Streaming chunk concatenation must equal the
/// summary tokens on both topologies.
#[test]
fn replica_count_never_changes_tokens() {
    let dir = scripted_artifacts("cluster_det", 64);
    let one = cluster(&dir, 1, RoutingPolicy::Affinity);
    let four = cluster(&dir, 4, RoutingPolicy::Affinity);

    let a = run_matrix(&one, 24);
    let b = run_matrix(&four, 24);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.0, y.0, "request {i}: tokens diverge between 1 and 4 replicas");
        assert_eq!(x.1, y.1, "request {i}: finish_reason diverges");
        if !x.2.is_empty() || !y.2.is_empty() {
            assert_eq!(x.2, x.0, "request {i}: 1-replica chunks must concat to tokens");
            assert_eq!(y.2, y.0, "request {i}: 4-replica chunks must concat to tokens");
        }
    }
    // the 4-replica cluster actually spread the work
    let s = four.scrape();
    let serving = (0..4)
        .filter(|i| s[&format!("replica{i}_requests_received")] > 0.0)
        .count();
    assert!(serving > 1, "4-replica cluster served everything on one replica");
    one.shutdown();
    four.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Cancel on a cluster: the id broadcast finds the serving replica, the
/// partial output is a prefix of the bit-exact reference decode (which is
/// itself identical across topologies), and the stream stays consistent.
#[test]
fn cancel_routes_by_id_and_stays_a_prefix_of_the_reference() {
    let dir = scripted_artifacts("cluster_cancel", 16384);
    let one = cluster(&dir, 1, RoutingPolicy::Affinity);
    let four = cluster(&dir, 4, RoutingPolicy::Affinity);

    let long = |ce: &ClusterEngine| {
        let mut r = Request::simple(ce.next_id(), "w5 w6", image(1));
        r.mode = DecodeMode::TargetOnly;
        r.gen.max_new = 16000;
        r.gen.seed = 7;
        r
    };
    // the reference decode is bit-exact across topologies
    let ref1 = one.run(long(&one));
    let ref4 = four.run(long(&four));
    assert!(ref1.error.is_none() && ref4.error.is_none());
    assert_eq!(ref1.tokens, ref4.tokens, "reference must not depend on topology");

    // cancel mid-decode on the 4-replica cluster
    let req = long(&four);
    let id = req.id;
    let rx = four.submit_streaming(req);
    let mut streamed = match rx.recv().unwrap() {
        Update::Chunk(toks) => toks,
        Update::Done(r) => panic!("finished before cancel: {r:?}"),
    };
    assert!(four.cancel(id), "broadcast cancel must find the serving replica");
    let resp = loop {
        match rx.recv().unwrap() {
            Update::Chunk(toks) => streamed.extend(toks),
            Update::Done(resp) => break resp,
        }
    };
    assert_eq!(resp.finish_reason, "cancelled");
    assert!(resp.error.is_none(), "cancel is not an error: {:?}", resp.error);
    assert_eq!(streamed, resp.tokens, "chunks must concat to the partial output");
    assert!(!resp.tokens.is_empty() && resp.tokens.len() < 16000);
    // wall-clock decides *where* the cut lands; determinism guarantees the
    // partial output is a prefix of the reference decode
    assert_eq!(
        resp.tokens[..],
        ref4.tokens[..resp.tokens.len()],
        "cancelled output must be a prefix of the uncancelled decode"
    );
    assert!(!four.cancel(id), "finished id is no longer cancellable");
    one.shutdown();
    four.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Drain semantics: a draining replica finishes its in-flight stream
/// losslessly, admits nothing new while draining, and gets its affinity
/// keys back after undrain.
#[test]
fn draining_replica_finishes_inflight_and_admits_nothing() {
    let dir = scripted_artifacts("cluster_drain", 16384);
    let ce = cluster(&dir, 3, RoutingPolicy::Affinity);

    let mut long = Request::simple(ce.next_id(), "w5 w6 w7", image(2));
    long.mode = DecodeMode::TargetOnly;
    long.gen.max_new = 4000;
    let probe = long.clone();
    let target = ce.route(&probe);

    let rx = ce.submit_streaming(long);
    let mut streamed = match rx.recv().unwrap() {
        Update::Chunk(toks) => toks,
        Update::Done(r) => panic!("finished before drain: {r:?}"),
    };
    assert!(ce.drain(target));
    let received_before = ce.replica(target).metrics.requests_received.get();

    // placement skips the draining replica under every probe
    for _ in 0..20 {
        assert_ne!(ce.route(&probe), target, "draining replica must not be routed");
    }
    // new work is admitted elsewhere and completes
    for i in 0..8 {
        let mut r = Request::simple(ce.next_id(), "w8 w9", image(3 + i));
        r.mode = DecodeMode::TargetOnly;
        r.gen.max_new = 4;
        let resp = ce.run(r);
        assert!(resp.error.is_none(), "request during drain failed: {:?}", resp.error);
    }
    assert_eq!(
        ce.replica(target).metrics.requests_received.get(),
        received_before,
        "a draining replica must admit nothing new"
    );

    // the in-flight stream on the draining replica still finishes losslessly
    let resp = loop {
        match rx.recv().unwrap() {
            Update::Chunk(toks) => streamed.extend(toks),
            Update::Done(resp) => break resp,
        }
    };
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.finish_reason, "length");
    assert_eq!(resp.tokens.len(), 4000, "drain must not cut in-flight work short");
    assert_eq!(streamed, resp.tokens);

    let s = ce.scrape();
    assert_eq!(s["cluster_draining"], 1.0);
    assert_eq!(s[&format!("replica{target}_draining")], 1.0);

    // undrain: rendezvous is topology-stable, the key comes home
    assert!(ce.undrain(target));
    assert_eq!(ce.route(&probe), target, "undrained replica must regain its keys");
    assert_eq!(ce.scrape()["cluster_draining"], 0.0);
    ce.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Wire transparency: `Server<ClusterEngine>` speaks the identical
/// protocol -- generate, repeat-hit, streaming, cancel, metrics -- with
/// the cluster rollup visible under the `metrics` op.
#[test]
fn server_over_cluster_is_wire_transparent() {
    let dir = scripted_artifacts("cluster_server", 64);
    let ce = Arc::new(cluster(&dir, 2, RoutingPolicy::Affinity));
    let server = massv::server::Server::new(ce.clone());
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();

    let gen_req = |stream: bool| {
        Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("w5 w6 w7")),
            ("image", Json::arr_f32(&image(0))),
            ("seed", Json::num(0.0)),
            ("max_new", Json::num(16.0)),
            ("stream", Json::Bool(stream)),
        ])
    };

    let r1 = client.call(&gen_req(false)).unwrap();
    assert!(r1.get("error").is_none(), "{r1:?}");
    // affinity sends the identical request back to the same replica: warm
    let r2 = client.call(&gen_req(false)).unwrap();
    assert!(r2.get("cache_hit").unwrap().as_bool().unwrap(), "repeat must hit its home cache");
    assert_eq!(
        r2.get("tokens").unwrap().to_i32_vec().unwrap(),
        r1.get("tokens").unwrap().to_i32_vec().unwrap()
    );

    // streaming through the cluster front
    let (chunks, summary) = client.call_streaming(&gen_req(true)).unwrap();
    assert!(summary.get("error").is_none(), "{summary:?}");
    let concat: Vec<i32> = chunks.into_iter().flatten().collect();
    assert_eq!(concat, summary.get("tokens").unwrap().to_i32_vec().unwrap());
    assert_eq!(concat, r1.get("tokens").unwrap().to_i32_vec().unwrap());

    // cancel of an unknown id is a clean ok: false anywhere in the cluster
    let cancel = client
        .call(&Json::obj(vec![("op", Json::str("cancel")), ("id", Json::num(99999.0))]))
        .unwrap();
    assert!(!cancel.get("ok").unwrap().as_bool().unwrap());

    // the metrics op exposes the rollup, the cluster keys, and drill-down
    let m = client.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert_eq!(m.get("cluster_replicas").unwrap().as_f64().unwrap(), 2.0);
    assert!(m.get("cluster_routed_affinity").unwrap().as_f64().unwrap() >= 3.0);
    assert!(m.get("replica0_requests_received").is_some());
    assert!(m.get("replica1_prefix_cache_hit_rate").is_some());
    assert!(m.get("requests_completed").unwrap().as_f64().unwrap() >= 3.0);
    assert!(m.get("executables").is_some());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    let ce = Arc::try_unwrap(ce).unwrap_or_else(|_| panic!("cluster still shared"));
    ce.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// THE routing property, in its deterministic form: over a repeated
/// (image, prompt) working set on 4 replicas, affinity routing misses each
/// prefix exactly once cluster-wide, while round-robin re-misses it on
/// every replica it lands on.  48 sequential requests over 6 keys:
/// affinity = 6 misses (hit rate 42/48 = 0.875); round-robin period-12
/// pattern touches each key on exactly 2 replicas = 12 misses (36/48 =
/// 0.75).
#[test]
fn affinity_routing_beats_blind_routing_on_cache_hit_rate() {
    let dir = scripted_artifacts("cluster_hitrate", 64);
    let run_workload = |routing: RoutingPolicy| {
        let ce = cluster(&dir, 4, routing);
        for i in 0..48 {
            let mut r = Request::simple(
                ce.next_id(),
                ["w5 w6", "w7 w8 w9"][i % 2],
                image(i % 3),
            );
            r.mode = DecodeMode::TargetOnly;
            r.gen.max_new = 4;
            let resp = ce.run(r);
            assert!(resp.error.is_none(), "{:?}", resp.error);
        }
        let s = ce.scrape();
        let (hits, misses) = (s["prefix_cache_hits"], s["prefix_cache_misses"]);
        ce.shutdown();
        assert_eq!(hits + misses, 48.0, "every request runs exactly one prefix lookup");
        hits / (hits + misses)
    };

    let affinity = run_workload(RoutingPolicy::Affinity);
    let blind = run_workload(RoutingPolicy::RoundRobin);
    assert!(
        (affinity - 42.0 / 48.0).abs() < 1e-9,
        "affinity: each of 6 keys misses once cluster-wide, got {affinity}"
    );
    assert!(
        (blind - 36.0 / 48.0).abs() < 1e-9,
        "round-robin: each key misses on its 2 home replicas, got {blind}"
    );
    assert!(affinity > blind, "affinity {affinity} must beat blind {blind}");
    std::fs::remove_dir_all(&dir).ok();
}
