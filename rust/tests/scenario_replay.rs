//! Scenario trace replay against the real serving stack.
//!
//! Two standing guarantees:
//!
//! 1. **Cross-front equivalence** -- one greedy scenario trace replayed
//!    through every front (TCP newline-JSON streaming + blocking, HTTP
//!    non-streaming + SSE) at 1 and 2 replicas yields bit-identical
//!    token streams.  The trace is the experiment; the transport and the
//!    replica count must not be.
//!
//! 2. **Invariant soak** -- the mixed-tenant trace flooded through the
//!    HTTP gateway with per-request chaos (tight deadlines, mid-stream
//!    client disconnects, cancel pokes, quota sheds, engine admission
//!    rejections) settles with exactly-once terminal accounting: every
//!    admitted request reaches exactly one of
//!    completed/cancelled/deadline/failed/rejected, no session, permit,
//!    or connection leaks, and the gateway's shed counters agree with
//!    what clients actually observed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use massv::cluster::{ClusterConfig, ClusterEngine};
use massv::coordinator::EngineConfig;
use massv::metrics::scrape_delta;
use massv::models::scripted::{demo_image, write_test_artifacts};
use massv::server::http::{GatewayConfig, HttpClient, HttpServer, Quota};
use massv::server::Server;
use massv::util::json::{parse, Json};
use massv::util::rng::Rng;
use massv::workload::scenario::replay::{replay, Front, ReplayOptions};
use massv::workload::scenario::{by_name, ScenarioKnobs, TraceRequest};

fn cluster(dir: &str, replicas: usize, queue_capacity: usize) -> Arc<ClusterEngine> {
    let engine = EngineConfig {
        workers: 2,
        queue_capacity,
        prefix_cache_bytes: 64 << 20,
        ..EngineConfig::default()
    };
    // spill_depth high enough that the router never sheds: admission
    // pressure in these tests comes from the engine queue and the gateway
    let cfg =
        ClusterConfig { replicas, spill_depth: 1_000_000, engine, ..ClusterConfig::default() };
    Arc::new(ClusterEngine::start(dir, cfg).unwrap())
}

/// Both fronts over one engine, bound to ephemeral ports.
struct Fronts {
    tcp: String,
    http: String,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn start_fronts(engine: Arc<ClusterEngine>, gateway: GatewayConfig) -> Fronts {
    let tcp_server = Server::new(engine.clone());
    let http_server = HttpServer::new(engine, gateway);
    let stops = vec![tcp_server.stop_handle(), http_server.stop_handle()];
    let (tx, rx) = std::sync::mpsc::channel();
    let t1 = std::thread::spawn(move || {
        tcp_server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let tcp = rx.recv().unwrap().to_string();
    let (tx, rx) = std::sync::mpsc::channel();
    let t2 = std::thread::spawn(move || {
        http_server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let http = rx.recv().unwrap().to_string();
    Fronts { tcp, http, stops, handles: vec![t1, t2] }
}

impl Fronts {
    fn stop(self) {
        for s in &self.stops {
            s.store(true, Ordering::Relaxed);
        }
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

fn shutdown(engine: Arc<ClusterEngine>) {
    match Arc::try_unwrap(engine) {
        Ok(c) => c.shutdown(),
        Err(_) => panic!("cluster engine still shared after the fronts stopped"),
    }
}

/// One trace, four transports, two replica counts: eight replays, one
/// token-stream answer.
#[test]
fn cross_front_trace_replay_is_bit_identical() {
    let dir = write_test_artifacts("scenario_replay_equiv", 256, false);
    let knobs = ScenarioKnobs {
        requests: 12,
        rate: 300.0,
        image_pool: 4,
        prompt_pool: 4,
        max_new: 8,
        image_base: 0,
    };
    let trace = by_name("chat_image_reuse", &knobs, 21).unwrap();
    let mut reference: Option<Vec<Vec<i32>>> = None;
    for replicas in [1usize, 2] {
        let engine = cluster(&dir, replicas, 4096);
        let fronts = start_fronts(engine.clone(), GatewayConfig::default());
        for (front, streaming) in
            [(Front::Tcp, false), (Front::Tcp, true), (Front::Http, false), (Front::Http, true)]
        {
            let addr = match front {
                Front::Tcp => fronts.tcp.as_str(),
                Front::Http => fronts.http.as_str(),
            };
            let opts = ReplayOptions {
                front,
                streaming,
                time_scale: 0.0, // closed flood: pacing must not matter either
                retry_shed: true,
                shed_backoff_ms: 2,
            };
            let rep = replay(addr, &trace, &opts).unwrap();
            let label = format!("replicas={replicas} front={front:?} streaming={streaming}");
            assert_eq!(rep.completed(), trace.requests.len(), "{label}");
            let streams = rep.token_streams();
            assert!(streams.iter().all(|s| !s.is_empty()), "{label}: empty token stream");
            match &reference {
                None => reference = Some(streams),
                Some(want) => {
                    assert_eq!(&streams, want, "{label}: token streams must be bit-identical");
                }
            }
        }
        fronts.stop();
        shutdown(engine);
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------- chaos soak

/// Wire body for a trace request (the soak builds its own so it can
/// inject deadlines and drive raw SSE sockets).
fn soak_body(r: &TraceRequest, streaming: bool, deadline_ms: Option<u64>) -> Json {
    let mut fields = vec![
        ("prompt", Json::str(r.prompt.clone())),
        ("task", Json::str(r.class)),
        ("max_new", Json::num(r.max_new as f64)),
        ("temperature", Json::num(r.temperature as f64)),
        ("seed", Json::num(r.seed as f64)),
        ("priority", Json::str(r.priority)),
        ("tenant", Json::str(r.tenant.clone())),
        ("image", Json::arr_f32(&demo_image(r.image))),
    ];
    if streaming {
        fields.push(("stream", Json::Bool(true)));
    }
    if let Some(d) = deadline_ms {
        fields.push(("deadline_ms", Json::num(d as f64)));
    }
    Json::obj(fields)
}

/// Classify a response the way the reconciliation accounts for it: gate
/// sheds carry no `finish_reason` (the engine never saw the request),
/// engine admission rejections do.
fn classify(status: u16, body: &Json) -> String {
    match status {
        429 => "shed_429".to_string(),
        503 => {
            if body.get("finish_reason").is_some() {
                "rejected_503".to_string()
            } else {
                "shed_503_gate".to_string()
            }
        }
        200 => body
            .get("finish_reason")
            .and_then(|f| f.as_str().ok())
            .unwrap_or("error")
            .to_string(),
        s => panic!("unexpected HTTP status {s}: {body:?}"),
    }
}

/// Open a raw streaming request and consume the status line + headers,
/// so the test can abandon or poke the stream mid-flight.  Returns the
/// writer half, the buffered reader half, and the status code.
fn open_sse(addr: &str, body: &Json) -> (TcpStream, BufReader<TcpStream>, u16) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let payload = body.to_string();
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    writer.write_all(req.as_bytes()).unwrap();
    writer.write_all(payload.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .unwrap_or_else(|| panic!("malformed status line {line:?}"));
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h).unwrap() == 0 {
            panic!("connection closed mid-headers");
        }
        if h.trim_end().is_empty() {
            break;
        }
    }
    (writer, reader, status)
}

fn read_error_body(mut reader: BufReader<TcpStream>) -> Json {
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    parse(&rest).unwrap()
}

/// Run one soaked request; returns (classification tag, cancel pokes).
fn soak_one(addr: &str, idx: usize, r: &TraceRequest) -> (String, u32) {
    let mut rng = Rng::seeded(0xC0FF_EE00 ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    match rng.range(4) {
        // plain non-streaming request
        0 => {
            let (status, body) =
                HttpClient::new(addr).generate(&soak_body(r, false, None), None).unwrap();
            (classify(status, &body), 0)
        }
        // non-streaming with a deadline the flooded queue cannot make
        1 => {
            let (status, body) =
                HttpClient::new(addr).generate(&soak_body(r, false, Some(1)), None).unwrap();
            (classify(status, &body), 0)
        }
        // streaming, abandoned after 1-2 frames (client disconnect)
        2 => {
            let (writer, mut reader, status) = open_sse(addr, &soak_body(r, true, None));
            if status != 200 {
                drop(writer);
                return (classify(status, &read_error_body(reader)), 0);
            }
            let want = 1 + rng.range(2);
            let mut seen = 0;
            while seen < want {
                let mut l = String::new();
                if reader.read_line(&mut l).unwrap_or(0) == 0 {
                    break; // short stream finished before we could walk away
                }
                if l.trim_end().strip_prefix("data: ").is_some() {
                    seen += 1;
                }
            }
            drop(reader);
            drop(writer);
            ("abandoned".to_string(), 0)
        }
        // streaming, poked with POST /v1/cancel/{id} from a side channel
        _ => {
            let (writer, mut reader, status) = open_sse(addr, &soak_body(r, true, None));
            if status != 200 {
                drop(writer);
                return (classify(status, &read_error_body(reader)), 0);
            }
            let mut pokes = 0u32;
            let mut summary: Option<Json> = None;
            loop {
                let mut l = String::new();
                if reader.read_line(&mut l).unwrap_or(0) == 0 {
                    break;
                }
                let Some(data) = l.trim_end().strip_prefix("data: ") else { continue };
                if data == "[DONE]" {
                    break;
                }
                let v = parse(data).unwrap();
                if v.get("chunk").is_some() {
                    if pokes == 0 {
                        let id = v.get("id").and_then(|x| x.as_f64().ok()).unwrap() as u64;
                        let poke = HttpClient::new(addr)
                            .request("POST", &format!("/v1/cancel/{id}"), &[], None)
                            .unwrap();
                        assert_eq!(poke.0, 200, "cancel poke must route");
                        pokes = 1;
                    }
                } else {
                    summary = Some(v);
                }
            }
            drop(reader);
            drop(writer);
            let s = summary.expect("streaming request must end with a summary frame");
            (classify(200, &s), pokes)
        }
    }
}

/// Flood the mixed-tenant trace through the gateway with chaos and check
/// that every counter, permit, and session reconciles exactly once.
#[test]
fn mixed_tenant_chaos_soak_reconciles_exactly_once() {
    let dir = write_test_artifacts("scenario_replay_soak", 256, false);
    let knobs = ScenarioKnobs {
        requests: 72,
        rate: 400.0,
        image_pool: 4,
        prompt_pool: 4,
        max_new: 6,
        image_base: 100,
    };
    let trace = by_name("mixed_tenants", &knobs, 33).unwrap();
    // a tight engine queue so the flood provokes admission rejections
    let engine = cluster(&dir, 1, 16);
    let gateway = GatewayConfig {
        default_quota: Quota::default(),
        tenant_quotas: vec![
            // bulk saturates its concurrency slots -> gate 503s
            ("bulk".to_string(), Quota { rps: 0.0, burst: 0.0, max_concurrent: 4 }),
            // silver exhausts its token bucket -> gate 429s
            ("silver".to_string(), Quota { rps: 2.0, burst: 1.0, max_concurrent: 0 }),
        ],
    };
    let server = HttpServer::new(engine.clone(), gateway);
    let stop = server.stop_handle();
    let conns = server.conn_count_handle();
    let counters = server.counters();
    let admission = server.admission();
    let (tx, rx) = std::sync::mpsc::channel();
    let serve_handle = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |a| tx.send(a).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap().to_string();

    let before = engine.scrape();
    let (req0, s429_0, s503_0) =
        (counters.requests.get(), counters.shed_429.get(), counters.shed_503.get());

    // closed flood: every request dispatches immediately on its own thread
    let mut handles = Vec::new();
    for (idx, r) in trace.requests.iter().cloned().enumerate() {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || soak_one(&addr, idx, &r)));
    }
    let mut tags = Vec::new();
    let mut pokes = 0u64;
    for h in handles {
        let (tag, p) = h.join().expect("soak worker panicked");
        tags.push(tag);
        pokes += p as u64;
    }

    // settle: abandoned streams and cancelled sessions drain asynchronously
    let t0 = Instant::now();
    loop {
        let m = engine.scrape();
        if m["inflight"] == 0.0 && m["queue_depth"] == 0.0 && conns.load(Ordering::Relaxed) == 0 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "soak failed to settle: inflight={} queue_depth={} conns={}",
            m["inflight"],
            m["queue_depth"],
            conns.load(Ordering::Relaxed)
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    let after = engine.scrape();
    let d = scrape_delta(&before, &after);
    let g = |k: &str| d.get(k).copied().unwrap_or(0.0);
    let count = |t: &str| tags.iter().filter(|x| x.as_str() == t).count();

    let n = trace.requests.len();
    let s429 = count("shed_429");
    let s503_gate = count("shed_503_gate");
    let s503_engine = count("rejected_503");
    assert!(s429 >= 1, "silver's rate quota must shed at least once");
    assert!(s503_gate >= 1, "bulk's concurrency quota must shed at least once");
    assert_eq!(count("error"), 0, "no request may fail outright: {tags:?}");

    // the engine saw exactly the requests the gate admitted
    assert_eq!(g("requests_received") as usize, n - s429 - s503_gate, "{tags:?}");
    // ...and every one of them reached exactly one terminal
    let terminals = g("requests_completed")
        + g("requests_cancelled")
        + g("requests_deadline_exceeded")
        + g("requests_failed")
        + g("requests_rejected");
    assert_eq!(terminals, g("requests_received"), "exactly-once terminal accounting");
    assert_eq!(g("requests_failed"), 0.0);
    // engine admission rejections all surfaced to clients as engine 503s
    assert_eq!(g("requests_rejected") as usize, s503_engine, "{tags:?}");
    // client-observed terminals are a lower bound: abandoned streams
    // settle server-side as completed or cancelled without a client record
    assert!(g("requests_completed") as usize >= count("eos") + count("length"));
    assert!(g("requests_cancelled") as usize >= count("cancelled"));
    assert!(g("requests_deadline_exceeded") as usize >= count("deadline"));
    assert!(
        g("requests_deadline_exceeded") >= 1.0,
        "1ms deadlines under a flood must expire at least once: {tags:?}"
    );

    // gateway counters agree with what the clients observed
    assert_eq!(counters.shed_429.get() - s429_0, s429 as u64);
    assert_eq!(counters.shed_503.get() - s503_0, (s503_gate + s503_engine) as u64);
    assert_eq!(counters.requests.get() - req0, n as u64 + pokes, "generates + cancel pokes");

    // no admission permit leaked (inflight permits drop with the handler)
    for t in ["gold", "silver", "bulk"] {
        assert_eq!(admission.inflight(t), 0, "leaked admission permit for tenant {t}");
    }
    // per-tenant ledgers reconcile independently too
    for t in ["gold", "silver", "bulk"] {
        let tg = |s: &str| d.get(&format!("tenant_{s}{{tenant=\"{t}\"}}")).copied().unwrap_or(0.0);
        let term =
            tg("completed") + tg("cancelled") + tg("deadline") + tg("failed") + tg("rejected");
        assert_eq!(tg("received"), term, "tenant {t} terminals must reconcile");
        assert!(tg("received") >= 1.0, "tenant {t} must reach the engine at least once");
    }
    // the engine-side session gauge is back to idle
    assert_eq!(after["inflight"], 0.0);

    stop.store(true, Ordering::Relaxed);
    serve_handle.join().unwrap();
    shutdown(engine);
    std::fs::remove_dir_all(&dir).ok();
}
