//! Integration tests over the real AOT artifacts (run `make artifacts`
//! first).  These exercise the full L1+L2+L3 composition: HLO loading,
//! speculative decoding invariants, the coordinator, and the TCP server.
//!
//! Every test skips (with a loud message) when artifacts/ is missing so
//! `cargo test` stays green on a fresh checkout.

use std::sync::Arc;

use massv::coordinator::{DecodeMode, Engine, EngineConfig, Priority, Request};
use massv::models::ModelSet;
use massv::spec::{sampler, GenConfig, SpecDecoder};
use massv::tokenizer::Tokenizer;
use massv::util::json::Json;
use massv::workload;

fn artifacts() -> Option<String> {
    let dir = std::env::var("MASSV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} (run `make artifacts`)");
        None
    }
}

fn setup(dir: &str) -> (Arc<ModelSet>, Tokenizer, Vec<workload::EvalItem>) {
    let models = ModelSet::load(dir).unwrap();
    let tok = Tokenizer::load(dir).unwrap();
    let items = workload::load_task(dir, "coco", &tok, models.manifest.p_max).unwrap();
    (models, tok, items)
}

/// THE invariant of speculative decoding (Section 2.1): at T=0 the
/// speculative output equals plain target greedy decoding, token for token,
/// for every drafter variant (even a terrible drafter only costs speed).
#[test]
fn losslessness_greedy_spec_equals_target() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let target = models.target("qwensim-L").unwrap();
    for variant in ["massv", "massv_wo_sdvit", "baseline"] {
        let drafter = models.drafter_for("qwensim-L", variant).unwrap();
        let dec = SpecDecoder::new(target.clone(), drafter);
        for (i, it) in items.iter().take(6).enumerate() {
            let cfg =
                GenConfig { temperature: 0.0, top_p: 1.0, max_new: 48, seed: i as u64, tree: None };
            let spec = dec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg).unwrap();
            let base = SpecDecoder::generate_baseline(
                &target, &it.image, &it.prompt_ids, it.prompt_len, &cfg,
            )
            .unwrap();
            assert_eq!(
                spec.tokens, base.tokens,
                "variant {variant}, item {i}: speculative != greedy"
            );
        }
    }
}

/// The fused on-device draft loop must equal a host-side step-by-step
/// greedy draft (L2/L3 contract for the key perf optimization).
#[test]
fn fused_draft_matches_stepwise_greedy() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let drafter = models.drafter("qwensim-S", "massv").unwrap();
    let it = &items[0];
    let gamma = models.manifest.gamma;

    let mut s1 = drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false).unwrap();
    let mut s2 = drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false).unwrap();
    let last = 7i32;

    let out = drafter.draft(&mut s1, last, 0.0, 99).unwrap();
    // stepwise reference
    let mut cur = last;
    let mut toks = Vec::new();
    for i in 0..gamma {
        let logits = drafter.decode(&mut s2, cur).unwrap();
        for (a, b) in logits.iter().zip(out.qlogits.row(i)) {
            assert!((a - b).abs() < 1e-3, "qlogits diverge at step {i}");
        }
        cur = sampler::argmax(&logits) as i32;
        toks.push(cur);
    }
    assert_eq!(out.tokens, toks);
}

/// Draft seeds: same seed -> same stochastic draft; T=0 ignores the seed.
#[test]
fn draft_seed_semantics() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let drafter = models.drafter("qwensim-S", "massv").unwrap();
    let it = &items[0];
    let prefill =
        || drafter.prefill(Some(&it.image), &it.prompt_ids, it.prompt_len, false).unwrap();

    let (mut a, mut b, mut c) = (prefill(), prefill(), prefill());
    let oa = drafter.draft(&mut a, 7, 1.0, 123).unwrap();
    let ob = drafter.draft(&mut b, 7, 1.0, 123).unwrap();
    assert_eq!(oa.tokens, ob.tokens);
    let og1 = drafter.draft(&mut c, 7, 0.0, 1).unwrap();
    let mut d = prefill();
    let og2 = drafter.draft(&mut d, 7, 0.0, 2).unwrap();
    assert_eq!(og1.tokens, og2.tokens, "greedy draft must ignore the seed");
}

/// Rollback-free KV: after a simulated rejection mid-window, continuing to
/// decode must equal a fresh run over the accepted prefix.
#[test]
fn kv_stale_tail_is_harmless_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let target = models.target("qwensim-L").unwrap();
    let it = &items[1];
    let gamma = models.manifest.gamma;

    // run a verify with garbage speculation, accept nothing, then decode
    let (logits, mut dirty) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len).unwrap();
    let first = sampler::argmax(&logits) as i32;
    let mut junk = vec![first];
    junk.extend(std::iter::repeat(3).take(gamma)); // <sep> spam as speculation
    let _plogits = target.verify(&mut dirty, &junk).unwrap();
    // accept only `first` -> next decode happens at pos+1
    dirty.pos += 1;
    let dirty_logits = target.decode(&mut dirty, 9).unwrap();

    let (_l2, mut clean) = target.prefill_mm(&it.image, &it.prompt_ids, it.prompt_len).unwrap();
    let _ = target.decode(&mut clean, first).unwrap();
    let clean_logits = target.decode(&mut clean, 9).unwrap();
    for (a, b) in dirty_logits.iter().zip(&clean_logits) {
        assert!((a - b).abs() < 1e-3, "stale tail leaked into logits");
    }
}

/// MASSV must actually speculate productively on visually grounded tasks:
/// pooled MAL > 1.5 (a broken drafter would sit near 1.0).
#[test]
fn massv_mal_is_materially_above_one() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let stats =
        massv::eval::run_spec(&models, "qwensim-L", "massv", &items[..8], 0.0, false, 3).unwrap();
    let mal = massv::eval::pooled_mal(&stats);
    assert!(mal > 1.5, "massv pooled MAL {mal:.2} suspiciously low");
}

/// Target generations must be visually grounded: the caption for an eval
/// image should mention the reference's color+shape pairs (the target was
/// trained to describe the scene; this guards against artifact mixups).
#[test]
fn target_generations_are_visually_grounded() {
    let Some(dir) = artifacts() else { return };
    let (models, tok, items) = setup(&dir);
    let target = models.target("qwensim-L").unwrap();
    let mut hits = 0;
    let mut total = 0;
    for it in items.iter().take(10) {
        let cfg = GenConfig::default();
        let out = SpecDecoder::generate_baseline(
            &target, &it.image, &it.prompt_ids, it.prompt_len, &cfg,
        )
        .unwrap();
        let text = tok.decode(
            &out.tokens.iter().map(|&t| t as u32).collect::<Vec<_>>(),
        );
        // count color-shape bigrams of the reference found in the output
        let ref_words: Vec<&str> = it.reference.split_whitespace().collect();
        for w in ref_words.windows(2) {
            if massv::workload::TASKS.contains(&"coco") // always true; keep shape
                && ["red", "blue", "green", "yellow", "purple", "orange"].contains(&w[0])
            {
                total += 1;
                if text.contains(&format!("{} {}", w[0], w[1])) {
                    hits += 1;
                }
            }
        }
    }
    assert!(total > 0);
    let acc = hits as f64 / total as f64;
    assert!(acc > 0.6, "visual grounding accuracy {acc:.2} too low ({hits}/{total})");
}

/// Engine end-to-end: concurrent requests through the scheduler/worker
/// pool produce valid responses and consistent metrics.
#[test]
fn engine_concurrent_requests() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(
        &dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 3,
            queue_capacity: 64,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let tok = &engine.tokenizer;
    let items = workload::load_task(&dir, "gqa", tok, engine.models.manifest.p_max).unwrap();

    let mut rxs = Vec::new();
    for (i, it) in items.iter().take(9).enumerate() {
        let mut req = Request::simple(engine.next_id(), &it.prompt, it.image.clone());
        req.priority = if i % 3 == 0 { Priority::Batch } else { Priority::Interactive };
        rxs.push(engine.submit(req));
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.tokens.is_empty());
        ok += 1;
    }
    assert_eq!(ok, 9);
    assert_eq!(engine.metrics.requests_completed.get(), 9);
    assert!(engine.metrics.overall_mal() > 1.0);
    engine.shutdown();
}

/// Router fallback inside the engine: requesting TargetOnly works and
/// reports no MAL.
#[test]
fn engine_target_only_mode() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(&dir, EngineConfig::default()).unwrap();
    let items =
        workload::load_task(&dir, "instruct", &engine.tokenizer, engine.models.manifest.p_max)
            .unwrap();
    let mut req = Request::simple(engine.next_id(), &items[0].prompt, items[0].image.clone());
    req.mode = DecodeMode::TargetOnly;
    let resp = engine.run(req);
    assert!(resp.error.is_none());
    assert_eq!(resp.mal, 0.0);
    assert!(resp.verify_calls > 0); // decode steps counted as target passes
    engine.shutdown();
}

/// Full server round-trip over a real socket: generate + metrics + ping.
#[test]
fn server_round_trip() {
    let Some(dir) = artifacts() else { return };
    let engine = Arc::new(
        Engine::start(
            &dir,
            EngineConfig {
                default_target: "qwensim-L".into(),
                workers: 2,
                queue_capacity: 16,
                ..EngineConfig::default()
            },
        )
        .unwrap(),
    );
    let items =
        workload::load_task(&dir, "coco", &engine.tokenizer, engine.models.manifest.p_max)
            .unwrap();

    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();
    assert!(client.ping().unwrap());

    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str(items[0].prompt.clone())),
        ("image", Json::arr_f32(&items[0].image)),
        ("task", Json::str("coco")),
        ("mode", Json::str("massv")),
    ]);
    let resp = client.call(&req).unwrap();
    assert!(resp.get("error").is_none(), "{resp:?}");
    assert!(!resp.get("text").unwrap().as_str().unwrap().is_empty());
    assert!(resp.get("mal").unwrap().as_f64().unwrap() > 1.0);

    let metrics = client.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert!(metrics.get("requests_completed").unwrap().as_f64().unwrap() >= 1.0);

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
}

/// Backpressure: a queue of capacity 1 with a held worker rejects floods.
#[test]
fn engine_backpressure_rejects() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(
        &dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 1,
            queue_capacity: 2,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let items =
        workload::load_task(&dir, "wild", &engine.tokenizer, engine.models.manifest.p_max)
            .unwrap();
    // flood: most must complete, overflow must be rejected cleanly
    let rxs: Vec<_> = (0..12)
        .map(|_| {
            engine.submit(Request::simple(
                engine.next_id(),
                &items[0].prompt,
                items[0].image.clone(),
            ))
        })
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|r| r.recv().unwrap()).collect();
    let rejected = responses.iter().filter(|r| r.error.is_some()).count();
    let completed = responses.iter().filter(|r| r.error.is_none()).count();
    assert_eq!(rejected + completed, 12);
    assert!(rejected > 0, "expected some backpressure rejections");
    assert!(completed >= 2, "queue should still drain");
    assert_eq!(engine.metrics.requests_rejected.get() as usize, rejected);
    engine.shutdown();
}

/// TVD analysis sanity: MASSV's TVD mass at low values exceeds the
/// w/o-SDViT drafter's (the Figure-4 claim, testable end to end).
#[test]
fn tvd_massv_is_better_aligned_than_wo_sdvit() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let (h_massv, _) =
        massv::eval::tvd_histogram(&models, "qwensim-L", "massv", &items[..6], 20, 16).unwrap();
    let (h_wo, _) =
        massv::eval::tvd_histogram(&models, "qwensim-L", "massv_wo_sdvit", &items[..6], 20, 16)
            .unwrap();
    let low_massv = h_massv.cdf(0.3);
    let low_wo = h_wo.cdf(0.3);
    assert!(
        low_massv > low_wo,
        "massv low-TVD mass {low_massv:.3} should exceed w/o-SDViT {low_wo:.3}"
    );
}

/// Adaptive speculation (extension): with a well-aligned drafter it stays
/// speculative and matches plain SD output exactly at T=0; the engine path
/// accepts the flag end to end.
#[test]
fn adaptive_mode_matches_spec_output() {
    let Some(dir) = artifacts() else { return };
    let (models, _tok, items) = setup(&dir);
    let target = models.target("qwensim-L").unwrap();
    let drafter = models.drafter_for("qwensim-L", "massv").unwrap();
    let it = &items[2];
    let cfg = GenConfig::default();

    let dec = SpecDecoder::new(target.clone(), drafter.clone());
    let plain = dec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg).unwrap();

    let adec = massv::spec::AdaptiveDecoder::new(
        SpecDecoder::new(target, drafter),
        massv::spec::AdaptiveConfig::default(),
    );
    let adaptive = adec.generate(&it.image, &it.prompt_ids, it.prompt_len, &cfg).unwrap();
    assert_eq!(plain.tokens, adaptive.tokens);
    assert_eq!(adaptive.fallback_at, None, "aligned drafter should stay speculative");

    // engine-level flag
    let engine = Engine::start(&dir, EngineConfig::default()).unwrap();
    let mut req = Request::simple(engine.next_id(), &it.prompt, it.image.clone());
    req.mode = DecodeMode::Speculative {
        variant: "massv".into(),
        text_only_draft: false,
        adaptive: true,
    };
    let resp = engine.run(req);
    assert!(resp.error.is_none());
    assert_eq!(resp.tokens, plain.tokens);
    engine.shutdown();
}
