//! Cross-request batching tests: the batched-vs-sequential determinism
//! property (session-level oracle over the scripted backend and the full
//! engine), batch-occupancy observability, and a randomized scheduler soak
//! (admit/cancel/deadline/stream interleavings) over the batched engine --
//! no PJRT involved (`manifest.backend == "scripted"`).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use massv::coordinator::{
    DecodeMode, Engine, EngineConfig, Priority, Request, Response, Update,
};
use massv::models::scripted::{demo_image, write_test_artifacts};
use massv::models::ModelSet;
use massv::spec::testing::{run_batched_vs_sequential, OracleLane};
use massv::spec::{GenConfig, SpecMode, TreeConfig};
use massv::util::rng::Rng;

const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// THE batched-execution determinism property at the session level: a
/// random mix of chain/tree/adaptive/target-only lanes (greedy and T=1,
/// cold and prefix-cache-warm prefills) replayed through engine-style
/// fused ticks must be bit-identical -- tokens, accept counts, emission
/// boundaries, GenStats -- to sequential stepping.
#[test]
fn prop_batched_replay_is_bit_identical_to_sequential() {
    let dir = write_test_artifacts("batch_oracle", 48, false);
    let set = ModelSet::load(&dir).unwrap();

    massv::util::prop::propcheck("batched == sequential (oracle)", 24, |rng| {
        let n_lanes = 1 + rng.range(7);
        let lanes: Vec<OracleLane> = (0..n_lanes)
            .map(|_| {
                let mode = match rng.range(4) {
                    0 => None, // target-only (plain-decode lane)
                    1 => Some(SpecMode::Tree),
                    _ => Some(SpecMode::Chain),
                };
                OracleLane {
                    adaptive: mode.is_some() && rng.range(3) == 0,
                    mode,
                    cfg: GenConfig {
                        temperature: if rng.range(2) == 0 { 0.0 } else { 1.0 },
                        seed: rng.next_u64(),
                        max_new: 8 + rng.range(40),
                        tree: Some(TreeConfig {
                            branch: vec![2, 2, 1, 1, 1],
                            max_nodes: 16,
                        }),
                        ..GenConfig::default()
                    },
                    image_phase: rng.range(4),
                    prompt: (0..(2 + rng.range(5)))
                        .map(|_| 5 + rng.range(90) as i32)
                        .collect(),
                    warm: rng.range(3) == 0,
                }
            })
            .collect();
        run_batched_vs_sequential(&set, "qwensim-L", "massv", &lanes)
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// The same property end-to-end through the engine: identical request sets
/// served by an unbatched engine (`max_batch == 1`) and a ganging engine
/// (`max_batch == 8`) must produce identical responses -- tokens, accept
/// accounting, steps, finish reasons -- while the ganging engine actually
/// fuses multi-lane ticks (occupancy metrics prove it ran batched).
#[test]
fn engine_batched_matches_unbatched_and_reports_occupancy() {
    let dir = write_test_artifacts("batch_engine_eq", 2048, false);
    let run_engine = |max_batch: usize| -> (Vec<Response>, std::collections::HashMap<String, f64>) {
        let engine = Engine::start(
            &dir,
            EngineConfig {
                workers: 2,
                queue_capacity: 128,
                max_batch,
                ..EngineConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..12)
            .map(|i| {
                let mut req = Request::simple(
                    engine.next_id(),
                    &format!("w{} w{}", 5 + i % 4, 9 + i % 3),
                    demo_image(i % 3),
                );
                req.mode = match i % 3 {
                    0 => DecodeMode::TargetOnly,
                    1 => DecodeMode::Speculative {
                        variant: "massv".into(),
                        text_only_draft: false,
                        adaptive: false,
                    },
                    _ => DecodeMode::Tree {
                        variant: "massv".into(),
                        text_only_draft: false,
                        adaptive: false,
                    },
                };
                req.gen.max_new = 48;
                req.gen.temperature = if i % 2 == 0 { 0.0 } else { 1.0 };
                req.gen.seed = 1000 + i as u64;
                engine.submit(req)
            })
            .collect();
        let responses: Vec<Response> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let metrics = engine.scrape();
        engine.shutdown();
        (responses, metrics)
    };

    let (unbatched, m1) = run_engine(1);
    let (batched, m8) = run_engine(8);
    assert_eq!(m1["batch_ticks"], 0.0, "max_batch=1 must never fuse ticks");
    assert_eq!(m1["batch_max_lanes"], 1.0);
    assert!(
        m8["batch_ticks"] > 0.0,
        "12 concurrent sessions on 2 workers must produce fused ticks: {m8:?}"
    );
    assert!(m8["batch_occupancy_mean"] > 1.0);
    assert!(m8["batch_occupancy_max"] <= 8.0);
    assert_eq!(m8["batch_max_lanes"], 8.0);

    for (a, b) in unbatched.iter().zip(&batched) {
        assert!(a.error.is_none() && b.error.is_none(), "{:?} / {:?}", a.error, b.error);
        assert_eq!(a.tokens, b.tokens, "ganged decoding must not change tokens");
        assert_eq!(a.verify_calls, b.verify_calls);
        assert_eq!(a.accepted_draft, b.accepted_draft);
        assert_eq!(a.finish_reason, b.finish_reason);
        assert_eq!(a.finished_by_eos, b.finished_by_eos);
        assert_eq!(a.tree_nodes_drafted, b.tree_nodes_drafted);
    }
    std::fs::remove_dir_all(&dir).ok();
}

enum PendingReply {
    Oneshot(Receiver<Response>),
    Stream(massv::coordinator::UpdateReceiver),
}

/// Scheduler soak over the batched engine: randomized admit / cancel /
/// deadline / streaming interleavings for N seeded trials.  Asserts no
/// lost sessions (every submission reaches exactly one terminal), no
/// double completions (terminal counters sum to the submission count; no
/// frames after a stream's Done), and monotone per-session token streams
/// (chunks concatenate exactly to the final token list).
#[test]
fn soak_randomized_admit_cancel_deadline_stream_interleavings() {
    let dir = write_test_artifacts("batch_soak", 4096, false);
    for trial in 0..6u64 {
        let mut rng = Rng::seeded(0x50AC + trial);
        let engine = Engine::start(
            &dir,
            EngineConfig {
                workers: 1 + (trial as usize % 3),
                queue_capacity: 256,
                max_batch: 2 + rng.range(7),
                ..EngineConfig::default()
            },
        )
        .unwrap();

        let n = 16 + rng.range(17);
        let mut pending: Vec<(u64, PendingReply)> = Vec::new();
        let mut submitted_ids: Vec<u64> = Vec::new();
        for _ in 0..n {
            let mut req = Request::simple(
                engine.next_id(),
                ["w5 w6", "w7 w8 w9", "w10", "w11 w12"][rng.range(4)],
                demo_image(rng.range(4)),
            );
            req.mode = match rng.range(4) {
                0 => DecodeMode::TargetOnly,
                1 => DecodeMode::Tree {
                    variant: "massv".into(),
                    text_only_draft: false,
                    adaptive: rng.range(2) == 0,
                },
                _ => DecodeMode::Speculative {
                    variant: "massv".into(),
                    text_only_draft: false,
                    adaptive: rng.range(2) == 0,
                },
            };
            req.gen.max_new = 4 + rng.range(60);
            req.gen.temperature = if rng.range(2) == 0 { 0.0 } else { 1.0 };
            req.gen.seed = rng.next_u64();
            req.priority =
                if rng.range(3) == 0 { Priority::Batch } else { Priority::Interactive };
            if rng.range(6) == 0 {
                req.deadline_ms = Some(rng.range(3) as u64);
            }
            let id = req.id;
            submitted_ids.push(id);
            let reply = if rng.range(2) == 0 {
                PendingReply::Stream(engine.submit_streaming(req))
            } else {
                PendingReply::Oneshot(engine.submit(req))
            };
            pending.push((id, reply));
            // interleave: occasionally cancel an earlier request mid-flight
            if rng.range(4) == 0 && !submitted_ids.is_empty() {
                let victim = submitted_ids[rng.range(submitted_ids.len())];
                engine.cancel(victim); // false for already-finished ids: fine
            }
            if rng.range(3) == 0 {
                std::thread::sleep(Duration::from_micros(50 + rng.range(400) as u64));
            }
        }

        // every submission must reach exactly one terminal reply
        for (id, reply) in pending {
            match reply {
                PendingReply::Oneshot(rx) => {
                    let resp = rx
                        .recv_timeout(RECV_TIMEOUT)
                        .unwrap_or_else(|e| panic!("trial {trial}: lost session {id}: {e}"));
                    assert_eq!(resp.id, id);
                    assert!(
                        rx.recv_timeout(Duration::from_millis(10)).is_err(),
                        "trial {trial}: double completion for {id}"
                    );
                }
                PendingReply::Stream(rx) => {
                    let mut streamed: Vec<i32> = Vec::new();
                    let resp = loop {
                        match rx.recv_timeout(RECV_TIMEOUT) {
                            Ok(Update::Chunk(toks)) => {
                                assert!(!toks.is_empty(), "empty chunk frames are never sent");
                                streamed.extend(toks); // chunks only append: monotone stream
                            }
                            Ok(Update::Done(resp)) => break resp,
                            Err(e) => panic!("trial {trial}: lost stream {id}: {e}"),
                        }
                    };
                    assert_eq!(resp.id, id);
                    // the flush invariant holds for EVERY finish reason --
                    // completed, cancelled, deadline, failed: chunk
                    // concatenation equals the summary token list exactly
                    assert_eq!(
                        streamed, resp.tokens,
                        "trial {trial}: stream of {id} ({}) diverges from summary",
                        resp.finish_reason
                    );
                    match rx.recv_timeout(Duration::from_millis(10)) {
                        Err(RecvTimeoutError::Disconnected) | Err(RecvTimeoutError::Timeout) => {}
                        Ok(f) => panic!("trial {trial}: frame after Done for {id}: {f:?}"),
                    }
                }
            }
        }

        // exactly-once terminal accounting across the whole trial
        let m = engine.scrape();
        let terminals = m["requests_completed"]
            + m["requests_cancelled"]
            + m["requests_deadline_exceeded"]
            + m["requests_failed"]
            + m["requests_rejected"];
        assert_eq!(
            terminals, n as f64,
            "trial {trial}: terminal counters must sum to submissions: {m:?}"
        );
        assert_eq!(m["requests_received"], n as f64);
        assert_eq!(m["inflight"], 0.0, "trial {trial}: sessions leaked");
        assert_eq!(m["requests_failed"], 0.0, "trial {trial}: unexpected failures");
        engine.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}
