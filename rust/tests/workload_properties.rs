//! Property tests for the workload generators and scenario traces.
//!
//! These pin the contracts the scenario suite (`docs/scenarios.md`)
//! leans on:
//!
//! 1. determinism -- the same `(knobs, seed)` pair produces a
//!    byte-identical schedule or trace (floats compared by bit pattern,
//!    traces by `Trace::digest` *and* full `Debug` rendering);
//! 2. validity -- scenario arrivals are time-sorted, non-negative, and
//!    carry legal class/tenant/budget fields under every knob variant;
//! 3. stream independence -- each knob perturbs only the stream it
//!    semantically owns: `rate` moves arrival times but never
//!    items/images/classes, `zipf_s` moves images but never
//!    arrivals/items/classes, `prompt_pool` and `max_new` never move
//!    arrivals or images.
//!
//! Property 3 is what makes knob sweeps in the benches A/B-comparable:
//! two traces that differ in one knob share everything that knob does
//! not own.

use std::collections::BTreeMap;

use massv::util::rng::Rng;
use massv::workload::scenario::{by_name, ScenarioKnobs, Trace, NAMES};
use massv::workload::{
    bounded_pareto, hotspot_image_schedule, piecewise_poisson, poisson_schedule,
    repeated_image_schedule, Arrival, HotSpotKnobs, MmArrival, RepeatKnobs, CLASSES,
};

fn knobs() -> ScenarioKnobs {
    ScenarioKnobs { requests: 64, ..ScenarioKnobs::default() }
}

/// Full byte-level signature of a flat schedule (floats by bit pattern).
fn arr_sig(s: &[Arrival]) -> Vec<(u64, usize, &'static str)> {
    s.iter().map(|a| (a.at.to_bits(), a.item, a.class)).collect()
}

fn mm_sig(s: &[MmArrival]) -> Vec<(u64, usize, usize, &'static str)> {
    s.iter().map(|a| (a.at.to_bits(), a.item, a.image, a.class)).collect()
}

/// Trace keyed by (conversation, turn): everything a `rate` sweep must
/// preserve.  `finish()` sorts by arrival and truncates to the request
/// budget, so a rate change may rotate which fringe requests survive the
/// cut -- comparisons go through this map, not positional order.
type Placement = (u64, usize, &'static str, String, usize);

fn content_map(t: &Trace) -> BTreeMap<(u64, usize), (usize, &'static str, String, String, usize)> {
    t.requests
        .iter()
        .map(|r| {
            let v = (r.image, r.class, r.tenant.clone(), r.prompt.clone(), r.max_new);
            ((r.conv, r.turn), v)
        })
        .collect()
}

/// Keyed view with arrival bits but without the prompt text: what a
/// `prompt_pool` sweep must preserve.
fn placement_map(t: &Trace, keep_prompt: bool) -> BTreeMap<(u64, usize), Placement> {
    t.requests
        .iter()
        .map(|r| {
            let p = if keep_prompt { r.prompt.clone() } else { String::new() };
            ((r.conv, r.turn), (r.at.to_bits(), r.image, r.class, r.tenant.clone(), p))
        })
        .collect()
}

#[test]
fn same_seed_flat_schedules_are_byte_identical() {
    assert_eq!(
        arr_sig(&poisson_schedule(256, 25.0, 12, 42)),
        arr_sig(&poisson_schedule(256, 25.0, 12, 42))
    );
    assert_ne!(
        arr_sig(&poisson_schedule(256, 25.0, 12, 42)),
        arr_sig(&poisson_schedule(256, 25.0, 12, 43)),
        "seed must matter"
    );
    let rk = RepeatKnobs { image_pool: 6, reuse_prob: 0.35 };
    assert_eq!(
        mm_sig(&repeated_image_schedule(256, 25.0, 8, &rk, 42)),
        mm_sig(&repeated_image_schedule(256, 25.0, 8, &rk, 42))
    );
    let hk = HotSpotKnobs { image_pool: 16, zipf_s: 1.1, reuse_prob: 0.3 };
    assert_eq!(
        mm_sig(&hotspot_image_schedule(256, 25.0, 8, &hk, 42)),
        mm_sig(&hotspot_image_schedule(256, 25.0, 8, &hk, 42))
    );
    // the scalar primitives replay too, given equal rng states
    let mut a = Rng::seeded(99);
    let mut b = Rng::seeded(99);
    let pa: Vec<u64> = (0..64).map(|_| bounded_pareto(&mut a, 1.2, 2.0, 40.0).to_bits()).collect();
    let pb: Vec<u64> = (0..64).map(|_| bounded_pareto(&mut b, 1.2, 2.0, 40.0).to_bits()).collect();
    assert_eq!(pa, pb);
    let segs = [(1.0, 4.0), (0.5, 16.0)];
    let wa: Vec<u64> = piecewise_poisson(64, &segs, &mut a).iter().map(|x| x.to_bits()).collect();
    let wb: Vec<u64> = piecewise_poisson(64, &segs, &mut b).iter().map(|x| x.to_bits()).collect();
    assert_eq!(wa, wb);
}

#[test]
fn same_seed_traces_are_byte_identical() {
    for name in NAMES {
        let a = by_name(name, &knobs(), 17).unwrap();
        let b = by_name(name, &knobs(), 17).unwrap();
        assert_eq!(a.digest(), b.digest(), "{name}: same seed, same digest");
        assert_eq!(format!("{a:?}"), format!("{b:?}"), "{name}: same seed, same bytes");
        let c = by_name(name, &knobs(), 18).unwrap();
        assert_ne!(a.digest(), c.digest(), "{name}: seed must matter");
    }
}

#[test]
fn scenario_arrivals_always_sorted_and_tagged() {
    let variants = [
        ScenarioKnobs { requests: 48, rate: 15.0, ..knobs() },
        ScenarioKnobs { requests: 64, rate: 200.0, image_pool: 2, prompt_pool: 2, ..knobs() },
        ScenarioKnobs { requests: 32, rate: 0.0, max_new: 1, image_base: 500, ..knobs() },
    ];
    for name in NAMES {
        for (vi, k) in variants.iter().enumerate() {
            for seed in [1, 2] {
                let t = by_name(name, k, seed).unwrap();
                assert_eq!(t.requests.len(), k.requests, "{name} v{vi} s{seed}");
                for w in t.requests.windows(2) {
                    assert!(w[0].at <= w[1].at, "{name} v{vi} s{seed}: arrivals sorted");
                }
                for r in &t.requests {
                    assert!(r.at.is_finite() && r.at >= 0.0, "{name} v{vi} s{seed}");
                    assert!(CLASSES.contains(&r.class), "{name} v{vi} s{seed}");
                    assert!(!r.tenant.is_empty() && !r.prompt.is_empty(), "{name} v{vi} s{seed}");
                    assert!(r.max_new >= 1, "{name} v{vi} s{seed}");
                    assert!(r.image >= k.image_base, "{name} v{vi} s{seed}: image_base offsets");
                }
            }
        }
    }
}

#[test]
fn flat_generator_knobs_perturb_only_their_streams() {
    // rate owns arrival times: items and classes never move
    let slow = poisson_schedule(256, 5.0, 12, 7);
    let fast = poisson_schedule(256, 50.0, 12, 7);
    let tail = |s: &[Arrival]| s.iter().map(|a| (a.item, a.class)).collect::<Vec<_>>();
    assert_eq!(tail(&slow), tail(&fast), "rate must not move items/classes");

    // item_pool owns items: arrivals, images, and classes never move
    let rk = RepeatKnobs { image_pool: 6, reuse_prob: 0.35 };
    let a = repeated_image_schedule(256, 30.0, 4, &rk, 7);
    let b = repeated_image_schedule(256, 30.0, 9, &rk, 7);
    let frame =
        |s: &[MmArrival]| s.iter().map(|x| (x.at.to_bits(), x.image, x.class)).collect::<Vec<_>>();
    assert_eq!(frame(&a), frame(&b), "item_pool must not move arrivals/images/classes");
    assert_ne!(
        a.iter().map(|x| x.item).collect::<Vec<_>>(),
        b.iter().map(|x| x.item).collect::<Vec<_>>(),
        "item_pool owns the item stream"
    );

    // zipf_s owns image popularity: arrivals, items, and classes never move
    let uk = HotSpotKnobs { image_pool: 16, zipf_s: 0.0, reuse_prob: 0.2 };
    let sk = HotSpotKnobs { zipf_s: 1.4, ..uk.clone() };
    let u = hotspot_image_schedule(256, 30.0, 5, &uk, 7);
    let s = hotspot_image_schedule(256, 30.0, 5, &sk, 7);
    let spine =
        |s: &[MmArrival]| s.iter().map(|x| (x.at.to_bits(), x.item, x.class)).collect::<Vec<_>>();
    assert_eq!(spine(&u), spine(&s), "zipf_s must not move arrivals/items/classes");
    assert_ne!(
        u.iter().map(|x| x.image).collect::<Vec<_>>(),
        s.iter().map(|x| x.image).collect::<Vec<_>>(),
        "zipf_s owns the image stream"
    );
}

#[test]
fn scenario_rate_moves_times_never_content() {
    for name in NAMES {
        let slow = by_name(name, &ScenarioKnobs { rate: 20.0, ..knobs() }, 11).unwrap();
        let fast = by_name(name, &ScenarioKnobs { rate: 60.0, ..knobs() }, 11).unwrap();
        let (a, b) = (content_map(&slow), content_map(&fast));
        // a rate change can rotate which fringe requests survive the
        // truncation cut, but the shared core must agree field-for-field
        let shared: Vec<_> = a.keys().filter(|k| b.contains_key(*k)).collect();
        assert!(
            shared.len() * 4 >= knobs().requests * 3,
            "{name}: truncation may drop a fringe, not {} of {}",
            knobs().requests - shared.len(),
            knobs().requests
        );
        for key in shared {
            assert_eq!(a[key], b[key], "{name} {key:?}: rate must not move content");
        }
    }
}

#[test]
fn scenario_prompt_pool_never_moves_arrivals_images_or_classes() {
    for name in NAMES {
        let a = by_name(name, &ScenarioKnobs { prompt_pool: 3, ..knobs() }, 13).unwrap();
        let b = by_name(name, &ScenarioKnobs { prompt_pool: 9, ..knobs() }, 13).unwrap();
        assert_eq!(placement_map(&a, false), placement_map(&b, false), "{name}");
    }
}

#[test]
fn scenario_decode_budget_never_moves_arrivals_or_content() {
    for name in NAMES {
        let a = by_name(name, &ScenarioKnobs { max_new: 8, ..knobs() }, 19).unwrap();
        let b = by_name(name, &ScenarioKnobs { max_new: 24, ..knobs() }, 19).unwrap();
        assert_eq!(placement_map(&a, true), placement_map(&b, true), "{name}");
    }
}

#[test]
fn registry_is_complete_and_closed() {
    let mut seen = std::collections::BTreeSet::new();
    for name in NAMES {
        assert!(seen.insert(name), "duplicate scenario name {name}");
        assert!(by_name(name, &knobs(), 1).is_some(), "{name} must build");
    }
    assert_eq!(seen.len(), 6);
    assert!(by_name("not_a_scenario", &knobs(), 1).is_none());
}
