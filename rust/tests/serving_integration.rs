//! Continuous-batching serving tests over the scripted backend: iteration-
//! level scheduling, streaming delivery, cancellation, deadlines, and the
//! run-to-completion fallback policy -- all with no PJRT involved
//! (`manifest.backend == "scripted"`).

use std::sync::{Arc, Mutex};

use massv::coordinator::{
    DecodeMode, Engine, EngineConfig, Priority, Request, SchedPolicy, Update,
};
use massv::util::json::Json;

/// Scripted-backend artifact dir under tmp (shared fixture; `gen_max`
/// controls the stream length -- large values make decodes long enough to
/// observe interleaving deterministically).
fn scripted_artifacts(tag: &str, gen_max: usize) -> String {
    massv::models::scripted::write_test_artifacts(tag, gen_max, false)
}

fn image(phase: usize) -> Vec<f32> {
    massv::models::scripted::demo_image(phase)
}

fn request(engine: &Engine, mode: DecodeMode, prompt: &str, img_phase: usize) -> Request {
    let mut req = Request::simple(engine.next_id(), prompt, image(img_phase));
    req.mode = mode;
    req
}

fn one_worker(dir: &str, queue: usize) -> Engine {
    Engine::start(
        dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 1,
            queue_capacity: queue,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

/// Drain a streaming receiver: returns (concatenated chunks, final response).
fn drain(rx: massv::coordinator::UpdateReceiver) -> (Vec<i32>, massv::coordinator::Response) {
    let mut streamed = Vec::new();
    loop {
        match rx.recv().expect("stream ended without a Done frame") {
            Update::Chunk(toks) => streamed.extend(toks),
            Update::Done(resp) => return (streamed, resp),
        }
    }
}

/// THE continuous-batching property: with ONE worker, a short interactive
/// request submitted while a long batch request is mid-decode finishes
/// first (iteration-level scheduling interleaves them), and the batch
/// request still completes losslessly.
#[test]
fn interactive_preempts_long_batch_decode_with_one_worker() {
    let dir = scripted_artifacts("interleave", 16384);
    let engine = one_worker(&dir, 64);

    // long batch decode: 16000 target-only steps
    let mut batch = request(&engine, DecodeMode::TargetOnly, "w5 w6 w7", 0);
    batch.priority = Priority::Batch;
    batch.gen.max_new = 16000;
    let batch_rx = engine.submit_streaming(batch);

    // wait until the batch request is mid-decode (prefill chunk arrived)
    match batch_rx.recv().unwrap() {
        Update::Chunk(_) => {}
        Update::Done(r) => panic!("batch finished instantly: {r:?}"),
    }

    // now a short interactive request arrives
    let mut inter = request(&engine, DecodeMode::TargetOnly, "w8 w9", 1);
    inter.gen.max_new = 4;
    let inter_rx = engine.submit(inter);

    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let o1 = order.clone();
    let batch_handle = std::thread::spawn(move || {
        let (streamed, resp) = drain(batch_rx);
        o1.lock().unwrap().push("batch");
        (streamed, resp)
    });
    let o2 = order.clone();
    let inter_handle = std::thread::spawn(move || {
        let resp = inter_rx.recv().unwrap();
        o2.lock().unwrap().push("interactive");
        resp
    });

    let inter_resp = inter_handle.join().unwrap();
    let (batch_streamed, batch_resp) = batch_handle.join().unwrap();

    assert_eq!(
        order.lock().unwrap().first().copied(),
        Some("interactive"),
        "interactive request must finish before the long batch decode"
    );
    assert!(inter_resp.error.is_none(), "{:?}", inter_resp.error);
    assert_eq!(inter_resp.tokens.len(), 4);
    assert!(
        inter_resp.steps <= 6,
        "interactive took {} dispatches; expected a handful",
        inter_resp.steps
    );
    assert!(
        inter_resp.latency_ms < batch_resp.latency_ms,
        "interactive latency {:.1}ms must undercut batch {:.1}ms",
        inter_resp.latency_ms,
        batch_resp.latency_ms
    );

    // the interleaved batch decode is still lossless
    assert!(batch_resp.error.is_none(), "{:?}", batch_resp.error);
    assert_eq!(batch_resp.finish_reason, "length");
    assert_eq!(batch_resp.tokens.len(), 16000);
    assert_eq!(batch_streamed, batch_resp.tokens, "chunks must concatenate to the output");
    let mut reference = request(&engine, DecodeMode::TargetOnly, "w5 w6 w7", 0);
    reference.gen.max_new = 16000;
    let reference = engine.run(reference);
    assert_eq!(batch_resp.tokens, reference.tokens, "interleaving must not change tokens");

    assert_eq!(engine.metrics.requests_completed.get(), 3);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Cancellation mid-decode returns a partial response and frees the
/// session (registry entry removed, active_sessions back to zero).
#[test]
fn cancel_mid_decode_returns_partial_output() {
    let dir = scripted_artifacts("cancel", 16384);
    let engine = one_worker(&dir, 16);

    let mut req = request(&engine, DecodeMode::TargetOnly, "w10 w11", 2);
    req.gen.max_new = 16000;
    let id = req.id;
    let rx = engine.submit_streaming(req);
    match rx.recv().unwrap() {
        Update::Chunk(_) => {}
        Update::Done(r) => panic!("finished before cancel: {r:?}"),
    }

    assert!(engine.cancel(id), "id must still be live");
    let (streamed, resp) = drain(rx);
    assert_eq!(resp.finish_reason, "cancelled");
    assert!(resp.error.is_none(), "cancellation is not an error: {:?}", resp.error);
    assert!(!resp.tokens.is_empty(), "partial output must be delivered");
    assert!(resp.tokens.len() < 16000, "cancel must cut the decode short");
    assert!(!resp.finished_by_eos);
    assert_eq!(streamed, resp.tokens);

    assert_eq!(engine.metrics.requests_cancelled.get(), 1);
    assert_eq!(engine.metrics.inflight.get(), 0, "session must be freed");
    assert!(!engine.cancel(id), "finished request is no longer cancellable");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Deadlines: an already-expired deadline drops the request at admission
/// with zero output; a mid-decode expiry returns the partial output.
#[test]
fn deadline_exceeded_drops_cleanly() {
    let dir = scripted_artifacts("deadline", 16384);
    let engine = one_worker(&dir, 16);

    // expired on arrival
    let mut req = request(&engine, DecodeMode::TargetOnly, "w12", 3);
    req.deadline_ms = Some(0);
    let resp = engine.run(req);
    assert_eq!(resp.finish_reason, "deadline");
    assert!(resp.tokens.is_empty());
    assert!(resp.error.is_none());

    // expires mid-decode
    let mut req = request(&engine, DecodeMode::TargetOnly, "w13 w14", 4);
    req.gen.max_new = 16000;
    req.deadline_ms = Some(2);
    let resp = engine.run(req);
    assert_eq!(resp.finish_reason, "deadline");
    assert!(resp.tokens.len() < 16000, "deadline must cut the decode short");
    assert!(!resp.finished_by_eos);

    assert_eq!(engine.metrics.requests_deadline_exceeded.get(), 2);
    assert_eq!(engine.metrics.inflight.get(), 0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Streaming equivalence property: seed for seed, the concatenation of
/// streamed chunks equals the one-shot Response.tokens, for chain and tree
/// modes (plus target-only), greedy and T=1.
#[test]
fn prop_streamed_chunks_equal_oneshot_tokens() {
    let dir = scripted_artifacts("stream_eq", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14"];

    let eng = engine.clone();
    massv::util::prop::propcheck("streamed chunks == one-shot tokens", 24, move |rng| {
        let prompt = prompts[rng.range(prompts.len())];
        let phase = rng.range(5);
        let mode = match rng.range(3) {
            0 => DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: rng.range(2) == 0,
            },
            1 => DecodeMode::Tree {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: rng.range(2) == 0,
            },
            _ => DecodeMode::TargetOnly,
        };
        let temperature = if rng.range(2) == 0 { 0.0 } else { 1.0 };
        let seed = rng.next_u64();

        let mut oneshot = request(&eng, mode.clone(), prompt, phase);
        oneshot.gen.temperature = temperature;
        oneshot.gen.seed = seed;
        let mut streaming = request(&eng, mode, prompt, phase);
        streaming.gen.temperature = temperature;
        streaming.gen.seed = seed;

        let oneshot = eng.run(oneshot);
        if oneshot.error.is_some() {
            return Err(format!("one-shot failed: {:?}", oneshot.error));
        }
        let rx = eng.submit_streaming(streaming);
        let mut streamed = Vec::new();
        let resp = loop {
            match rx.recv().map_err(|e| format!("stream dropped: {e}"))? {
                Update::Chunk(toks) => streamed.extend(toks),
                Update::Done(resp) => break resp,
            }
        };
        if resp.error.is_some() {
            return Err(format!("streaming failed: {:?}", resp.error));
        }
        if streamed != resp.tokens {
            return Err(format!(
                "chunk concat {streamed:?} != summary tokens {:?}",
                resp.tokens
            ));
        }
        if resp.tokens != oneshot.tokens {
            return Err(format!(
                "streamed tokens {:?} != one-shot tokens {:?}",
                resp.tokens, oneshot.tokens
            ));
        }
        Ok(())
    });

    let engine = Arc::try_unwrap(engine).unwrap_or_else(|_| panic!("engine still shared"));
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The legacy run-to-completion policy still serves correctly (A/B knob
/// for benches) and produces the same tokens as continuous batching.
#[test]
fn run_to_completion_policy_matches_continuous() {
    let dir = scripted_artifacts("rtc", 48);
    let continuous = Engine::start(&dir, EngineConfig::default()).unwrap();
    let rtc = Engine::start(
        &dir,
        EngineConfig { policy: SchedPolicy::RunToCompletion, ..EngineConfig::default() },
    )
    .unwrap();

    for (i, prompt) in ["w5 w6 w7", "w8 w9"].iter().enumerate() {
        let spec = DecodeMode::Speculative {
            variant: "massv".into(),
            text_only_draft: false,
            adaptive: false,
        };
        let a = continuous.run(request(&continuous, spec.clone(), prompt, i));
        let b = rtc.run(request(&rtc, spec, prompt, i));
        assert!(a.error.is_none() && b.error.is_none());
        assert_eq!(a.tokens, b.tokens, "policies must agree on {prompt:?}");

        // streaming works under run-to-completion too
        let rx = rtc.submit_streaming(request(
            &rtc,
            DecodeMode::Tree { variant: "massv".into(), text_only_draft: false, adaptive: false },
            prompt,
            i,
        ));
        let (streamed, resp) = drain(rx);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(streamed, resp.tokens);
    }
    continuous.shutdown();
    rtc.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Rejected submissions are terminal outcomes: finish_reason "rejected"
/// and queue/latency samples recorded (the old path dropped them).
#[test]
fn rejections_record_metrics() {
    let dir = scripted_artifacts("reject", 16384);
    let engine = one_worker(&dir, 2);

    let rxs: Vec<_> = (0..10)
        .map(|i| {
            let mut req = request(&engine, DecodeMode::TargetOnly, "w15 w16", i);
            req.gen.max_new = 2000;
            req.priority = Priority::Batch;
            engine.submit(req)
        })
        .collect();
    let responses: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    let rejected = responses.iter().filter(|r| r.finish_reason == "rejected").count();
    let completed = responses.iter().filter(|r| r.error.is_none()).count();
    assert_eq!(rejected + completed, 10);
    assert!(rejected > 0, "capacity 2 must reject part of a 10-deep flood");
    assert!(completed >= 2, "the queue must still drain");
    assert_eq!(engine.metrics.requests_rejected.get() as usize, rejected);
    // every terminal outcome -- completed or rejected -- left a sample
    assert_eq!(engine.metrics.queue_ms.count(), 10);
    assert_eq!(engine.metrics.latency_ms.count(), 10);
    assert_eq!(engine.metrics.steps_per_request.count(), completed);
    assert!(engine.metrics.steps_per_request.mean() > 1.0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// THE prefix-cache property at the engine level: resubmitting an
/// identical request must hit the cache and produce a bit-identical
/// response (tokens, acceptance accounting, steps, finish reason) across
/// chain, tree, adaptive, and target-only modes, greedy and T=1 -- and a
/// third submission referencing the image by `image_id` alone must match
/// too.
#[test]
fn prop_warm_prefill_matches_cold_across_modes() {
    let dir = scripted_artifacts("prefix_prop", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let prompts = ["w5 w6 w7", "w8 w9", "w10 w11 w12 w13", "w14"];

    let eng = engine.clone();
    massv::util::prop::propcheck("warm prefill == cold prefill (engine)", 20, move |rng| {
        let prompt = prompts[rng.range(prompts.len())];
        let phase = rng.range(6);
        let mode = match rng.range(4) {
            0 => DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            },
            1 => DecodeMode::Tree {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: false,
            },
            2 => DecodeMode::Speculative {
                variant: "massv".into(),
                text_only_draft: false,
                adaptive: true,
            },
            _ => DecodeMode::TargetOnly,
        };
        let temperature = if rng.range(2) == 0 { 0.0 } else { 1.0 };
        let seed = rng.next_u64();
        let make = || {
            let mut r = request(&eng, mode.clone(), prompt, phase);
            r.gen.temperature = temperature;
            r.gen.seed = seed;
            r
        };

        let first = eng.run(make());
        if first.error.is_some() {
            return Err(format!("first run failed: {:?}", first.error));
        }
        let second = eng.run(make());
        if second.error.is_some() {
            return Err(format!("second run failed: {:?}", second.error));
        }
        if !second.cache_hit {
            return Err("second identical request must hit the prefix cache".into());
        }
        if second.tokens != first.tokens {
            return Err(format!(
                "warm tokens {:?} != cold tokens {:?}",
                second.tokens, first.tokens
            ));
        }
        let same = second.verify_calls == first.verify_calls
            && second.accepted_draft == first.accepted_draft
            && second.steps == first.steps
            && second.finish_reason == first.finish_reason
            && second.finished_by_eos == first.finished_by_eos
            && second.tree_nodes_drafted == first.tree_nodes_drafted
            && (second.mal - first.mal).abs() < 1e-12
            && (second.mean_path_depth - first.mean_path_depth).abs() < 1e-12;
        if !same {
            return Err(format!("warm stats diverge: {second:?} vs {first:?}"));
        }

        // image_id-only resubmission: no pixels on the wire at all
        if first.image_id.is_empty() {
            return Err("responses must report the image_id".into());
        }
        let mut by_id = make();
        by_id.image = Vec::new();
        by_id.image_id =
            Some(massv::cache::parse_image_id(&first.image_id).map_err(|e| format!("{e:#}"))?);
        let by_id = eng.run(by_id);
        if by_id.error.is_some() {
            return Err(format!("image_id run failed: {:?}", by_id.error));
        }
        if by_id.tokens != first.tokens {
            return Err("image_id request must reproduce the pixel request".into());
        }
        Ok(())
    });

    let engine = Arc::try_unwrap(engine).unwrap_or_else(|_| panic!("engine still shared"));
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Eviction under pressure: a tiny byte budget must evict (counted in
/// metrics), never exceed the budget, and never affect correctness --
/// an evicted prefix simply re-runs cold with the same deterministic
/// output.
#[test]
fn eviction_under_pressure_stays_within_budget_and_correct() {
    let dir = scripted_artifacts("evict", 2048);
    let engine = Engine::start(
        &dir,
        EngineConfig {
            workers: 1,
            queue_capacity: 64,
            prefix_cache_bytes: 64 * 1024,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let spec = || DecodeMode::Speculative {
        variant: "massv".into(),
        text_only_draft: false,
        adaptive: false,
    };
    let mut req0 = request(&engine, spec(), "w5 w6", 0);
    req0.gen.max_new = 6;
    let first = engine.run(req0);
    assert!(first.error.is_none(), "{:?}", first.error);

    // flood with distinct images; each prefix is ~25 KB of scripts + KV,
    // so a 64 KB budget forces evictions
    for i in 1..10 {
        let mut r = request(&engine, spec(), "w5 w6", i);
        r.gen.max_new = 6;
        let resp = engine.run(r);
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let m = engine.scrape();
    assert!(m["prefix_cache_evictions"] > 0.0, "pressure must evict: {m:?}");
    assert!(
        m["prefix_cache_bytes"] <= (64 * 1024) as f64,
        "budget violated: {} bytes",
        m["prefix_cache_bytes"]
    );

    // the first image's prefix was evicted long ago; re-running is cold
    // again but bit-identical
    let mut again = request(&engine, spec(), "w5 w6", 0);
    again.gen.max_new = 6;
    let again = engine.run(again);
    assert!(again.error.is_none(), "{:?}", again.error);
    assert_eq!(again.tokens, first.tokens, "eviction must not change outputs");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Single-flight dedup: six concurrent requests over the same fresh image
/// (distinct prompts, so six prefix fills) must run exactly ONE image
/// encode -- the rest wait on the in-flight fill and count as hits.
#[test]
fn single_flight_dedupes_concurrent_encodes() {
    let dir = scripted_artifacts("singleflight", 4096);
    let engine = Engine::start(
        &dir,
        EngineConfig { workers: 4, queue_capacity: 64, ..EngineConfig::default() },
    )
    .unwrap();
    let img = image(9);
    let rxs: Vec<_> = (0..6)
        .map(|i| {
            let mut r = Request::simple(engine.next_id(), &format!("w{}", 20 + i), img.clone());
            r.mode = DecodeMode::TargetOnly;
            r.gen.max_new = 4;
            engine.submit(r)
        })
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.tokens.len(), 4);
    }
    let m = engine.scrape();
    assert_eq!(
        m["vision_encode_fills"], 1.0,
        "six concurrent same-image requests must encode once: {m:?}"
    );
    assert_eq!(m["vision_encode_hits"], 5.0);
    assert_eq!(m["prefix_cache_misses"], 6.0, "six distinct prompts -> six prefix fills");
    assert_eq!(m["prefix_cache_hits"], 0.0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `image_id` over the wire: send pixels once, reference them afterwards;
/// unknown and malformed ids produce clean errors.
#[test]
fn image_id_protocol_round_trip() {
    let dir = scripted_artifacts("image_id", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();

    let with_pixels = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6 w7")),
        ("image", Json::arr_f32(&image(2))),
        ("seed", Json::num(0.0)),
    ]);
    let r1 = client.call(&with_pixels).unwrap();
    assert!(r1.get("error").is_none(), "{r1:?}");
    let id = r1.get("image_id").unwrap().as_str().unwrap().to_string();
    assert_eq!(id.len(), 16, "image_id is 16 hex digits: {id:?}");
    assert!(!r1.get("cache_hit").unwrap().as_bool().unwrap(), "first touch is cold");
    assert!(r1.get("prefill_ms").unwrap().as_f64().unwrap() >= 0.0);

    // follow-up without pixels
    let by_id = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6 w7")),
        ("image_id", Json::str(id.clone())),
        ("seed", Json::num(0.0)),
    ]);
    let r2 = client.call(&by_id).unwrap();
    assert!(r2.get("error").is_none(), "{r2:?}");
    assert_eq!(
        r2.get("tokens").unwrap().to_i32_vec().unwrap(),
        r1.get("tokens").unwrap().to_i32_vec().unwrap(),
        "image_id request must reproduce the pixel request"
    );
    assert!(r2.get("cache_hit").unwrap().as_bool().unwrap(), "identical request must be warm");
    assert_eq!(r2.get("image_id").unwrap().as_str().unwrap(), id);

    // unknown id: clean per-request error, server keeps serving
    let unknown = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5")),
        ("image_id", Json::str("00000000000000aa".to_string())),
    ]);
    let r3 = client.call(&unknown).unwrap();
    let err = r3.get("error").unwrap().as_str().unwrap().to_string();
    assert!(err.contains("unknown image_id"), "{err}");

    // malformed id: rejected at parse time
    let malformed = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5")),
        ("image_id", Json::str("not-hex".to_string())),
    ]);
    let r4 = client.call(&malformed).unwrap();
    assert!(r4.get("error").unwrap().as_str().unwrap().contains("image_id"));

    // neither pixels nor id
    let neither = Json::obj(vec![("op", Json::str("generate")), ("prompt", Json::str("w5"))]);
    let r5 = client.call(&neither).unwrap();
    assert!(r5.get("error").is_some());

    assert!(client.ping().unwrap(), "server must survive the error paths");
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Full TCP round-trip for the new wire surface: streaming frames and the
/// cancel op.
#[test]
fn server_streaming_and_cancel_round_trip() {
    let dir = scripted_artifacts("server_stream", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();

    let gen_req = |mode: &str, stream: bool| {
        Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("w5 w6 w7")),
            ("image", Json::arr_f32(&image(0))),
            ("mode", Json::str(mode)),
            ("seed", Json::num(0.0)),
            ("stream", Json::Bool(stream)),
        ])
    };

    for mode in ["massv", "tree", "target_only"] {
        let oneshot = client.call(&gen_req(mode, false)).unwrap();
        assert!(oneshot.get("error").is_none(), "{oneshot:?}");
        let (chunks, summary) = client.call_streaming(&gen_req(mode, true)).unwrap();
        assert!(summary.get("error").is_none(), "{summary:?}");
        assert!(chunks.len() > 1, "{mode}: expected multiple frames");
        let concat: Vec<i32> = chunks.into_iter().flatten().collect();
        assert_eq!(
            concat,
            summary.get("tokens").unwrap().to_i32_vec().unwrap(),
            "{mode}: chunk concatenation must equal the summary tokens"
        );
        assert_eq!(
            concat,
            oneshot.get("tokens").unwrap().to_i32_vec().unwrap(),
            "{mode}: streaming must not change the tokens"
        );
        assert!(summary.get("finish_reason").is_some());
        assert!(summary.get("steps").unwrap().as_i64().unwrap() >= 1);
    }

    // cancel of an already-finished id reports ok: false
    let done_id = client
        .call(&gen_req("massv", false))
        .unwrap()
        .get("id")
        .unwrap()
        .as_i64()
        .unwrap();
    let cancel = client
        .call(&Json::obj(vec![
            ("op", Json::str("cancel")),
            ("id", Json::num(done_id as f64)),
        ]))
        .unwrap();
    assert!(!cancel.get("ok").unwrap().as_bool().unwrap());

    // metrics expose the serving-layer gauges
    let metrics = client.call(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert!(metrics.get("active_sessions").unwrap().as_f64().unwrap() >= 0.0);
    assert!(metrics.get("steps_per_request_mean").unwrap().as_f64().unwrap() > 1.0);
    assert!(metrics.get("tpot_ms_p50").is_some());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: a request dribbled in over multiple writes with a pause
/// longer than the server's 100 ms read timeout must still parse.  The old
/// handler cleared its line buffer at the top of every loop iteration, so
/// a timeout tick discarded whatever partial line `read_line` had already
/// consumed from the socket -- slow clients got "parse error" or silence.
#[test]
fn slow_client_dribbled_request_survives_read_timeout() {
    use std::io::{BufRead, BufReader, Write};

    let dir = scripted_artifacts("dribble", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6 w7")),
        ("image", Json::arr_f32(&image(0))),
        ("seed", Json::num(0.0)),
        ("max_new", Json::num(8.0)),
    ])
    .to_string();
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    // first half, then a pause spanning several server read-timeout ticks,
    // then the rest of the line
    let (head, tail) = req.split_at(req.len() / 2);
    stream.write_all(head.as_bytes()).unwrap();
    stream.flush().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(350));
    stream.write_all(tail.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let resp = massv::util::json::parse(&line).unwrap();
    assert!(resp.get("error").is_none(), "dribbled request failed: {resp:?}");
    assert_eq!(resp.get("tokens").unwrap().to_i32_vec().unwrap().len(), 8);

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression (session leak): a mid-stream write failure -- the client
/// vanished -- must cancel the session and settle terminal accounting
/// before `handle_request` unwinds.  The pre-fix handler just returned the
/// write error, leaving the engine decoding to max_new for a dead
/// connection; asserting counter state IMMEDIATELY after the call returns
/// fails on that code (the session was still live) and passes on the fix
/// (cancel + drain happen inside the handler).
#[test]
fn mid_stream_write_failure_cancels_session_before_handler_returns() {
    use std::io::Write;

    /// Accepts `ok_writes` write calls, then reports the peer gone.
    struct FailAfter {
        ok_writes: usize,
        written: usize,
    }
    impl Write for FailAfter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.written >= self.ok_writes {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "client gone",
                ));
            }
            self.written += 1;
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    let dir = scripted_artifacts("write_fail", 16384);
    let engine = one_worker(&dir, 16);
    let line = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(0))),
        ("max_new", Json::num(16000.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    // let the first chunk frame through (frame bytes + newline = 2 write
    // calls), then fail: the "client" disconnected mid-stream
    let mut sink = FailAfter { ok_writes: 2, written: 0 };
    let result = massv::server::handle_request(&line, &engine, &mut sink);
    assert!(result.is_err(), "the write failure must surface to the connection loop");
    // no polling, no sleeps: the handler drained the stream to its end, and
    // the engine settles terminal accounting before closing the channel
    assert_eq!(engine.metrics.requests_cancelled.get(), 1, "session must be cancelled");
    assert_eq!(engine.metrics.inflight.get(), 0, "session must be freed");
    assert_eq!(engine.metrics.requests_completed.get(), 0);
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The same disconnect black-box over a real socket: a client that drops
/// its connection mid-stream gets its session cancelled promptly (the dead
/// peer turns into a write error, which the handler converts to a cancel)
/// instead of decoding to max_new.
#[test]
fn tcp_disconnect_mid_stream_frees_session() {
    use std::io::{BufRead, BufReader, Write};

    let dir = scripted_artifacts("tcp_disconnect", 16384);
    let engine = Arc::new(one_worker(&dir, 16));
    let server = massv::server::Server::new(engine.clone());
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6")),
        ("image", Json::arr_f32(&image(1))),
        ("max_new", Json::num(16000.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writer.write_all(req.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    // wait for the first frame so the stream is known to be in flight,
    // then vanish without reading the rest
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        massv::util::json::parse(&line).unwrap().get("chunk").is_some(),
        "first frame must be a chunk: {line:?}"
    );
    drop(reader);
    drop(writer);

    // the handler notices the dead peer on its next frame write and
    // cancels; give it a bounded window to settle
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
    loop {
        if engine.metrics.requests_cancelled.get() == 1 && engine.metrics.inflight.get() == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "disconnected client's session never cancelled: cancelled={} inflight={}",
            engine.metrics.requests_cancelled.get(),
            engine.metrics.inflight.get()
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert_eq!(engine.metrics.requests_completed.get(), 0, "session must not run to max_new");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression (unbounded buffering): the per-session update channel is
/// bounded -- a consumer that stalls gets later chunks coalesced into the
/// newest queued frame instead of queueing one frame per decode step --
/// and coalescing never changes the delivered token sequence.
#[test]
fn slow_consumer_stream_is_bounded_and_lossless() {
    let dir = scripted_artifacts("bounded_stream", 16384);
    let engine = Engine::start(
        &dir,
        EngineConfig {
            default_target: "qwensim-L".into(),
            workers: 1,
            queue_capacity: 16,
            stream_chunk_cap: 4,
            ..EngineConfig::default()
        },
    )
    .unwrap();

    let mut reference = request(&engine, DecodeMode::TargetOnly, "w5 w6", 0);
    reference.gen.max_new = 3000;
    let reference = engine.run(reference);
    assert!(reference.error.is_none(), "{:?}", reference.error);

    let mut req = request(&engine, DecodeMode::TargetOnly, "w5 w6", 0);
    req.gen.max_new = 3000;
    let rx = engine.submit_streaming(req);
    // consume far slower than the decode produces: the old unbounded
    // channel would buffer thousands of frames here
    let mut streamed = Vec::new();
    let resp = loop {
        match rx.recv().unwrap() {
            Update::Chunk(toks) => {
                streamed.extend(toks);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            Update::Done(resp) => break resp,
        }
    };
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.finish_reason, "length");
    assert_eq!(streamed, resp.tokens, "chunks must concatenate to the output");
    assert_eq!(resp.tokens, reference.tokens, "coalescing must not change tokens");
    assert!(
        rx.peak_buffered() <= 4,
        "buffer must stay within stream_chunk_cap: peak {}",
        rx.peak_buffered()
    );
    assert!(rx.coalesced() > 0, "a slow consumer must actually trigger coalescing");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Raising the stop flag while a stream is in flight neither hangs
/// `serve()` nor loses the stream's final summary frame: the in-flight
/// frame sequence runs to completion, then the handler notices the flag,
/// exits, and the accept loop joins every connection thread.
#[test]
fn shutdown_mid_stream_delivers_summary_and_joins() {
    use std::io::{BufRead, BufReader, Write};

    let dir = scripted_artifacts("shutdown_stream", 16384);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    let req = Json::obj(vec![
        ("op", Json::str("generate")),
        ("prompt", Json::str("w5 w6 w7")),
        ("image", Json::arr_f32(&image(0))),
        ("max_new", Json::num(4000.0)),
        ("stream", Json::Bool(true)),
    ])
    .to_string();
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writer.write_all(req.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(massv::util::json::parse(&line).unwrap().get("chunk").is_some());

    // stop the server while the stream is mid-flight
    stop.store(true, std::sync::atomic::Ordering::Relaxed);

    // the client must still receive the rest of the stream, summary included
    let summary = loop {
        let mut line = String::new();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "stream cut before the summary frame"
        );
        let frame = massv::util::json::parse(&line).unwrap();
        if frame.get("chunk").is_none() {
            break frame;
        }
    };
    assert_eq!(summary.get("finish_reason").unwrap().as_str().unwrap(), "length");
    assert_eq!(summary.get("tokens").unwrap().to_i32_vec().unwrap().len(), 4000);

    // ...and serve() must join (a hang here fails the test by timeout)
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression (silent coercion): a present-but-malformed generate field is
/// rejected with an error frame naming the field, never coerced to a
/// default.  Table-driven over every validated field; the connection
/// survives each rejection and a well-formed request still succeeds after.
#[test]
fn malformed_fields_are_rejected_with_named_errors() {
    let dir = scripted_artifacts("bad_fields", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine.clone());
    let stop = server.stop_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();
    let mut client = massv::server::Client::connect(&addr.to_string()).unwrap();

    // (field expected in the error message, poisoned request fields)
    let cases: Vec<(&str, Vec<(&str, Json)>)> = vec![
        ("temperature", vec![("temperature", Json::str("hot"))]),
        ("temperature", vec![("temperature", Json::num(-0.5))]),
        ("top_p", vec![("top_p", Json::num(0.0))]),
        ("top_p", vec![("top_p", Json::num(1.5))]),
        ("top_p", vec![("top_p", Json::str("p"))]),
        ("max_new", vec![("max_new", Json::num(0.0))]),
        ("max_new", vec![("max_new", Json::num(7.5))]),
        ("max_new", vec![("max_new", Json::str("many"))]),
        ("seed", vec![("seed", Json::num(-1.0))]),
        ("seed", vec![("seed", Json::Bool(true))]),
        ("stream", vec![("stream", Json::str("yes"))]),
        ("priority", vec![("priority", Json::str("urgent"))]),
        ("priority", vec![("priority", Json::num(1.0))]),
        ("deadline_ms", vec![("deadline_ms", Json::num(-5.0))]),
        ("deadline_ms", vec![("deadline_ms", Json::num(0.5))]),
        ("draft_vision_ratio", vec![("draft_vision_ratio", Json::str("x"))]),
        ("tenant", vec![("tenant", Json::str(""))]),
        ("tenant", vec![("tenant", Json::num(3.0))]),
        ("mode", vec![("mode", Json::num(1.0))]),
        // variant is only consulted (and therefore validated) in tree mode
        ("variant", vec![("mode", Json::str("tree")), ("variant", Json::Bool(false))]),
        ("prompt", vec![("prompt", Json::num(5.0))]),
        ("image", vec![("image", Json::str("pixels"))]),
        ("image_id", vec![("image_id", Json::num(9.0))]),
        ("text_only_draft", vec![("text_only_draft", Json::str("no"))]),
        ("adaptive", vec![("adaptive", Json::num(1.0))]),
    ];
    for (field, poison) in cases {
        let mut obj = vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("w5 w6")),
            ("image", Json::arr_f32(&image(0))),
        ];
        for (k, v) in poison {
            obj.retain(|(name, _)| *name != k);
            obj.push((k, v));
        }
        let resp = client.call(&Json::obj(obj)).unwrap();
        let err = resp
            .get("error")
            .unwrap_or_else(|| panic!("bad {field:?} was coerced, not rejected: {resp:?}"))
            .as_str()
            .unwrap()
            .to_string();
        assert!(
            err.contains(&format!("{field:?}")),
            "error for {field:?} must name the field: {err}"
        );
    }
    // nothing reached the engine, and the connection survived every reject
    assert_eq!(engine.metrics.requests_received.get(), 0);
    assert!(client.ping().unwrap());
    let ok = client
        .call(&Json::obj(vec![
            ("op", Json::str("generate")),
            ("prompt", Json::str("w5 w6")),
            ("image", Json::arr_f32(&image(0))),
        ]))
        .unwrap();
    assert!(ok.get("error").is_none(), "{ok:?}");

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: the accept loop must reap finished connection threads as it
/// runs, not hold every JoinHandle until shutdown (one leaked handle per
/// connection ever accepted, unbounded on a long-lived server).
#[test]
fn accept_loop_reaps_finished_connection_threads() {
    let dir = scripted_artifacts("reap", 48);
    let engine = Arc::new(Engine::start(&dir, EngineConfig::default()).unwrap());
    let server = massv::server::Server::new(engine);
    let stop = server.stop_handle();
    let conns = server.conn_count_handle();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap()).unwrap();
    });
    let addr = rx.recv().unwrap();

    // open a burst of connections, use them, close them all
    let mut clients: Vec<_> = (0..5)
        .map(|_| massv::server::Client::connect(&addr.to_string()).unwrap())
        .collect();
    for c in clients.iter_mut() {
        assert!(c.ping().unwrap());
    }
    assert!(conns.load(std::sync::atomic::Ordering::Relaxed) >= 5);
    drop(clients);

    // the handlers notice EOF within one 100 ms read-timeout tick; give
    // the accept loop time to observe the finished threads and reap
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        if conns.load(std::sync::atomic::Ordering::Relaxed) == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "finished connection threads were never reaped: {} still tracked",
            conns.load(std::sync::atomic::Ordering::Relaxed)
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // the server still accepts new connections after reaping
    let mut again = massv::server::Client::connect(&addr.to_string()).unwrap();
    assert!(again.ping().unwrap());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    h.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
