"""Pure-jnp oracle for the fused attention kernel.

This is the CORE correctness signal for Layer 1: kernels/attention.py must
match this reference bit-for-bit in semantics (allclose in f32) across every
shape/mask configuration the models use.  pytest + hypothesis sweep the
space in python/tests/test_kernel.py.

Masking semantics (shared by kernel, reference, and the Rust-side mental
model):
  * query i in the current call has absolute position ``qa = pos + i``
  * key j is visible iff  j <= qa                      (causal)
  *                 and  j > qa - window  (if windowed) (sliding window)
Stale KV-cache entries at j > pos + s - 1 are never visible because of the
causal rule, which is what makes rejection rollback free in the serving
layer (DESIGN.md section 3).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q: jnp.ndarray,  # [H, S, Dh]
    k: jnp.ndarray,  # [H, T, Dh]
    v: jnp.ndarray,  # [H, T, Dh]
    pos,  # scalar i32: absolute position of q[:, 0]
    window: int | None = None,
    causal: bool = True,
) -> jnp.ndarray:
    """Naive softmax attention with the canonical mask. Returns [H, S, Dh]."""
    h, s, dh = q.shape
    t = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("hsd,htd->hst", q, k) * scale  # [H, S, T]

    qa = pos + jnp.arange(s)[:, None]  # [S, 1] absolute query positions
    kj = jnp.arange(t)[None, :]  # [1, T]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask = mask & (kj <= qa)
    if window is not None:
        mask = mask & (kj > qa - window)
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)

    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hst,htd->hsd", probs, v)
