"""Layer-1 Pallas kernel: fused flash-style attention over a KV cache.

This is the compute hot-spot of VLM serving (the paper's decode/verify path
on H100).  HARDWARE ADAPTATION (DESIGN.md section 3): the paper's setting is
CUDA (threadblocks, shared memory); on TPU-shaped hardware we re-express the
same insight with Pallas primitives:

  * the HBM<->VMEM schedule the paper does with threadblocks is expressed
    with ``BlockSpec``s: one (head, q-block) program instance per grid cell,
    K/V streamed through VMEM in ``block_k``-sized tiles;
  * online softmax keeps the running (max, denominator, accumulator) state
    in VMEM-resident loop carries instead of shared memory;
  * tile sizes default to MXU-friendly multiples (the systolic array wants
    128-lane tiles; our toy head dims are smaller, so tiles are
    parameterized and the roofline analysis in EXPERIMENTS.md scales them).

``interpret=True`` is required for CPU PJRT execution: real TPU lowering
emits a Mosaic custom-call that the CPU plugin cannot run.  Correctness is
pinned to kernels/ref.py by python/tests/test_kernel.py (pytest +
hypothesis shape/mask sweeps).

Masking semantics are shared with the reference (see ref.py docstring):
query i has absolute position ``qa = pos + i``; key j is visible iff
``j <= qa`` and, for sliding-window layers, ``j > qa - window``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    pos_ref,  # [1] i32 in SMEM-like memory: absolute position of q[:, 0]
    q_ref,  # [1, block_q, Dh]
    k_ref,  # [1, T, Dh] (whole head, streamed in block_k tiles below)
    v_ref,  # [1, T, Dh]
    o_ref,  # [1, block_q, Dh]
    *,
    block_k: int,
    window: int | None,
    causal: bool,
):
    block_q = q_ref.shape[1]
    dh = q_ref.shape[2]
    t = k_ref.shape[1]
    n_k = t // block_k

    iq = pl.program_id(1)
    pos = pos_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, Dh]
    # absolute positions of the queries in this block
    qa = pos + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    def body(jk, carry):
        m_prev, l_prev, acc_prev = carry
        k_blk = k_ref[0, pl.ds(jk * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(jk * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_blk.T  # [bq, bk] -- the MXU matmul tile

        kj = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=bool)
        if causal:
            mask = mask & (kj <= qa)
        if window is not None:
            mask = mask & (kj > qa - window)
        s = jnp.where(mask, s, NEG_INF)

        # online softmax update
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        # fully-masked entries at m_new == NEG_INF would yield exp(0)=1;
        # they are wiped by corr=0 as soon as a real key appears and a row
        # always sees at least its own position, so the final state is exact
        # (proof obligation discharged by the hypothesis sweep vs ref.py).
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc_prev * corr + p @ v_blk
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, acc0))

    out = acc / jnp.maximum(l, 1e-30)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "causal", "block_q", "block_k", "interpret"),
)
def fused_attention(
    q: jnp.ndarray,  # [H, S, Dh]
    k: jnp.ndarray,  # [H, T, Dh]
    v: jnp.ndarray,  # [H, T, Dh]
    pos,  # scalar i32
    *,
    window: int | None = None,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused attention over a (possibly stale-tailed) KV cache.

    Pads S up to a multiple of ``block_q`` (padded queries attend validly
    but their outputs are sliced away) and requires T to be a multiple of
    ``block_k`` -- model configs guarantee that (T_max = 96, block 32).
    """
    h, s, dh = q.shape
    t = k.shape[1]
    bq = min(block_q, _next_multiple(s, 1))
    bq = s if s <= block_q else block_q
    s_pad = _next_multiple(s, bq)
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    if t % block_k != 0:
        raise ValueError(f"T={t} must be a multiple of block_k={block_k}")

    grid = (h, s_pad // bq)
    pos_arr = jnp.asarray(pos, dtype=jnp.int32).reshape((1,))

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, block_k=block_k, window=window, causal=causal
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda ih, iq: (0,)),
            pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
            pl.BlockSpec((1, t, dh), lambda ih, iq: (ih, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda ih, iq: (ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, iq: (ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s_pad, dh), q.dtype),
        interpret=interpret,
    )(pos_arr, q, k, v)

    return out[:, :s, :]


def _next_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_footprint_bytes(
    s: int, t: int, dh: int, block_q: int, block_k: int, dtype_bytes: int = 4
) -> dict:
    """Analytic VMEM budget per program instance -- the quantity we tune in
    the section-Perf block-size sweep (interpret-mode wallclock is not a TPU
    proxy; structure is what we optimize).  See EXPERIMENTS.md section Perf."""
    bq = min(block_q, s)
    q_tile = bq * dh * dtype_bytes
    kv_tile = 2 * block_k * dh * dtype_bytes
    state = (2 * bq + bq * dh) * 4  # m, l, acc in f32
    scores = bq * block_k * 4
    total = q_tile + kv_tile + state + scores
    return {
        "q_tile": q_tile,
        "kv_tile": kv_tile,
        "softmax_state": state,
        "scores_tile": scores,
        "total": total,
    }


def mxu_utilization_estimate(dh: int, block_q: int, block_k: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for the score matmul tile, assuming a
    mxu x mxu systolic array processes (block_q x dh) @ (dh x block_k)."""
    eff_m = min(block_q, mxu) / mxu
    eff_k = min(dh, mxu) / mxu
    eff_n = min(block_k, mxu) / mxu
    return eff_m * eff_k * eff_n
