"""AOT lowering: JAX -> HLO text artifacts for the Rust runtime.

Python's last act: every inference entry point of every trained model is
jitted with its weights CLOSED OVER (baked as HLO constants), lowered to
stablehlo, converted to an XlaComputation, and dumped as HLO *text*.

HLO text -- not ``.serialize()`` -- is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Also emitted:
  artifacts/manifest.json         model registry the Rust runtime loads
  artifacts/vocab.json            shared tokenizer tables
  artifacts/eval/<task>.json      fixed eval sets (prompts + images)
  artifacts/training_curves.json  Figure-5 data (written by train.py)

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, shapeworld, train
from .config import (
    ALIGN_TARGET,
    DRAFT_VARIANTS,
    EVAL_N_PER_TASK,
    EVAL_SEED,
    GAMMA,
    GEN_MAX,
    MODELS,
    N_VISUAL,
    P_MAX,
    T_MAX,
    ModelConfig,
)

# Serving artifacts are lowered from the pure-jnp attention path.  The
# Pallas kernel is a TPU artifact: on CPU it must run interpret=True,
# which expands each pallas_call into a while-loop nest whose overhead
# grows with grid size (measured ~1.15x on gamma+1 verify at this model
# scale, larger on long-sequence prefill -- EXPERIMENTS.md section Perf).
# XLA:CPU also fuses the jnp attention into tighter loops than the
# interpret expansion allows.  The kernel still ships in the SAME HLO
# format for the models listed in KERNEL_VALIDATION below; the Rust
# integration suite proves kernel-path and serving-path artifacts are
# numerically identical, and pytest pins the kernel to the jnp oracle.
# Set MASSV_SERVE_KERNEL=1 to serve fully from the kernel lowering.
SERVE_KERNEL = os.environ.get("MASSV_SERVE_KERNEL", "0") == "1"
KERNEL_VALIDATION = [("target", "qwensim-L"), ("draft", "qwensim-S", "massv")]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # Two print-option gotchas vs the plain ``as_hlo_text()``:
    #  * print_large_constants=True -- jax >= 0.7 ELIDES multi-dim dense
    #    literals as ``constant({...})`` by default; XLA 0.5.1's parser
    #    silently accepts that as garbage (zeros / denormals), so every
    #    baked weight would vanish.
    #  * print_metadata=False -- the new printer emits metadata fields
    #    (source_end_line, ...) the 0.5.1 parser rejects outright.
    mod = comp.get_hlo_module()
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return mod.to_string(opts)


def _write(outdir: str, name: str, lowered) -> dict:
    path = os.path.join(outdir, "hlo", f"{name}.hlo.txt")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return {"file": f"hlo/{name}.hlo.txt", "bytes": len(text)}


def _kv_shape(cfg: ModelConfig) -> list[int]:
    return [cfg.n_layers, 2, cfg.n_heads, cfg.t_max, cfg.d_head]


# ---------------------------------------------------------------------------
# Lowering per model
# ---------------------------------------------------------------------------


def lower_common(
    params: dict, cfg: ModelConfig, name: str, outdir: str, *, mm: bool,
    use_kernel: bool = None,
) -> dict:
    """Entry points shared by targets and drafters."""
    USE_KERNEL = SERVE_KERNEL if use_kernel is None else use_kernel
    img = jax.ShapeDtypeStruct((shapeworld.IMG_SIZE, shapeworld.IMG_SIZE, 3), jnp.float32)
    prompt = jax.ShapeDtypeStruct((P_MAX,), jnp.int32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    f32 = jax.ShapeDtypeStruct((), jnp.float32)
    u32 = jax.ShapeDtypeStruct((), jnp.uint32)
    kv = jax.ShapeDtypeStruct(tuple(_kv_shape(cfg)), jnp.float32)

    entries = {}
    if mm:
        entries["prefill_mm"] = _write(
            outdir, f"{name}.prefill_mm",
            jax.jit(
                lambda image, ids, ln: model.prefill_mm(
                    params, cfg, image, ids, ln, use_kernel=USE_KERNEL
                )
            ).lower(img, prompt, i32),
        )
    entries["prefill_text"] = _write(
        outdir, f"{name}.prefill_text",
        jax.jit(
            lambda ids, ln: model.prefill_text(params, cfg, ids, ln, use_kernel=USE_KERNEL)
        ).lower(prompt, i32),
    )
    toks_v = jax.ShapeDtypeStruct((GAMMA + 1,), jnp.int32)
    entries["verify"] = _write(
        outdir, f"{name}.verify",
        jax.jit(
            lambda t, p, c: model.extend(params, cfg, t, p, c, use_kernel=USE_KERNEL)
        ).lower(toks_v, i32, kv),
    )
    tok1 = jax.ShapeDtypeStruct((1,), jnp.int32)
    entries["decode"] = _write(
        outdir, f"{name}.decode",
        jax.jit(
            lambda t, p, c: model.extend(params, cfg, t, p, c, use_kernel=USE_KERNEL)
        ).lower(tok1, i32, kv),
    )
    entries["draft"] = _write(
        outdir, f"{name}.draft",
        jax.jit(
            lambda t, p, c, temp, seed: model.draft_scan(
                params, cfg, t, p, c, temp, seed, gamma=GAMMA, use_kernel=USE_KERNEL
            )
        ).lower(i32, i32, kv, f32, u32),
    )
    return entries


def model_record(name: str, cfg: ModelConfig, entries: dict, *, kind: str, extra: dict) -> dict:
    return {
        "name": name,
        "kind": kind,
        "family": cfg.family,
        "paper_analog": cfg.paper_analog,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_head": cfg.d_head,
        "vocab": cfg.vocab,
        "window": cfg.window if cfg.family == "gemsim" else None,
        "kv_shape": _kv_shape(cfg),
        "entries": entries,
        **extra,
    }


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse existing artifacts/params checkpoints")
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    pdir = os.path.join(outdir, "params")

    # ---- 1. train (or reuse checkpoints) ---------------------------------
    have_all = os.path.isdir(pdir) and all(
        os.path.exists(os.path.join(pdir, f"target_{t}.pkl"))
        for t, c in MODELS.items()
        if c.role == "target"
    ) and all(
        os.path.exists(os.path.join(pdir, f"draft_{d}_{v}.pkl"))
        for d in ALIGN_TARGET
        for v in DRAFT_VARIANTS
    )
    if not (args.skip_train and have_all) and not have_all:
        train.train_all(outdir)

    # ---- 2. lower every model --------------------------------------------
    manifest: dict = {
        "schema": 1,
        "gamma": GAMMA,
        "t_max": T_MAX,
        "p_max": P_MAX,
        "n_visual": N_VISUAL,
        "gen_max": GEN_MAX,
        "vocab_size": shapeworld.VOCAB_SIZE,
        "pad_id": shapeworld.PAD_ID,
        "bos_id": shapeworld.BOS_ID,
        "eos_id": shapeworld.EOS_ID,
        "sep_id": shapeworld.SEP_ID,
        "use_kernel": SERVE_KERNEL,
        "targets": [],
        "drafters": [],
    }

    for name, cfg in MODELS.items():
        if cfg.role != "target":
            continue
        print(f"lowering target {name}", flush=True)
        params = train.load_params(os.path.join(pdir, f"target_{name}.pkl"))
        entries = lower_common(params, cfg, f"target_{name}", outdir, mm=True)
        manifest["targets"].append(
            model_record(name, cfg, entries, kind="target", extra={})
        )

    for dname, align in ALIGN_TARGET.items():
        cfg = MODELS[dname]
        for variant in DRAFT_VARIANTS:
            print(f"lowering drafter {dname}/{variant}", flush=True)
            params = train.load_params(
                os.path.join(pdir, f"draft_{dname}_{variant}.pkl")
            )
            mm = variant != "baseline"  # baseline is the text-only drafter
            entries = lower_common(
                params, cfg, f"draft_{dname}_{variant}", outdir, mm=mm
            )
            manifest["drafters"].append(
                model_record(
                    dname, cfg, entries, kind="draft",
                    extra={
                        "variant": variant,
                        "aligned_target": align,
                        "multimodal": mm,
                    },
                )
            )

    # ---- 2b. kernel-path validation artifacts ------------------------------
    # Same models, attention routed through the Pallas kernel (interpret
    # lowering).  The Rust suite asserts numerical equivalence with the
    # serving artifacts; EXPERIMENTS.md section Perf benches the gap.
    kernel_records = []
    for spec in KERNEL_VALIDATION:
        if spec[0] == "target":
            name = spec[1]
            params = train.load_params(os.path.join(pdir, f"target_{name}.pkl"))
            cfg = MODELS[name]
            mm = True
            label = f"kernel_target_{name}"
        else:
            name, variant = spec[1], spec[2]
            params = train.load_params(os.path.join(pdir, f"draft_{name}_{variant}.pkl"))
            cfg = MODELS[name]
            mm = variant != "baseline"
            label = f"kernel_draft_{name}_{variant}"
        print(f"lowering kernel-path validation artifact {label}", flush=True)
        entries = lower_common(params, cfg, label, outdir, mm=mm, use_kernel=True)
        rec = model_record(name, cfg, entries, kind="kernel_validation", extra={})
        if spec[0] == "draft":
            rec["variant"] = spec[2]
        kernel_records.append(rec)
    manifest["kernel_validation"] = kernel_records

    # ---- 3. vocab + eval sets --------------------------------------------
    with open(os.path.join(outdir, "vocab.json"), "w") as f:
        f.write(shapeworld.vocab_json())
    evdir = os.path.join(outdir, "eval")
    os.makedirs(evdir, exist_ok=True)
    for i, task in enumerate(shapeworld.TASKS):
        with open(os.path.join(evdir, f"{task}.json"), "w") as f:
            f.write(shapeworld.eval_set_json(task, EVAL_N_PER_TASK, EVAL_SEED + i))

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts written to {outdir}", flush=True)


if __name__ == "__main__":
    main()
